//! Figure 8 — PCIe read bandwidth: Base vs BuddyMoE.
//!
//! Paper: the Base method (always fetch missing experts from host memory)
//! uses ~20% more PCIe read bandwidth than BuddyMoE, which resolves most
//! misses inside GPU memory. We serve the identical workload under both
//! policies and report demand/prefetch read bytes and effective bandwidth.

mod bench_support;

use std::sync::Arc;

use buddymoe::buddy::BuddyProfile;
use buddymoe::config::ServingConfig;
use buddymoe::eval::{build_requests, profile_model, warm_rank_from_profile, TableSettings};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::server::Server;

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let fast = bench_support::fast_mode();
    let settings = TableSettings {
        cache_rate: 0.5,
        n_easy: if fast { 3 } else { 6 },
        n_hard: if fast { 3 } else { 6 },
        max_new: if fast { 8 } else { 16 },
        seed: 42,
        clock: bench_support::clock_mode(),
    };
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 64 }, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);

    println!("# Figure 8 — PCIe read traffic at c = {}\n", settings.cache_rate);
    println!("| Method | demand MB | prefetch MB | total MB | mean read bw (scaled GB/s) | wall s |");
    println!("|---|---|---|---|---|---|");
    let mut totals = Vec::new();
    for preset in ["original", "buddy-rho3"] {
        let mut scfg = ServingConfig::default().preset(preset).unwrap();
        scfg.cache_rate = settings.cache_rate;
        let buddies =
            BuddyProfile::build(&pc, &vec![scfg.cft_alpha; cfg.n_layers], scfg.k_max, 1e-3, true)
                .unwrap();
        let engine = Engine::new(
            cfg.clone(),
            scfg,
            Arc::clone(&store),
            Some(buddies),
            Some(warm.clone()),
            EngineOptions { clock: settings.clock, ..Default::default() },
        )
        .unwrap();
        let mut server = Server::new(engine);
        let clock = server.engine.clock();
        let t0 = clock.now();
        server.run_offline(build_requests(&cfg, &settings)).unwrap();
        let wall = clock.since(t0);
        let stats = server
            .engine
            .transfer_handle()
            .with_state(|st| st.pcie_stats());
        let mb = |b: u64| b as f64 / (1024.0 * 1024.0);
        let scaled_bw = if wall > 0.0 {
            stats.total_bytes() as f64 * 1600.0 / wall / 1e9
        } else {
            0.0
        };
        println!(
            "| {} | {:.2} | {:.2} | {:.2} | {:.2} | {:.1} |",
            preset,
            mb(stats.demand_bytes),
            mb(stats.prefetch_bytes),
            mb(stats.total_bytes()),
            scaled_bw,
            wall
        );
        totals.push(stats.total_bytes() as f64);
        server.engine.shutdown();
    }
    if totals.len() == 2 && totals[1] > 0.0 {
        println!(
            "\nBase uses {:+.1}% more PCIe read traffic than BuddyMoE (paper: ~+20%)",
            100.0 * (totals[0] / totals[1] - 1.0)
        );
    }
}
