//! Figures 1, 4, 6, 7/9 — the structural/motivational figures.
//!
//! * Fig 1: model size vs accelerator memory scaling gap (static series
//!   reconstructed from public specs, as the paper does).
//! * Fig 4: expert weight-similarity heatmap statistics (layer 0).
//! * Fig 6: per-expert activation distribution (layer 11).
//! * Fig 7/9: co-activation matrix sparsity structure (layer 1).

mod bench_support;

use buddymoe::eval::profile_model;
use buddymoe::profilecollect::expert_similarity_matrix;

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };

    // ---- Fig 1: the scaling gap (relative to 2017 levels) ---------------
    println!("# Figure 1 — model size vs single-accelerator memory (relative, 2017=1)\n");
    println!("| year | flagship model | params (B) | rel. model | device | mem GB | rel. mem |");
    println!("|---|---|---|---|---|---|---|");
    let series = [
        (2017, "Transformer-big", 0.21, "P100", 16.0),
        (2019, "GPT-2", 1.5, "V100", 32.0),
        (2020, "GPT-3", 175.0, "A100", 40.0),
        (2022, "PaLM", 540.0, "A100", 80.0),
        (2024, "DeepSeek-V3 (MoE)", 671.0, "H100", 80.0),
        (2025, "frontier MoE (est.)", 2000.0, "B200", 192.0),
    ];
    let (p0, m0) = (series[0].2, series[0].4);
    for (y, m, p, d, mem) in series {
        println!(
            "| {y} | {m} | {p} | {:.0}x | {d} | {mem} | {:.1}x |",
            p / p0,
            mem / m0
        );
    }
    println!("\n-> model growth ~9500x vs memory growth ~12x over the window (the paper's widening gap).\n");

    // ---- Fig 4: weight similarity ---------------------------------------
    let sim = expert_similarity_matrix(&cfg, &store, 0).unwrap();
    let fs = cfg.family_size;
    let (mut win, mut cross, mut nw, mut nc) = (0.0f64, 0.0f64, 0usize, 0usize);
    let mut bright = 0usize;
    for i in 0..cfg.n_experts {
        for j in (i + 1)..cfg.n_experts {
            let s = sim[i][j] as f64;
            if s > 0.5 {
                bright += 1;
            }
            if i / fs == j / fs {
                win += s;
                nw += 1;
            } else {
                cross += s;
                nc += 1;
            }
        }
    }
    println!("# Figure 4 — expert similarity heatmap (layer 0)\n");
    println!(
        "within-family mean cos: {:.3} | cross-family: {:.3} | pairs >0.5: {} (bright regions)",
        win / nw as f64,
        cross / nc as f64,
        bright
    );

    // ---- Figs 6 + 7/9: routing structure --------------------------------
    let n = if bench_support::fast_mode() { 24 } else { 64 };
    let pc = profile_model(&cfg, store, n, 7777).unwrap();

    let l = (cfg.n_layers - 1).min(11);
    let acts = &pc.layer(l).activations;
    let total: f64 = acts.iter().sum();
    let mut ranked: Vec<f64> = acts.clone();
    ranked.sort_by(|a, b| b.total_cmp(a));
    let top8: f64 = ranked.iter().take(8).sum();
    let gini = {
        let mut s = acts.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let n = s.len() as f64;
        let sum: f64 = s.iter().sum();
        let cum: f64 = s
            .iter()
            .enumerate()
            .map(|(i, &x)| (i as f64 + 1.0) * x)
            .sum();
        (2.0 * cum) / (n * sum) - (n + 1.0) / n
    };
    println!("\n# Figure 6 — activation distribution (layer {l})\n");
    println!(
        "top-8/{} experts take {:.1}% of routing events | gini {:.3} | max/median {:.1}",
        cfg.n_experts,
        100.0 * top8 / total,
        gini,
        ranked[0] / ranked[cfg.n_experts / 2].max(1.0)
    );

    let co = pc.layer(1.min(cfg.n_layers - 1));
    let mut cells: Vec<f64> = Vec::new();
    for i in 0..cfg.n_experts {
        for j in (i + 1)..cfg.n_experts {
            cells.push(co.m(i, j));
        }
    }
    let tot: f64 = cells.iter().sum();
    cells.sort_by(|a, b| b.total_cmp(a));
    let top5pct: f64 = cells.iter().take(cells.len() / 20).sum();
    let mut same_fam_mass = 0.0;
    for i in 0..cfg.n_experts {
        for j in (i + 1)..cfg.n_experts {
            if i / fs == j / fs {
                same_fam_mass += co.m(i, j);
            }
        }
    }
    println!("\n# Figure 7/9 — co-activation heatmap (layer 1)\n");
    println!(
        "top 5% of expert pairs hold {:.1}% of co-activation mass (sparse bright cells); \
         same-family pairs ({:.1}% of pairs) hold {:.1}% of mass",
        100.0 * top5pct / tot,
        100.0 * (cfg.n_experts * (fs - 1) / 2) as f64
            / (cfg.n_experts * (cfg.n_experts - 1) / 2) as f64,
        100.0 * same_fam_mass / tot
    );
    println!("\nraw matrices: `buddymoe figures --out artifacts/figures` dumps JSON for plotting.");
}
