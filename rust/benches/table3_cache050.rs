//! Table 3 — performance at cache rate c = 0.50.
//!
//! Paper: Random collapses to 0.23 acc; BuddyMoE(tau=0.99,|B|=2) holds
//! 0.53 with modest throughput; Buddy(rho=3) best avg 0.635 at 30.21 t/s.

mod bench_support;

use buddymoe::eval::{run_table, MethodSpec, TableSettings};

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let fast = bench_support::fast_mode();
    let settings = TableSettings {
        cache_rate: 0.50,
        n_easy: if fast { 3 } else { 8 },
        n_hard: if fast { 3 } else { 8 },
        max_new: if fast { 8 } else { 16 },
        seed: 42,
        clock: bench_support::clock_mode(),
    };
    // Table 3 adds the strict (tau=0.99, |B|=2) row.
    let methods = vec![
        MethodSpec::new("Original (on-demand)", "original"),
        MethodSpec::new("Random", "random"),
        MethodSpec::new("BuddyMoE t=0.99 |B|=2", "buddy-strict"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16", "buddy-wide"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=3", "buddy-rho3"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=4", "buddy-rho4"),
    ];
    let (_rows, md) = run_table(&cfg, store, &settings, &methods).expect("table 3");
    println!("# Table 3 — {md}");
    println!("paper reference: Random 0.23/33.14 (unusable), Buddy(strict) 0.53/28.95, Buddy(rho3) 0.635/30.21");
}
