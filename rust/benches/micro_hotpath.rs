//! Micro-benchmarks of the L3 hot path: the paper claims the substitution
//! logic adds negligible latency next to expert compute. Quantify every
//! piece: top-k, TAE gate, Algorithm 1, cache ops, host router (PreGate),
//! one expert FFN invocation, the raw kernels (naive vs blocked), and a
//! full decode step through the reference backend across kernel modes and
//! thread counts.
//!
//! Runs with or without artifacts (synthetic fallback), so CI can execute
//! it in `--fast` mode. Emits machine-readable `BENCH_hotpath.json` next
//! to Cargo.toml — the perf trajectory artifact uploaded by CI.

mod bench_support;

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use buddymoe::buddy::{BuddyProfile, GateParams, SubstitutionEngine, TokenRouting};
use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::prefetch::host_router_probs;
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::runtime::{kernels, BackendKind};
use buddymoe::stats::Counters;
use buddymoe::trace::TraceSink;
use buddymoe::util::clock::ClockMode;
use buddymoe::util::json::{num, s, Json};
use buddymoe::util::math::{tae, top_k};
use buddymoe::util::par;
use buddymoe::util::rng::Rng;
use buddymoe::weights::WeightStore;

/// Counting wrapper around the system allocator: lets the benchmark
/// assert a hot path is allocation-free (the `counters_add_hot_allocs`
/// row) instead of inferring it from timing noise.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    let mut json = BTreeMap::new();
    // Runs first, before any worker threads exist, so the allocation count
    // is attributable to the measured loop alone.
    counters_alloc_bench(&mut json);

    let (cfg, store) = bench_support::load_model_or_synthetic();
    let iters = if bench_support::fast_mode() { 200 } else { 2000 };
    let mut rng = Rng::new(3);

    println!("# Micro hot-path latencies (per call)\n");
    println!("| op | mean | p95 |");
    println!("|---|---|---|");

    // top-k over the router width
    let probs: Vec<f32> = (0..cfg.n_experts).map(|_| rng.f32()).collect();
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = top_k(&probs, cfg.top_k);
    });
    println!(
        "| top-k (E={}, k={}) | {:.2} us | {:.2} us |",
        cfg.n_experts,
        cfg.top_k,
        m * 1e6,
        p * 1e6
    );

    // TAE gate
    let w = [0.3f32, 0.2, 0.18, 0.14, 0.1, 0.08];
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = tae(&w);
    });
    println!("| TAE (k=6) | {:.3} us | {:.3} us |", m * 1e6, p * 1e6);

    // Algorithm 1 over a full decode batch (8 tokens x top-k)
    let mut pc = ProfileCollector::new(cfg.n_layers, cfg.n_experts);
    for _ in 0..4000 {
        let fam = rng.below(cfg.n_experts / cfg.family_size);
        let a = fam * cfg.family_size + rng.below(cfg.family_size);
        let b = fam * cfg.family_size + rng.below(cfg.family_size);
        if a != b {
            pc.record(0, &[a, b], &[0.6, 0.4]).unwrap();
        }
    }
    let profile = BuddyProfile::build(&pc, &vec![0.9; cfg.n_layers], 16, 1e-3, true).unwrap();
    let mut eng = SubstitutionEngine::new(&profile);
    eng.gates = GateParams { tau: 0.2, beta: 1.0, margin_gamma: None, temperature: None };
    let residency: Vec<bool> = (0..cfg.n_experts).map(|e| e % 2 == 0).collect();
    let mut counters = Counters::new();
    let top_k_w = vec![1.0 / cfg.top_k as f32; cfg.top_k];
    let mk_batch = |rng: &mut Rng| -> Vec<TokenRouting> {
        (0..8)
            .map(|_| {
                let mut sel = Vec::new();
                while sel.len() < cfg.top_k {
                    let e = rng.below(cfg.n_experts);
                    if !sel.contains(&e) {
                        sel.push(e);
                    }
                }
                TokenRouting { selected: sel, weights: top_k_w.clone() }
            })
            .collect()
    };
    let mut rng2 = Rng::new(5);
    let (m, p) = bench_support::time_it(50, iters, || {
        let mut batch = mk_batch(&mut rng2);
        let _ = eng.apply(
            0,
            &mut batch,
            &residency,
            MissPolicy::Buddy,
            None,
            &mut counters,
            &mut rng2,
        );
    });
    println!(
        "| Algorithm 1 (batch of 8 x top-{}, ~50% miss) | {:.2} us | {:.2} us |",
        cfg.top_k,
        m * 1e6,
        p * 1e6
    );

    // Host router (PreGate predictor math)
    let x: Vec<f32> = (0..cfg.d_model).map(|_| rng.f32() - 0.5).collect();
    let ln2 = store.tensor("L0.ln2").unwrap().data.clone();
    let wg = store.tensor("L0.wg").unwrap().clone();
    let rbias = store.tensor("L0.rbias").unwrap().data.clone();
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = host_router_probs(&x, cfg.d_model, &ln2, &wg, &rbias, 1e-5);
    });
    println!("| host router probs (PreGate, 1 token) | {:.2} us | {:.2} us |", m * 1e6, p * 1e6);

    // One expert FFN through the stage backend (T=8) — the compute
    // substitution enables. PJRT when compiled in; reference otherwise.
    expert_ffn_bench(&cfg, &store, iters);

    // PCIe transfer for contrast (simulated link model).
    let scfg = ServingConfig::default();
    println!(
        "| PCIe expert transfer (simulated) | {:.0} us | — |",
        scfg.transfer_seconds(store.expert_bytes) * 1e6
    );
    println!(
        "\nclaim check: substitution (~us) is negligible vs the ~{:.1} ms transfer it avoids.",
        scfg.transfer_seconds(store.expert_bytes) * 1e3
    );
    let _ = Arc::strong_count(&store);

    // ------------------------------------------------------------------
    // Raw kernels + full decode step: naive vs blocked, 1..4 threads.
    // ------------------------------------------------------------------
    kernel_bench(iters, &mut json);
    decode_step_bench(&mut json);
    long_context_bench(&mut json);
    tracing_overhead_bench(&mut json);

    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    std::fs::write(&path, Json::Obj(json).to_string() + "\n").expect("write BENCH_hotpath.json");
    println!("\nwrote {}", path.display());
}

/// `Counters::add` on warm keys must not allocate: the counting allocator
/// observes a tight single-threaded loop of adds against already-present
/// keys and reports the exact allocation count (`counters_add_hot_allocs`,
/// expected 0 — CI grep-asserts the row).
fn counters_alloc_bench(json: &mut BTreeMap<String, Json>) {
    let keys = [
        "substitutions",
        "fetches",
        "peer_hops",
        "replica_hits",
        "retried_fetches",
        "waterfall_drops",
    ];
    let mut c = Counters::new();
    // Warm-up: the first touch of each key allocates its String once.
    for k in &keys {
        c.add(k, 1);
    }
    let iters = 10_000u64;
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..iters {
        c.add(keys[(i % keys.len() as u64) as usize], 1);
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    println!("# Counters hot path\n");
    println!("counters.add on warm keys: {allocs} allocations across {iters} adds\n");
    json.insert("counters_add_hot_allocs".into(), num(allocs as f64));
    json.insert("counters_add_hot_iters".into(), num(iters as f64));
}

/// Decode step with the trace ring sink on vs. off (same model, same
/// workload, blocked kernels, one thread): the `tracing_overhead_ratio`
/// row quantifies the cost of full instrumentation, and the untraced row
/// doubles as evidence the disabled tracer stays off the hot path.
fn tracing_overhead_bench(json: &mut BTreeMap<String, Json>) {
    let cfg = perf_cfg();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    let batch = 8usize;
    let warmup = 3usize;
    let iters = if bench_support::fast_mode() { 12 } else { 40 };
    par::set_threads(1);

    println!("\n# Tracing overhead: decode step, ring sink on vs off\n");
    println!("| sink | mean | p95 |");
    println!("|---|---|---|");

    let mut means = Vec::new();
    for (label, sink) in [("untraced", TraceSink::Off), ("traced", TraceSink::Ring)] {
        let scfg = ServingConfig {
            cache_rate: 1.0,
            miss_policy: MissPolicy::OnDemand,
            prefetch: PrefetchKind::None,
            trace: sink,
            ..Default::default()
        };
        let opts = EngineOptions {
            clock: ClockMode::Virtual,
            backend: BackendKind::Reference,
            ..Default::default()
        };
        let mut engine =
            Engine::new(cfg.clone(), scfg, store.clone(), None, None, opts).unwrap();
        let mut seqs: Vec<_> = (0..batch)
            .map(|i| engine.new_sequence(vec![3 + i as i32, 9, 17, 4, 2, 11], iters + warmup))
            .collect();
        for sq in seqs.iter_mut() {
            engine.prefill(sq).unwrap();
        }
        let (mean, p95) = bench_support::time_it(warmup, iters, || {
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.decode_step(&mut refs).unwrap();
        });
        println!("| {label} | {:.3} ms | {:.3} ms |", mean * 1e3, p95 * 1e3);
        json.insert(format!("decode_step_{label}_mean_s"), num(mean));
        json.insert(format!("decode_step_{label}_p95_s"), num(p95));
        means.push(mean);
        engine.shutdown();
    }
    par::set_threads(0);
    let ratio = means[1] / means[0].max(1e-12);
    json.insert("tracing_overhead_ratio".into(), num(ratio));
    println!("\ntracing overhead: {ratio:.3}x traced vs untraced");
}

/// A synthetic model sized so kernels, not fixed overheads, dominate the
/// decode step (the artifact/test models are deliberately tiny).
fn perf_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::synthetic_small();
    cfg.name = "bench-hotpath".into();
    cfg.vocab_size = 2048;
    cfg.d_model = 128;
    cfg.n_heads = 4;
    cfg.head_dim = 32;
    cfg.n_layers = 4;
    cfg.n_experts = 16;
    cfg.top_k = 4;
    cfg.d_ff = 256;
    cfg.max_seq = 64;
    cfg.token_buckets = vec![1, 2, 4, 8, 16, 32, 64];
    cfg.batch_buckets = vec![1, 2, 4, 8];
    cfg.family_size = 4;
    cfg
}

/// Naive vs blocked kernels at decode-relevant shapes (single thread, so
/// the delta is pure blocking/layout, no parallelism).
fn kernel_bench(iters: usize, json: &mut BTreeMap<String, Json>) {
    use buddymoe::runtime::kernels::naive;

    let mut rng = Rng::new(17);
    let iters = iters.min(500);
    par::set_threads(1);

    println!("\n# Kernels: naive vs blocked (single thread)\n");
    println!("| kernel | shape | naive mean | blocked mean | speedup |");
    println!("|---|---|---|---|---|");

    // Expert-FFN-shaped matmul: [8, 128] @ [128, 256].
    let (mm, k, n) = (8usize, 128usize, 256usize);
    let a: Vec<f32> = (0..mm * k).map(|_| rng.f32() - 0.5).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
    let (nm, _) = bench_support::time_it(20, iters, || {
        let _ = naive::matmul(&a, mm, k, &b, n);
    });
    let (bm, _) = bench_support::time_it(20, iters, || {
        let _ = kernels::matmul(&a, mm, k, &b, n);
    });
    println!(
        "| matmul | [{mm},{k}]@[{k},{n}] | {:.2} us | {:.2} us | {:.2}x |",
        nm * 1e6,
        bm * 1e6,
        nm / bm.max(1e-12)
    );
    json.insert("matmul_naive_s".into(), num(nm));
    json.insert("matmul_blocked_s".into(), num(bm));

    // lm-head-shaped transposed matmul: [8, 128] @ [2048, 128]^T.
    let v = 2048usize;
    let bt: Vec<f32> = (0..v * k).map(|_| rng.f32() - 0.5).collect();
    let (nm, _) = bench_support::time_it(10, iters.min(200), || {
        let _ = naive::matmul_bt(&a, mm, k, &bt, v);
    });
    let (bm, _) = bench_support::time_it(10, iters.min(200), || {
        let _ = kernels::matmul_bt(&a, mm, k, &bt, v);
    });
    println!(
        "| matmul_bt | [{mm},{k}]@[{v},{k}]^T | {:.2} us | {:.2} us | {:.2}x |",
        nm * 1e6,
        bm * 1e6,
        nm / bm.max(1e-12)
    );
    json.insert("matmul_bt_naive_s".into(), num(nm));
    json.insert("matmul_bt_blocked_s".into(), num(bm));
    par::set_threads(0);
}

/// Full decode step (embed → attention → router → experts → lm head) on
/// the reference backend: naive baseline vs blocked kernels at 1/2/4
/// threads. The ≥4x acceptance number is `speedup_best_vs_naive`.
fn decode_step_bench(json: &mut BTreeMap<String, Json>) {
    let cfg = perf_cfg();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    let batch = 8usize;
    // Stay within the KV budget: warmup + iters decode steps per engine.
    let warmup = 3usize;
    let iters = if bench_support::fast_mode() { 12 } else { 40 };

    println!(
        "\n# Decode step, reference backend (d={}, ff={}, V={}, L={}, batch={batch})\n",
        cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    );
    println!("| kernels | threads | mean | p95 |");
    println!("|---|---|---|---|");

    let mut results: Vec<(String, f64)> = Vec::new();
    for (mode_name, naive) in [("naive", true), ("blocked", false)] {
        if naive {
            std::env::set_var("PALLAS_NAIVE", "1");
        } else {
            std::env::remove_var("PALLAS_NAIVE");
        }
        for threads in [1usize, 2, 4] {
            if naive && threads > 1 {
                continue; // the baseline is the old single-core path
            }
            par::set_threads(threads);
            let scfg = ServingConfig {
                cache_rate: 1.0,
                miss_policy: MissPolicy::OnDemand,
                prefetch: PrefetchKind::None,
                ..Default::default()
            };
            let opts = EngineOptions {
                clock: ClockMode::Virtual,
                backend: BackendKind::Reference,
                ..Default::default()
            };
            let mut engine =
                Engine::new(cfg.clone(), scfg, store.clone(), None, None, opts).unwrap();
            let mut seqs: Vec<_> = (0..batch)
                .map(|i| engine.new_sequence(vec![3 + i as i32, 9, 17, 4, 2, 11], iters + warmup))
                .collect();
            for sq in seqs.iter_mut() {
                engine.prefill(sq).unwrap();
            }
            let (mean, p95) = bench_support::time_it(warmup, iters, || {
                let mut batch_refs: Vec<&mut _> = seqs.iter_mut().collect();
                engine.decode_step(&mut batch_refs).unwrap();
            });
            println!(
                "| {mode_name} | {threads} | {:.3} ms | {:.3} ms |",
                mean * 1e3,
                p95 * 1e3
            );
            let label = format!("{mode_name}_t{threads}");
            json.insert(format!("decode_step_mean_s_{label}"), num(mean));
            json.insert(format!("decode_step_p95_s_{label}"), num(p95));
            results.push((label, mean));
            engine.shutdown();
        }
    }
    par::set_threads(0);
    std::env::remove_var("PALLAS_NAIVE");

    json.insert("bench".into(), s("micro_hotpath"));
    json.insert("d_model".into(), num(cfg.d_model as f64));
    json.insert("d_ff".into(), num(cfg.d_ff as f64));
    json.insert("vocab_size".into(), num(cfg.vocab_size as f64));
    json.insert("n_layers".into(), num(cfg.n_layers as f64));
    json.insert("batch".into(), num(batch as f64));

    let naive1 = results.iter().find(|r| r.0 == "naive_t1").map(|r| r.1);
    let blocked1 = results.iter().find(|r| r.0 == "blocked_t1").map(|r| r.1);
    let best = results
        .iter()
        .filter(|r| r.0.starts_with("blocked"))
        .map(|r| r.1)
        .fold(f64::INFINITY, f64::min);
    if let (Some(n1), Some(b1)) = (naive1, blocked1) {
        let s1 = n1 / b1.max(1e-12);
        let sb = n1 / best.max(1e-12);
        json.insert("speedup_blocked1_vs_naive1".into(), num(s1));
        json.insert("speedup_best_vs_naive".into(), num(sb));
        println!("\nspeedup: blocked@1T = {s1:.2}x, best blocked = {sb:.2}x vs naive@1T");
    }
}

/// Long-context decode steps (PR 5): sequences filled near `max_seq`, the
/// regime where the killed per-layer `[bb, s, d]` KV assembly dominated
/// the step. Emits view-path rows plus the measured copy-path cost — the
/// view step time plus the per-layer materialization the seed engine
/// performed every step (`runtime::materialize_kv` reproduces its exact
/// copy volume) — into `BENCH_hotpath.json`. CI fails if the view rows
/// are missing from the artifact.
fn long_context_bench(json: &mut BTreeMap<String, Json>) {
    use buddymoe::runtime::{materialize_kv, KvSlices};
    use buddymoe::util::tensor::Tensor;

    let mut cfg = ModelConfig::synthetic_small();
    cfg.name = "bench-longctx".into();
    cfg.vocab_size = 512;
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.head_dim = 16;
    cfg.n_layers = 2;
    cfg.n_experts = 8;
    cfg.top_k = 2;
    cfg.d_ff = 128;
    cfg.max_seq = 512;
    cfg.token_buckets = vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    cfg.batch_buckets = vec![1, 2, 4, 8];
    cfg.family_size = 4;
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 77));
    let warmup = 2usize;
    let iters = if bench_support::fast_mode() { 8 } else { 30 };

    println!(
        "\n# Long-context decode step (S={}, d={}, L={}): view vs copy path\n",
        cfg.max_seq, cfg.d_model, cfg.n_layers
    );
    println!("| batch | ctx | view mean | kv assembly (seed copy) | copy-path mean | speedup |");
    println!("|---|---|---|---|---|---|");

    for &batch in &[1usize, 4] {
        let scfg = ServingConfig {
            cache_rate: 1.0,
            miss_policy: MissPolicy::OnDemand,
            prefetch: PrefetchKind::None,
            ..Default::default()
        };
        let opts = EngineOptions {
            clock: ClockMode::Virtual,
            backend: BackendKind::Reference,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg.clone(), scfg, store.clone(), None, None, opts).unwrap();
        // Fill the context near max_seq, leaving exactly enough headroom
        // for the measured steps.
        let budget = warmup + iters;
        let plen = cfg.max_seq - budget - 1;
        let mut seqs: Vec<_> = (0..batch)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..plen).map(|t| ((t * 7 + i * 13) % cfg.vocab_size) as i32).collect();
                engine.new_sequence(prompt, budget)
            })
            .collect();
        for sq in seqs.iter_mut() {
            engine.prefill(sq).unwrap();
        }
        let (view_mean, view_p95) = bench_support::time_it(warmup, iters, || {
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.decode_step(&mut refs).unwrap();
        });
        // The copy the view killed: per layer, assemble contiguous
        // [bb, s, d] K and V from the same sequences (the seed's exact
        // per-step copy volume and layout).
        let bb = cfg.batch_bucket_for(batch).unwrap();
        let (assembly_mean, _) = bench_support::time_it(2, iters, || {
            for l in 0..cfg.n_layers {
                let kr: Vec<&Tensor> = seqs.iter().map(|sq| &sq.kv_k[l]).collect();
                let vr: Vec<&Tensor> = seqs.iter().map(|sq| &sq.kv_v[l]).collect();
                let kv = KvSlices { k: &kr, v: &vr };
                let _ = materialize_kv(&kv, bb, cfg.max_seq, cfg.d_model).unwrap();
            }
        });
        let copy_mean = view_mean + assembly_mean;
        let speedup = copy_mean / view_mean.max(1e-12);
        println!(
            "| {batch} | {plen} | {:.3} ms | {:.3} ms | {:.3} ms | {speedup:.2}x |",
            view_mean * 1e3,
            assembly_mean * 1e3,
            copy_mean * 1e3
        );
        json.insert(format!("decode_step_long_view_mean_s_b{batch}"), num(view_mean));
        json.insert(format!("decode_step_long_view_p95_s_b{batch}"), num(view_p95));
        json.insert(format!("decode_step_long_kv_assembly_mean_s_b{batch}"), num(assembly_mean));
        json.insert(format!("decode_step_long_copy_mean_s_b{batch}"), num(copy_mean));
        json.insert(format!("speedup_long_view_vs_copy_b{batch}"), num(speedup));
        engine.shutdown();
    }
    json.insert("long_ctx_seq".into(), num(cfg.max_seq as f64));
    json.insert("long_ctx_d_model".into(), num(cfg.d_model as f64));
    json.insert("long_ctx_n_layers".into(), num(cfg.n_layers as f64));
}

#[cfg(feature = "pjrt")]
fn expert_ffn_bench(
    cfg: &buddymoe::config::ModelConfig,
    store: &Arc<buddymoe::weights::WeightStore>,
    iters: usize,
) {
    use buddymoe::runtime::Runtime;
    use buddymoe::util::tensor::Tensor;
    use buddymoe::weights::ExpertKey;

    if cfg.artifacts.is_empty() {
        eprintln!("SKIP expert FFN via PJRT: no artifacts");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut reg = rt.load_artifacts(cfg).unwrap();
    let key = ExpertKey::new(0, 0);
    let ew = store.expert(key).unwrap();
    reg.admit_expert(&rt, key, &ew).unwrap();
    let h = Tensor::new(
        vec![8, cfg.d_model],
        (0..8 * cfg.d_model).map(|i| ((i % 13) as f32) / 13.0 - 0.5).collect(),
    )
    .unwrap();
    let (m, p) = bench_support::time_it(20, iters.min(500), || {
        let hbuf = rt.to_device(&h.data, &h.dims).unwrap();
        let bufs = reg.expert_buffers(key).unwrap();
        let _ = reg
            .run_buffers("expert_T8", &[&hbuf, &bufs[0], &bufs[1], &bufs[2]])
            .unwrap();
    });
    println!("| expert FFN via PJRT (T=8) | {:.2} us | {:.2} us |", m * 1e6, p * 1e6);
}

#[cfg(not(feature = "pjrt"))]
fn expert_ffn_bench(
    cfg: &buddymoe::config::ModelConfig,
    store: &Arc<buddymoe::weights::WeightStore>,
    iters: usize,
) {
    use buddymoe::runtime::{RefStages, StageRunner};
    use buddymoe::util::tensor::{Tensor, TensorView};
    use buddymoe::weights::ExpertKey;

    let mut stages = RefStages::new(cfg.clone(), store.clone());
    let key = ExpertKey::new(0, 0);
    let ew = store.expert(key).unwrap();
    stages.admit_expert(key, &ew).unwrap();
    let h = Tensor::new(
        vec![8, cfg.d_model],
        (0..8 * cfg.d_model).map(|i| ((i % 13) as f32) / 13.0 - 0.5).collect(),
    )
    .unwrap();
    let hv = TensorView::from_tensor(&h);
    let (m, p) = bench_support::time_it(20, iters.min(500), || {
        let _ = stages.expert_resident(8, key, &hv).unwrap();
    });
    println!(
        "| expert FFN via reference backend (T=8) | {:.2} us | {:.2} us |",
        m * 1e6,
        p * 1e6
    );
}
