//! Micro-benchmarks of the L3 hot path: the paper claims the substitution
//! logic adds negligible latency next to expert compute. Quantify every
//! piece: top-k, TAE gate, Algorithm 1, cache ops, host router (PreGate),
//! and one expert FFN invocation through PJRT for scale.

mod bench_support;

use std::sync::Arc;

use buddymoe::buddy::{BuddyProfile, GateParams, SubstitutionEngine, TokenRouting};
use buddymoe::config::{MissPolicy, ServingConfig};
use buddymoe::prefetch::host_router_probs;
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::stats::Counters;
use buddymoe::util::math::{tae, top_k};
use buddymoe::util::rng::Rng;

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let iters = if bench_support::fast_mode() { 200 } else { 2000 };
    let mut rng = Rng::new(3);

    println!("# Micro hot-path latencies (per call)\n");
    println!("| op | mean | p95 |");
    println!("|---|---|---|");

    // top-k over 64 experts
    let probs: Vec<f32> = (0..cfg.n_experts).map(|_| rng.f32()).collect();
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = top_k(&probs, cfg.top_k);
    });
    println!("| top-k (E=64, k=6) | {:.2} us | {:.2} us |", m * 1e6, p * 1e6);

    // TAE gate
    let w = [0.3f32, 0.2, 0.18, 0.14, 0.1, 0.08];
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = tae(&w);
    });
    println!("| TAE (k=6) | {:.3} us | {:.3} us |", m * 1e6, p * 1e6);

    // Algorithm 1 over a full decode batch (8 tokens x top-6)
    let mut pc = ProfileCollector::new(cfg.n_layers, cfg.n_experts);
    for _ in 0..4000 {
        let fam = rng.below(cfg.n_experts / cfg.family_size);
        let a = fam * cfg.family_size + rng.below(cfg.family_size);
        let b = fam * cfg.family_size + rng.below(cfg.family_size);
        if a != b {
            pc.record(0, &[a, b], &[0.6, 0.4]).unwrap();
        }
    }
    let profile = BuddyProfile::build(&pc, &vec![0.9; cfg.n_layers], 16, 1e-3, true).unwrap();
    let mut eng = SubstitutionEngine::new(&profile);
    eng.gates = GateParams { tau: 0.2, beta: 1.0, margin_gamma: None, temperature: None };
    let residency: Vec<bool> = (0..cfg.n_experts).map(|e| e % 2 == 0).collect();
    let mut counters = Counters::new();
    let mk_batch = |rng: &mut Rng| -> Vec<TokenRouting> {
        (0..8)
            .map(|_| {
                let mut sel = Vec::new();
                while sel.len() < cfg.top_k {
                    let e = rng.below(cfg.n_experts);
                    if !sel.contains(&e) {
                        sel.push(e);
                    }
                }
                TokenRouting { selected: sel, weights: vec![1.0 / 6.0; 6] }
            })
            .collect()
    };
    let mut rng2 = Rng::new(5);
    let (m, p) = bench_support::time_it(50, iters, || {
        let mut batch = mk_batch(&mut rng2);
        let _ = eng.apply(
            0,
            &mut batch,
            &residency,
            MissPolicy::Buddy,
            None,
            &mut counters,
            &mut rng2,
        );
    });
    println!(
        "| Algorithm 1 (batch of 8 x top-6, ~50% miss) | {:.2} us | {:.2} us |",
        m * 1e6,
        p * 1e6
    );

    // Host router (PreGate predictor math)
    let x: Vec<f32> = (0..cfg.d_model).map(|_| rng.f32() - 0.5).collect();
    let ln2 = store.tensor("L0.ln2").unwrap().data.clone();
    let wg = store.tensor("L0.wg").unwrap().clone();
    let rbias = store.tensor("L0.rbias").unwrap().data.clone();
    let (m, p) = bench_support::time_it(100, iters, || {
        let _ = host_router_probs(&x, cfg.d_model, &ln2, &wg, &rbias, 1e-5);
    });
    println!("| host router probs (PreGate, 1 token) | {:.2} us | {:.2} us |", m * 1e6, p * 1e6);

    // One expert FFN through the stage backend (T=8) — the compute
    // substitution enables. PJRT when compiled in; reference otherwise.
    expert_ffn_bench(&cfg, &store, iters);

    // PCIe transfer for contrast (simulated link model).
    let scfg = ServingConfig::default();
    println!(
        "| PCIe expert transfer (simulated) | {:.0} us | — |",
        scfg.transfer_seconds(store.expert_bytes) * 1e6
    );
    println!(
        "\nclaim check: substitution (~us) is negligible vs the ~{:.1} ms transfer it avoids.",
        scfg.transfer_seconds(store.expert_bytes) * 1e3
    );
    let _ = Arc::strong_count(&store);
}

#[cfg(feature = "pjrt")]
fn expert_ffn_bench(
    cfg: &buddymoe::config::ModelConfig,
    store: &Arc<buddymoe::weights::WeightStore>,
    iters: usize,
) {
    use buddymoe::runtime::Runtime;
    use buddymoe::util::tensor::Tensor;
    use buddymoe::weights::ExpertKey;

    let rt = Runtime::cpu().unwrap();
    let mut reg = rt.load_artifacts(cfg).unwrap();
    let key = ExpertKey::new(0, 0);
    let ew = store.expert(key).unwrap();
    reg.admit_expert(&rt, key, &ew).unwrap();
    let h = Tensor::new(
        vec![8, cfg.d_model],
        (0..8 * cfg.d_model).map(|i| ((i % 13) as f32) / 13.0 - 0.5).collect(),
    )
    .unwrap();
    let (m, p) = bench_support::time_it(20, iters.min(500), || {
        let hbuf = rt.to_device(&h.data, &h.dims).unwrap();
        let bufs = reg.expert_buffers(key).unwrap();
        let _ = reg
            .run_buffers("expert_T8", &[&hbuf, &bufs[0], &bufs[1], &bufs[2]])
            .unwrap();
    });
    println!("| expert FFN via PJRT (T=8) | {:.2} us | {:.2} us |", m * 1e6, p * 1e6);
}

#[cfg(not(feature = "pjrt"))]
fn expert_ffn_bench(
    cfg: &buddymoe::config::ModelConfig,
    store: &Arc<buddymoe::weights::WeightStore>,
    iters: usize,
) {
    use buddymoe::runtime::{RefStages, StageRunner};
    use buddymoe::util::tensor::Tensor;
    use buddymoe::weights::ExpertKey;

    let mut stages = RefStages::new(cfg.clone(), store.clone());
    let key = ExpertKey::new(0, 0);
    let ew = store.expert(key).unwrap();
    stages.admit_expert(key, &ew).unwrap();
    let h = Tensor::new(
        vec![8, cfg.d_model],
        (0..8 * cfg.d_model).map(|i| ((i % 13) as f32) / 13.0 - 0.5).collect(),
    )
    .unwrap();
    let (m, p) = bench_support::time_it(20, iters.min(500), || {
        let _ = stages.expert_resident(8, key, &h).unwrap();
    });
    println!(
        "| expert FFN via reference backend (T=8) | {:.2} us | {:.2} us |",
        m * 1e6,
        p * 1e6
    );
}
