//! Table 2 — performance at cache rate c = 0.75.
//!
//! Paper (DeepSeek-V2-Lite on llama.cpp + A100): Original 0.735 acc /
//! 34.23 t/s; Random 0.55 / 39.67; best BuddyMoE (tau=0.95, |B|=16, rho=3)
//! 0.695 / 36.75. Expected *shape* here (absolute t/s differs — CPU PJRT
//! testbed): accuracy Original > Buddy(rho=3) > Buddy > Random; throughput
//! Random > Buddy > Original.

mod bench_support;

use buddymoe::eval::{run_table, table_methods, TableSettings};

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let fast = bench_support::fast_mode();
    let settings = TableSettings {
        cache_rate: 0.75,
        n_easy: if fast { 3 } else { 8 },
        n_hard: if fast { 3 } else { 8 },
        max_new: if fast { 8 } else { 16 },
        seed: 42,
        clock: bench_support::clock_mode(),
    };
    let (_rows, md) = run_table(&cfg, store, &settings, &table_methods()).expect("table 2");
    println!("# Table 2 — {md}");
    println!("paper reference: Original 0.735/34.23, Random 0.55/39.67, Buddy(rho3) 0.695/36.75");
}
