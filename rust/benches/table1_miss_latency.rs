//! Table 1 — Impact of cache misses and BuddyMoE on MoE inference.
//!
//! Paper rows:
//!   Baseline (on demand)   9-10 ms   lossless
//!   Prefetch hit           ~0        lossless
//!   Prefetch miss          9-10 ms   lossless
//!   BuddyMoE hit           ~0        lossless
//!   BuddyMoE miss          ~0        minimal loss
//!
//! We measure each scenario directly against the PCIe simulator + the
//! substitution engine: the "latency" column is the time the serving
//! thread is stalled for one missing expert, measured on the transfer
//! engine's clock (virtual by default, wall time with `--real-time`).

mod bench_support;



use buddymoe::buddy::{BuddyProfile, SubstitutionEngine, TokenRouting};
use buddymoe::config::{MissPolicy, ServingConfig};
use buddymoe::memory::{EvictPolicy, ExpertCache, PcieSim, TransferEngine, TransferPriority};
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::stats::Counters;
use buddymoe::util::rng::Rng;
use buddymoe::weights::ExpertKey;

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let scfg = ServingConfig::default();
    let iters = if bench_support::fast_mode() { 5 } else { 20 };

    // A deterministic profile with clear buddy structure for the miss rows.
    let mut pc = ProfileCollector::new(cfg.n_layers, cfg.n_experts);
    let mut rng = Rng::new(7);
    for _ in 0..2000 {
        let fam = rng.below(cfg.n_experts / cfg.family_size);
        let a = fam * cfg.family_size + rng.below(cfg.family_size);
        let b = fam * cfg.family_size + rng.below(cfg.family_size);
        if a != b {
            pc.record(0, &[a, b], &[0.6, 0.4]).unwrap();
        }
    }
    let profile = BuddyProfile::build(&pc, &vec![0.9; cfg.n_layers], 16, 1e-3, true).unwrap();

    // Latencies are measured on the transfer engine's clock: virtual by
    // default (instant, deterministic), real with `--real-time`.
    let spawn = |cap: usize| {
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, cap, EvictPolicy::Lru);
        let pcie = PcieSim::new(scfg.pcie_bandwidth, scfg.pcie_base_latency, scfg.transfer_bytes_scale);
        let clock = buddymoe::util::clock::SimClock::new(bench_support::clock_mode());
        (
            TransferEngine::spawn(cache, pcie, store.clone(), clock.clone()),
            clock,
        )
    };

    println!("# Table 1 — miss-handling latency per missing expert\n");
    println!("| Scenario | Latency (ms) | Accuracy |");
    println!("|---|---|---|");

    // --- Baseline (on demand): synchronous PCIe fetch -------------------
    {
        let (h, clock) = spawn(cfg.n_experts);
        let mut lat = Vec::new();
        for i in 0..iters {
            let key = ExpertKey::new(0, i % cfg.n_experts);
            let t0 = clock.now();
            h.request(key, TransferPriority::Demand);
            let _ = h.wait_gpu(key);
            lat.push(clock.since(t0) * 1e3);
            // Demote everything again so the next iteration misses even
            // when iters wraps past n_experts.
            h.with_state(|st| {
                for e in 0..cfg.n_experts {
                    st.demote(ExpertKey::new(0, e));
                }
            });
            let _ = h.drain_arrivals();
        }
        let mean = lat.iter().sum::<f64>() / lat.len() as f64;
        println!("| Baseline (on demand) | {mean:.2} | lossless |");
        h.shutdown();
    }

    // --- Prefetch hit: expert already resident when needed --------------
    {
        let (h, _clock) = spawn(cfg.n_experts);
        let key = ExpertKey::new(0, 3);
        h.request(key, TransferPriority::Prefetch);
        let _ = h.wait_gpu(key);
        let (mean, _) = bench_support::time_it(3, iters, || {
            assert!(h.with_state(|st| st.is_gpu(key)));
        });
        println!("| Prefetch hit | {:.4} | lossless |", mean * 1e3);
        h.shutdown();
    }

    // --- Prefetch miss: mispredicted; pay a full synchronous fetch ------
    {
        let (h, clock) = spawn(cfg.n_experts);
        let mut lat = Vec::new();
        for i in 0..iters {
            // Prefetcher warmed the WRONG expert (transfer already done by
            // verification time); the needed one misses and pays a full
            // synchronous load.
            let wrong = ExpertKey::new(1, (2 * i) % cfg.n_experts);
            let needed = ExpertKey::new(1, (2 * i + 1) % cfg.n_experts);
            h.request(wrong, TransferPriority::Prefetch);
            let _ = h.wait_gpu(wrong);
            let t0 = clock.now();
            h.request(needed, TransferPriority::Demand);
            let _ = h.wait_gpu(needed);
            lat.push(clock.since(t0) * 1e3);
            h.with_state(|st| {
                for e in 0..cfg.n_experts {
                    st.demote(ExpertKey::new(1, e));
                }
            });
            let _ = h.drain_arrivals();
        }
        let mean = lat.iter().sum::<f64>() / lat.len() as f64;
        println!("| Prefetch miss | {mean:.2} | lossless |");
        h.shutdown();
    }

    // --- BuddyMoE hit: same as prefetch hit (no intervention) -----------
    println!("| BuddyMoE hit | ~0 (= prefetch hit) | lossless |");

    // --- BuddyMoE miss: substitution instead of a fetch -----------------
    {
        // Residency: every second expert resident, so each missing expert
        // has same-family buddies on the GPU.
        let mut residency = vec![false; cfg.n_experts];
        for (e, r) in residency.iter_mut().enumerate() {
            *r = e % 2 == 0;
        }
        let mut eng = SubstitutionEngine::new(&profile);
        eng.gates.tau = 0.2;
        eng.gates.beta = 1.0;
        let mut counters = Counters::new();
        let mut rng = Rng::new(11);
        let (mean, p95) = bench_support::time_it(10, iters.max(100), || {
            // Two resident (2, 40) + four missing experts: the batch-level
            // CPU fraction stays below beta while the misses substitute.
            let mut toks = vec![TokenRouting {
                selected: vec![2, 40, 5, 17, 33, 57],
                weights: vec![1.0 / 6.0; 6],
            }];
            let _ = eng.apply(
                0,
                &mut toks,
                &residency,
                MissPolicy::Buddy,
                None,
                &mut counters,
                &mut rng,
            );
        });
        println!(
            "| BuddyMoE miss | {:.4} (p95 {:.4}) | minimal loss (see Tables 2-4) |",
            mean * 1e3,
            p95 * 1e3
        );
        assert!(counters.get("substitutions") > 0, "substitutions must fire");
    }
    println!("\npaper: on-demand and prefetch-miss cost 9-10 ms; hits and buddy substitution ~0.");
}
