//! Table 4 — performance at extreme memory constraint c = 0.375.
//!
//! Paper: Original 24.78 t/s; Random unusable (0.16); BuddyMoE(rho=3)
//! keeps 0.645 acc at 27.33 t/s — ~10% faster than Original.

mod bench_support;

use buddymoe::eval::{run_table, table_methods, TableSettings};

fn main() {
    let Some((cfg, store)) = bench_support::load_model() else {
        return;
    };
    let fast = bench_support::fast_mode();
    let settings = TableSettings {
        cache_rate: 0.375,
        n_easy: if fast { 3 } else { 8 },
        n_hard: if fast { 3 } else { 8 },
        max_new: if fast { 8 } else { 16 },
        seed: 42,
        clock: bench_support::clock_mode(),
    };
    let (rows, md) = run_table(&cfg, store, &settings, &table_methods()).expect("table 4");
    println!("# Table 4 — {md}");
    println!("paper reference: Original -/24.78, Random 0.16/-, Buddy(rho3) 0.645/27.33 (+10.3%)");
    // Headline claim check: buddy-rho3 throughput vs original.
    let orig = rows.iter().find(|r| r.label.contains("Original"));
    let rho3 = rows.iter().find(|r| r.label.contains("rho=3"));
    if let (Some(o), Some(b)) = (orig, rho3) {
        println!(
            "\nheadline: Buddy(rho3) {:.2} t/s vs Original {:.2} t/s -> {:+.1}%",
            b.tok_s,
            o.tok_s,
            100.0 * (b.tok_s / o.tok_s - 1.0)
        );
    }
}
