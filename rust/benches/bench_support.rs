//! Shared bench harness (criterion is unavailable offline): timing loops
//! with warm-up, and the common model-loading path. Each bench binary is a
//! plain `main` (harness = false) that prints a paper-style table.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use buddymoe::config::ModelConfig;
use buddymoe::weights::WeightStore;

/// Time `f` over `iters` iterations after `warmup` discarded ones.
/// Returns (mean seconds, p95 seconds).
#[allow(dead_code)]
#[allow(clippy::disallowed_methods)]
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    (mean, p95)
}

#[allow(dead_code)]
pub fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[allow(dead_code)]
pub fn load_model() -> Option<(ModelConfig, Arc<WeightStore>)> {
    let dir = artifacts_dir();
    if !dir.join("model_config.json").exists() {
        eprintln!("SKIP: artifacts not built — run `make artifacts` first");
        return None;
    }
    let cfg = ModelConfig::load(&dir).expect("model config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    Some((cfg, store))
}

/// The artifact model when built; otherwise the synthetic family model
/// (the shared `eval::load_model_or_synthetic` fallback) so the bench
/// runs anywhere — CI included.
#[allow(dead_code)]
pub fn load_model_or_synthetic() -> (ModelConfig, Arc<WeightStore>) {
    buddymoe::eval::load_model_or_synthetic(&artifacts_dir(), 2024).expect("model")
}

/// `--fast` shrinks workloads for CI-style runs.
#[allow(dead_code)]
pub fn fast_mode() -> bool {
    std::env::args().any(|a| a == "--fast") || std::env::var("BENCH_FAST").is_ok()
}

/// Benches default to the deterministic virtual clock (a full table sweep
/// finishes in milliseconds); pass `--real-time` to measure on the wall
/// clock with real PCIe stalls.
#[allow(dead_code)]
pub fn clock_mode() -> buddymoe::util::clock::ClockMode {
    if std::env::args().any(|a| a == "--real-time") {
        buddymoe::util::clock::ClockMode::RealTime
    } else {
        buddymoe::util::clock::ClockMode::Virtual
    }
}
