//! Algorithm 1 — Buddy Expert Substitution — plus the Random and Drop
//! baselines (paper §4, §5.1).
//!
//! Runs immediately after top-k selection, before expert scheduling: for
//! every token, every selected expert that is not GPU-resident is either
//! substituted with a resident buddy (subject to the TAE and distribution
//! gates, search rank H, per-token uniqueness, and the replacement budget
//! ρ), fetched on demand, or dropped — depending on the miss policy.
//!
//! The paper implements this as a CUDA kernel (one block per token, one
//! thread per top-k slot, shared-memory CAS for uniqueness). Here it is the
//! L3 hot path: per-token scratch sets give the same uniqueness guarantee
//! without cross-token synchronization; the `micro_hotpath` bench verifies
//! the paper's claim that this logic is negligible next to expert compute.

use crate::buddy::gates::{distribution_gate, tae_gate, GateParams};
use crate::buddy::profile::BuddyProfile;
use crate::buddy::score::{psi, PsiParams};
use crate::config::MissPolicy;
use crate::stats::Counters;
use crate::topology::HopContext;
use crate::util::rng::Rng;

/// One token's routing decision (post top-k, pre substitution).
#[derive(Debug, Clone, PartialEq)]
pub struct TokenRouting {
    /// Selected experts, descending renormalized probability.
    pub selected: Vec<usize>,
    /// Renormalized top-k weights aligned with `selected`.
    pub weights: Vec<f32>,
}

/// Outcome for one (token, slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotDecision {
    /// Expert was GPU-resident; unchanged.
    Keep,
    /// Substituted with a resident buddy.
    Substitute { to: usize, rank: usize },
    /// Must be fetched over PCIe (demand load).
    Fetch,
    /// Dropped from the computation (Drop baseline).
    Dropped,
}

/// Record of one substitution (telemetry / tests).
#[derive(Debug, Clone, PartialEq)]
pub struct SubEvent {
    pub token: usize,
    pub slot: usize,
    pub from: usize,
    pub to: usize,
    pub rank: usize,
    pub psi: f64,
}

/// The substitution engine for one layer invocation.
pub struct SubstitutionEngine<'a> {
    pub profile: &'a BuddyProfile,
    pub gates: GateParams,
    pub psi_params: PsiParams,
    /// Maximum buddy search rank H (Algorithm 1).
    pub search_h: usize,
    /// Per-token replacement budget ρ (None = unlimited).
    pub rho: Option<usize>,
    /// Pivot-relative cross-device hop counts for ψ's κ penalty, derived
    /// from the expert→device-set placement and scored against each
    /// candidate's *nearest replica* (see `crate::topology`). `None`
    /// on a single GPU, where every hop count is zero.
    pub topo: Option<HopContext<'a>>,
}

impl<'a> SubstitutionEngine<'a> {
    pub fn new(profile: &'a BuddyProfile) -> Self {
        Self {
            profile,
            gates: GateParams::default(),
            psi_params: PsiParams::default(),
            search_h: 16,
            rho: Some(3),
            topo: None,
        }
    }

    /// Apply the miss policy to a micro-batch at `layer`.
    ///
    /// * `residency` — Algorithm 1's mask M over this layer's experts.
    /// * `full_probs` — per-token full router probabilities (for the η
    ///   local-compatibility term); pass `None` to skip.
    ///
    /// Mutates `tokens` in place (substituted slots point at the buddy) and
    /// returns per-slot decisions plus substitution events.
    #[allow(clippy::too_many_arguments)]
    pub fn apply(
        &self,
        layer: usize,
        tokens: &mut [TokenRouting],
        residency: &[bool],
        policy: MissPolicy,
        full_probs: Option<&[Vec<f32>]>,
        counters: &mut Counters,
        rng: &mut Rng,
    ) -> (Vec<Vec<SlotDecision>>, Vec<SubEvent>) {
        // Batch-level distribution gate (Eq. 2): δ over unique requested.
        let mut requested = vec![false; residency.len()];
        for t in tokens.iter() {
            for &e in &t.selected {
                requested[e] = true;
            }
        }
        let total_req = requested.iter().filter(|&&r| r).count();
        let cpu_req = (0..residency.len())
            .filter(|&e| requested[e] && !residency[e])
            .count();
        let batch_gate_ok = distribution_gate(cpu_req, total_req, self.gates.beta);
        if !batch_gate_ok && policy == MissPolicy::Buddy {
            counters.inc("gate_dist_blocked_batches");
        }

        let mut decisions = Vec::with_capacity(tokens.len());
        let mut events = Vec::new();
        let resident_list: Vec<usize> = (0..residency.len()).filter(|&e| residency[e]).collect();

        for (ti, tok) in tokens.iter_mut().enumerate() {
            let token_gate_ok = tae_gate(&tok.weights, &self.gates);
            let mut budget = self.rho.unwrap_or(usize::MAX);
            let mut reuse: Vec<u16> = Vec::new(); // (expert, count) compact
            let mut reuse_ids: Vec<usize> = Vec::new();
            let mut slot_dec = Vec::with_capacity(tok.selected.len());
            let mut dropped_any = false;

            for slot in 0..tok.selected.len() {
                let e = tok.selected[slot];
                counters.inc("slots_total");
                if residency[e] {
                    counters.inc("slots_resident");
                    slot_dec.push(SlotDecision::Keep);
                    continue;
                }
                counters.inc("slots_miss");
                let dec = match policy {
                    MissPolicy::OnDemand => SlotDecision::Fetch,
                    MissPolicy::Drop => SlotDecision::Dropped,
                    MissPolicy::Random => {
                        let in_set = |cand: usize, sel: &[usize]| sel.contains(&cand);
                        let avail: Vec<usize> = resident_list
                            .iter()
                            .copied()
                            .filter(|&c| !in_set(c, &tok.selected))
                            .collect();
                        if avail.is_empty() {
                            SlotDecision::Fetch
                        } else {
                            let to = avail[rng.below(avail.len())];
                            // Random substitutions emit events too, so the
                            // engine's cross-device dispatch accounting
                            // covers the baseline policy as well.
                            events.push(SubEvent {
                                token: ti,
                                slot,
                                from: e,
                                to,
                                rank: 0,
                                psi: 0.0,
                            });
                            SlotDecision::Substitute { to, rank: 0 }
                        }
                    }
                    MissPolicy::Buddy => {
                        if !token_gate_ok {
                            counters.inc("gate_tae_blocked");
                            SlotDecision::Fetch
                        } else if !batch_gate_ok {
                            counters.inc("gate_dist_blocked");
                            SlotDecision::Fetch
                        } else if budget == 0 {
                            counters.inc("budget_blocked");
                            SlotDecision::Fetch
                        } else {
                            self.pick_buddy(
                                layer,
                                e,
                                &tok.selected,
                                residency,
                                full_probs.map(|p| p[ti].as_slice()),
                                &reuse_ids,
                                &reuse,
                            )
                            .map(|(to, rank, score)| {
                                events.push(SubEvent {
                                    token: ti,
                                    slot,
                                    from: e,
                                    to,
                                    rank,
                                    psi: score,
                                });
                                SlotDecision::Substitute { to, rank }
                            })
                            .unwrap_or_else(|| {
                                counters.inc("no_buddy_resident");
                                SlotDecision::Fetch
                            })
                        }
                    }
                };
                match dec {
                    SlotDecision::Substitute { to, .. } => {
                        counters.inc("substitutions");
                        tok.selected[slot] = to;
                        budget = budget.saturating_sub(1);
                        match reuse_ids.iter().position(|&x| x == to) {
                            Some(p) => reuse[p] += 1,
                            None => {
                                reuse_ids.push(to);
                                reuse.push(1);
                            }
                        }
                    }
                    SlotDecision::Fetch => counters.inc("fetches"),
                    SlotDecision::Dropped => {
                        counters.inc("drops");
                        dropped_any = true;
                    }
                    SlotDecision::Keep => {}
                }
                slot_dec.push(dec);
            }

            // Drop baseline: renormalize surviving weights. When every
            // slot dropped (all selected experts offloaded) the token gets
            // a zero MoE contribution — the residual stream carries it.
            if dropped_any {
                let kept: f32 = slot_dec
                    .iter()
                    .zip(&tok.weights)
                    .filter(|(d, _)| !matches!(d, SlotDecision::Dropped))
                    .map(|(_, &w)| w)
                    .sum();
                for (d, w) in slot_dec.iter().zip(tok.weights.iter_mut()) {
                    if matches!(d, SlotDecision::Dropped) {
                        *w = 0.0;
                    } else if kept > 0.0 {
                        *w /= kept;
                    }
                }
            }
            decisions.push(slot_dec);
        }
        (decisions, events)
    }

    /// Scan the pivot's buddy list up to rank H and return the best
    /// GPU-resident candidate not already in the token's active set.
    #[allow(clippy::too_many_arguments)]
    fn pick_buddy(
        &self,
        layer: usize,
        pivot: usize,
        active: &[usize],
        residency: &[bool],
        probs: Option<&[f32]>,
        reuse_ids: &[usize],
        reuse_counts: &[u16],
    ) -> Option<(usize, usize, f64)> {
        let list = self.profile.list(layer, pivot);
        let mut best: Option<(usize, usize, f64)> = None;
        for (r0, &(cand, q)) in list.ranked.iter().enumerate().take(self.search_h) {
            if !residency[cand] || active.contains(&cand) {
                continue;
            }
            let z_hat = probs.map(|p| p[cand] as f64).unwrap_or(0.0);
            let hops = self.topo.as_ref().map(|t| t.hops(pivot, cand)).unwrap_or(0);
            let reuse = reuse_ids
                .iter()
                .position(|&x| x == cand)
                .map(|p| reuse_counts[p] as usize)
                .unwrap_or(0);
            let score = psi(q, z_hat, hops, reuse, &self.psi_params);
            if best.map(|(_, _, b)| score > b).unwrap_or(true) {
                best = Some((cand, r0 + 1, score));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profilecollect::ProfileCollector;

    /// 6-expert layer; pivot 0 buddies with [1, 2, 3] (descending).
    fn profile() -> BuddyProfile {
        let mut p = ProfileCollector::new(1, 6);
        for _ in 0..8 {
            p.record(0, &[0, 1], &[0.6, 0.4]).unwrap();
        }
        for _ in 0..4 {
            p.record(0, &[0, 2], &[0.6, 0.4]).unwrap();
        }
        for _ in 0..2 {
            p.record(0, &[0, 3], &[0.6, 0.4]).unwrap();
        }
        // Give the other pivots some mass too.
        for _ in 0..3 {
            p.record(0, &[4, 5], &[0.5, 0.5]).unwrap();
            p.record(0, &[1, 2], &[0.5, 0.5]).unwrap();
            p.record(0, &[3, 5], &[0.5, 0.5]).unwrap();
        }
        BuddyProfile::build(&p, &[1.0], 6, 1e-6, false).unwrap()
    }

    fn diffuse_token(selected: Vec<usize>) -> TokenRouting {
        let k = selected.len();
        TokenRouting { selected, weights: vec![1.0 / k as f32; k] }
    }

    fn engine(p: &BuddyProfile) -> SubstitutionEngine<'_> {
        let mut e = SubstitutionEngine::new(p);
        e.gates.tau = 0.5; // diffuse test tokens pass
        e.gates.beta = 1.0; // distribution gate permissive unless tested
        e
    }

    #[test]
    fn substitutes_top_ranked_resident_buddy() {
        let p = profile();
        let eng = engine(&p);
        // Expert 0 missing; buddy 1 not resident, buddy 2 resident.
        let residency = [false, false, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, ev) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Substitute { to: 2, rank: 2 });
        assert_eq!(toks[0].selected, vec![2, 4]);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].from, 0);
        assert_eq!(c.get("substitutions"), 1);
    }

    #[test]
    fn uniqueness_constraint_respected() {
        let p = profile();
        let eng = engine(&p);
        // Token already uses expert 1; pivot 0's best buddy is 1 -> must
        // fall through to 2.
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 1])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Substitute { to: 2, rank: 2 });
        // No duplicate experts in the final set.
        let mut s = toks[0].selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), toks[0].selected.len());
    }

    #[test]
    fn search_rank_h_limits() {
        let p = profile();
        let mut eng = engine(&p);
        eng.search_h = 1; // only rank-1 buddy (expert 1) may be used
        let residency = [false, false, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Fetch);
        assert_eq!(c.get("no_buddy_resident"), 1);
    }

    #[test]
    fn tae_gate_blocks_peaky_tokens() {
        let p = profile();
        let mut eng = engine(&p);
        eng.gates.tau = 0.95;
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![TokenRouting {
            selected: vec![0, 4],
            weights: vec![0.98, 0.02], // peaky -> sensitive
        }];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Fetch);
        assert_eq!(c.get("gate_tae_blocked"), 1);
    }

    #[test]
    fn distribution_gate_blocks_broad_replacement() {
        let p = profile();
        let mut eng = engine(&p);
        eng.gates.beta = 0.4; // δ = 2 cpu / 3 requested = 0.67 >= β
        let residency = [false, true, true, false, true, true];
        let mut toks = vec![diffuse_token(vec![0, 3, 1])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Fetch);
        assert_eq!(dec[0][1], SlotDecision::Fetch);
        assert!(c.get("gate_dist_blocked") >= 2);
    }

    #[test]
    fn rho_budget_limits_substitutions() {
        let p = profile();
        let mut eng = engine(&p);
        eng.rho = Some(1);
        // Experts 0 and 3 both missing; only one substitution allowed.
        // (4 is resident so the batch-level δ = 2/3 < β = 1.0 passes.)
        let residency = [false, true, true, false, true, true];
        let mut toks = vec![diffuse_token(vec![0, 3, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        eng.gates.beta = 1.0;
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        let subs = dec[0]
            .iter()
            .filter(|d| matches!(d, SlotDecision::Substitute { .. }))
            .count();
        assert_eq!(subs, 1);
        assert_eq!(c.get("budget_blocked"), 1);
    }

    #[test]
    fn on_demand_always_fetches() {
        let p = profile();
        let eng = engine(&p);
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 1])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, ev) = eng.apply(
            0, &mut toks, &residency, MissPolicy::OnDemand, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Fetch);
        assert!(ev.is_empty());
        assert_eq!(toks[0].selected, vec![0, 1]); // unchanged
    }

    #[test]
    fn random_substitutes_resident_non_active() {
        let p = profile();
        let eng = engine(&p);
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 1])];
        let mut c = Counters::new();
        let mut rng = Rng::new(7);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Random, None, &mut c, &mut rng,
        );
        match dec[0][0] {
            SlotDecision::Substitute { to, .. } => {
                assert!(residency[to]);
                assert_ne!(to, 1, "must not duplicate an active expert");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn drop_renormalizes_weights() {
        let p = profile();
        let eng = engine(&p);
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![TokenRouting {
            selected: vec![0, 1, 2],
            weights: vec![0.5, 0.3, 0.2],
        }];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Drop, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Dropped);
        assert_eq!(toks[0].weights[0], 0.0);
        let sum: f32 = toks[0].weights.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!((toks[0].weights[1] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn eta_prefers_locally_compatible_buddy() {
        let p = profile();
        let mut eng = engine(&p);
        eng.psi_params.eta = 10.0; // exaggerate local compatibility
        let residency = [false, true, true, true, true, true];
        // Full probs make expert 3 (rank 3, q small) hugely compatible.
        let mut probs = vec![0.0f32; 6];
        probs[1] = 0.01;
        probs[2] = 0.01;
        probs[3] = 0.9;
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0,
            &mut toks,
            &residency,
            MissPolicy::Buddy,
            Some(&[probs]),
            &mut c,
            &mut rng,
        );
        assert!(matches!(dec[0][0], SlotDecision::Substitute { to: 3, .. }));
    }

    /// Pivot 0 with two *equally ranked* buddies (1 and 2): identical
    /// co-activation counts, so q is tied and rank order falls back to
    /// expert id (1 before 2).
    fn equal_q_profile() -> BuddyProfile {
        let mut p = ProfileCollector::new(1, 6);
        for _ in 0..8 {
            p.record(0, &[0, 1], &[0.6, 0.4]).unwrap();
            p.record(0, &[0, 2], &[0.6, 0.4]).unwrap();
        }
        for _ in 0..3 {
            p.record(0, &[4, 5], &[0.5, 0.5]).unwrap();
        }
        BuddyProfile::build(&p, &[1.0], 6, 1e-6, false).unwrap()
    }

    #[test]
    fn kappa_steers_to_same_device_buddy() {
        // The acceptance scenario: two devices, pivot 0 homed on device 0.
        // Buddy 1 (cross-device) and buddy 2 (same-device) are otherwise
        // equal (same q); with κ live, ψ must prefer the same-device buddy.
        let p = equal_q_profile();
        let mut eng = engine(&p);
        eng.psi_params.kappa = 0.5;
        // 2-way striping-ish: single-homed experts.
        let homes: Vec<Vec<usize>> =
            vec![vec![0], vec![1], vec![0], vec![0], vec![0], vec![1]];
        let hop_matrix = vec![vec![0usize, 1], vec![1, 0]];
        eng.topo = Some(HopContext { homes: &homes, hop_matrix: &hop_matrix });
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, ev) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(
            dec[0][0],
            SlotDecision::Substitute { to: 2, rank: 2 },
            "κ must flip the tie toward the same-device buddy"
        );
        assert_eq!(ev[0].to, 2);
    }

    #[test]
    fn kappa_sees_replicas_as_local() {
        // Same scenario, but the cross-device rank-1 buddy now has a
        // replica on the pivot's device: its nearest-replica hop count is
        // 0, so κ no longer penalizes it and rank order decides again.
        let p = equal_q_profile();
        let mut eng = engine(&p);
        eng.psi_params.kappa = 0.5;
        let homes: Vec<Vec<usize>> =
            vec![vec![0], vec![1, 0], vec![0], vec![0], vec![0], vec![1]];
        let hop_matrix = vec![vec![0usize, 1], vec![1, 0]];
        eng.topo = Some(HopContext { homes: &homes, hop_matrix: &hop_matrix });
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(
            dec[0][0],
            SlotDecision::Substitute { to: 1, rank: 1 },
            "a local replica must neutralize the κ penalty"
        );
    }

    #[test]
    fn without_kappa_cross_device_tie_keeps_rank_order() {
        // Control for the test above: κ = 0 leaves ψ topology-blind, so
        // the rank-1 (cross-device) buddy wins the q tie.
        let p = equal_q_profile();
        let mut eng = engine(&p);
        eng.psi_params.kappa = 0.0;
        let homes: Vec<Vec<usize>> =
            vec![vec![0], vec![1], vec![0], vec![0], vec![0], vec![1]];
        let hop_matrix = vec![vec![0usize, 1], vec![1, 0]];
        eng.topo = Some(HopContext { homes: &homes, hop_matrix: &hop_matrix });
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 4])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        let (dec, _) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng,
        );
        assert_eq!(dec[0][0], SlotDecision::Substitute { to: 1, rank: 1 });
    }

    #[test]
    fn random_substitution_emits_events() {
        let p = profile();
        let eng = engine(&p);
        let residency = [false, true, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 1])];
        let mut c = Counters::new();
        let mut rng = Rng::new(7);
        let (dec, ev) = eng.apply(
            0, &mut toks, &residency, MissPolicy::Random, None, &mut c, &mut rng,
        );
        match dec[0][0] {
            SlotDecision::Substitute { to, .. } => {
                assert_eq!(ev.len(), 1);
                assert_eq!(ev[0].from, 0);
                assert_eq!(ev[0].to, to);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn counters_consistency() {
        let p = profile();
        let eng = engine(&p);
        let residency = [false, false, true, true, true, true];
        let mut toks = vec![diffuse_token(vec![0, 1, 4]), diffuse_token(vec![2, 3])];
        let mut c = Counters::new();
        let mut rng = Rng::new(1);
        eng.apply(0, &mut toks, &residency, MissPolicy::Buddy, None, &mut c, &mut rng);
        assert_eq!(c.get("slots_total"), 5);
        assert_eq!(
            c.get("slots_total"),
            c.get("slots_resident") + c.get("slots_miss")
        );
        assert_eq!(
            c.get("slots_miss"),
            c.get("substitutions") + c.get("fetches") + c.get("drops")
        );
    }
}
