//! Offline buddy-list construction (paper §3.2–§3.3).
//!
//! For each pivot i: sort peers by q_{j|i} descending to get the sequence
//! π_i, then take the minimal prefix whose cumulative conditional mass
//! reaches the Cumulative Frequency Threshold α (Eq. 5). The buddy list
//! B_l(i; α) is that prefix (Eq. 6), capped at K_max, and guaranteed
//! non-empty for any pivot with nonzero activity.

use anyhow::{bail, Result};

use crate::profilecollect::ProfileCollector;
use crate::util::json::{num, obj, Json};

/// Ranked buddy list for one pivot expert.
#[derive(Debug, Clone, PartialEq)]
pub struct BuddyList {
    /// (buddy expert, q_{buddy|pivot}) in descending q.
    pub ranked: Vec<(usize, f64)>,
}

impl BuddyList {
    pub fn len(&self) -> usize {
        self.ranked.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranked.is_empty()
    }

    /// Rank (1-based, as in Algorithm 1) of an expert, if present.
    pub fn rank_of(&self, expert: usize) -> Option<usize> {
        self.ranked.iter().position(|&(e, _)| e == expert).map(|p| p + 1)
    }
}

/// Per-layer, per-pivot buddy lists plus the α schedule that produced them.
#[derive(Debug, Clone)]
pub struct BuddyProfile {
    pub n_layers: usize,
    pub n_experts: usize,
    pub alphas: Vec<f64>,
    pub k_max: usize,
    lists: Vec<Vec<BuddyList>>, // [layer][pivot]
}

impl BuddyProfile {
    /// Build from collected co-activation statistics.
    ///
    /// * `alphas` — per-layer CFT α (pass a single repeated value for a
    ///   uniform threshold; the per-layer schedule implements the paper's
    ///   layer-wise heterogeneity calibration).
    /// * `eps` — Laplace smoothing added to co-activation rows.
    /// * `use_weighted` — rank by probability-weighted co-activations
    ///   instead of binary counts.
    pub fn build(
        collector: &ProfileCollector,
        alphas: &[f64],
        k_max: usize,
        eps: f64,
        use_weighted: bool,
    ) -> Result<Self> {
        if alphas.len() != collector.n_layers() {
            bail!(
                "alpha schedule length {} != n_layers {}",
                alphas.len(),
                collector.n_layers()
            );
        }
        if k_max == 0 {
            bail!("k_max must be >= 1");
        }
        let mut lists = Vec::with_capacity(collector.n_layers());
        let mut n_experts = 0;
        for (l, &alpha) in alphas.iter().enumerate() {
            if !(0.0 < alpha && alpha <= 1.0) {
                bail!("alpha must be in (0,1], got {alpha}");
            }
            let co = collector.layer(l);
            n_experts = co.n_experts;
            let mut layer_lists = Vec::with_capacity(co.n_experts);
            for i in 0..co.n_experts {
                let q = co.q_given(i, eps, use_weighted);
                let mut order: Vec<usize> = (0..co.n_experts).filter(|&j| j != i).collect();
                // total_cmp: the old partial_cmp fallback treated NaN as
                // equal to everything, which breaks sort transitivity; a
                // NaN q now ranks deterministically.
                order.sort_by(|&a, &b| q[b].total_cmp(&q[a]).then(a.cmp(&b)));
                let mut ranked = Vec::new();
                let mut cum = 0.0;
                for &j in &order {
                    if q[j] <= 0.0 && !ranked.is_empty() {
                        break; // only zero-mass peers remain
                    }
                    ranked.push((j, q[j]));
                    cum += q[j];
                    if cum >= alpha || ranked.len() >= k_max {
                        break;
                    }
                }
                // t_i(alpha) >= 1 for any pivot with nonzero activity; for
                // fully inactive pivots (q all zero without smoothing) keep
                // the top-1 peer anyway so runtime lookups never fail.
                layer_lists.push(BuddyList { ranked });
            }
            lists.push(layer_lists);
        }
        Ok(Self {
            n_layers: collector.n_layers(),
            n_experts,
            alphas: alphas.to_vec(),
            k_max,
            lists,
        })
    }

    pub fn list(&self, layer: usize, pivot: usize) -> &BuddyList {
        &self.lists[layer][pivot]
    }

    /// |B_l(i; α)| distribution for one layer (paper reports compactness).
    pub fn list_sizes(&self, layer: usize) -> Vec<usize> {
        self.lists[layer].iter().map(|b| b.len()).collect()
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .lists
            .iter()
            .map(|layer| {
                Json::Arr(
                    layer
                        .iter()
                        .map(|bl| {
                            Json::Arr(
                                bl.ranked
                                    .iter()
                                    .map(|&(e, q)| {
                                        Json::Arr(vec![num(e as f64), num(q)])
                                    })
                                    .collect(),
                            )
                        })
                        .collect(),
                )
            })
            .collect();
        obj(vec![
            ("n_layers", num(self.n_layers as f64)),
            ("n_experts", num(self.n_experts as f64)),
            ("k_max", num(self.k_max as f64)),
            (
                "alphas",
                Json::Arr(self.alphas.iter().map(|&a| num(a)).collect()),
            ),
            ("lists", Json::Arr(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut lists = Vec::new();
        for layer in j.get("lists")?.as_arr()? {
            let mut layer_lists = Vec::new();
            for bl in layer.as_arr()? {
                let ranked = bl
                    .as_arr()?
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr()?;
                        Ok((pair[0].as_usize()?, pair[1].as_f64()?))
                    })
                    .collect::<Result<Vec<_>>>()?;
                layer_lists.push(BuddyList { ranked });
            }
            lists.push(layer_lists);
        }
        Ok(Self {
            n_layers: j.get("n_layers")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            k_max: j.get("k_max")?.as_usize()?,
            alphas: j
                .get("alphas")?
                .as_arr()?
                .iter()
                .map(|a| a.as_f64())
                .collect::<Result<Vec<_>, _>>()?,
            lists,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Collector where expert 0 co-activates mostly with 1, some with 2.
    fn skewed_collector() -> ProfileCollector {
        let mut p = ProfileCollector::new(1, 4);
        for _ in 0..6 {
            p.record(0, &[0, 1], &[0.6, 0.4]).unwrap();
        }
        for _ in 0..3 {
            p.record(0, &[0, 2], &[0.6, 0.4]).unwrap();
        }
        p.record(0, &[0, 3], &[0.6, 0.4]).unwrap();
        p
    }

    #[test]
    fn cft_prefix_minimal() {
        let p = skewed_collector();
        // q = [_, .6, .3, .1]; alpha=0.55 -> just {1}; alpha=0.8 -> {1,2}.
        let b = BuddyProfile::build(&p, &[0.55], 8, 0.0, false).unwrap();
        assert_eq!(
            b.list(0, 0).ranked.iter().map(|x| x.0).collect::<Vec<_>>(),
            vec![1]
        );
        let b = BuddyProfile::build(&p, &[0.8], 8, 0.0, false).unwrap();
        assert_eq!(
            b.list(0, 0).ranked.iter().map(|x| x.0).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }

    #[test]
    fn k_max_caps_lists() {
        let p = skewed_collector();
        let b = BuddyProfile::build(&p, &[1.0], 2, 0.0, false).unwrap();
        assert!(b.list(0, 0).len() <= 2);
    }

    #[test]
    fn lists_nonempty_with_smoothing() {
        let p = ProfileCollector::new(1, 4); // no activity at all
        let b = BuddyProfile::build(&p, &[0.5], 4, 1e-3, false).unwrap();
        for i in 0..4 {
            assert!(!b.list(0, i).is_empty(), "pivot {i} empty");
            // Pivot never appears in its own list.
            assert!(b.list(0, i).ranked.iter().all(|&(e, _)| e != i));
        }
    }

    #[test]
    fn ranked_descending() {
        let p = skewed_collector();
        let b = BuddyProfile::build(&p, &[1.0], 8, 1e-6, false).unwrap();
        let r = &b.list(0, 0).ranked;
        for w in r.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        assert_eq!(b.list(0, 0).rank_of(r[0].0), Some(1));
    }

    #[test]
    fn alpha_schedule_validated() {
        let p = skewed_collector();
        assert!(BuddyProfile::build(&p, &[0.5, 0.5], 4, 0.0, false).is_err());
        assert!(BuddyProfile::build(&p, &[0.0], 4, 0.0, false).is_err());
        assert!(BuddyProfile::build(&p, &[0.5], 0, 0.0, false).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let p = skewed_collector();
        let b = BuddyProfile::build(&p, &[0.9], 4, 1e-3, true).unwrap();
        let back = BuddyProfile::from_json(&b.to_json()).unwrap();
        assert_eq!(back.n_experts, b.n_experts);
        assert_eq!(back.list(0, 0), b.list(0, 0));
        assert_eq!(back.alphas, b.alphas);
    }
}
