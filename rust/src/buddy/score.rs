//! Buddy selection priority score Ψ (paper Eq. 3):
//!
//! Ψ_l(j | i, x) = q_{j|i} · (1 + η·ẑ_j(x)) · (1 − κ·hop(j)) · d^{reuse}
//!
//! where ẑ_j is the normalized router logit of the candidate on this token
//! (local compatibility), hop(j) counts cross-partition hops (0 on the same
//! GPU), and d < 1 is the multiplicative diversity discount applied each
//! time the candidate has already been picked for this token.
//!
//! Since the multi-device topology PR, hop(j) is *live*: it is derived
//! from the expert→device-set placement as the *nearest-replica* peer-link
//! distance — the minimum of `Topology::hops(hp, hc)` over every pair of
//! pivot home `hp` and candidate home `hc` — packaged per layer as a
//! [`crate::topology::HopContext`] and handed to the substitution engine
//! by `model::engine` whenever `ServingConfig::n_devices > 1`. A buddy
//! with *any* replica on the pivot's device costs zero hops, so
//! replicating a hot expert (replication_factor > 1) neutralizes its κ
//! penalty fleet-wide; a buddy whose nearest replica is remote pays κ per
//! hop here *and* a contended peer-link activation round trip on the
//! virtual clock (the engine's peer-dispatch accounting), so κ steers
//! substitution toward locally-resident buddies for exactly the reason it
//! exists in the paper. On one device every hop count is zero and ψ
//! reduces to the original form.

#[derive(Debug, Clone, Copy)]
pub struct PsiParams {
    /// Local-compatibility weight η (default 0 per paper).
    pub eta: f64,
    /// Cross-link penalty κ (default 0 per paper).
    pub kappa: f64,
    /// Diversity discount factor in (0, 1].
    pub diversity_discount: f64,
}

impl Default for PsiParams {
    fn default() -> Self {
        Self { eta: 0.0, kappa: 0.0, diversity_discount: 0.5 }
    }
}

/// Compute Ψ for one candidate.
///
/// * `q` — global co-activation similarity q_{j|i}.
/// * `z_hat` — normalized router logit of candidate j on this token
///   (pass 0.0 when unavailable).
/// * `hops` — cross-partition hops to reach j.
/// * `reuse_count` — times j was already chosen for this token.
pub fn psi(q: f64, z_hat: f64, hops: usize, reuse_count: usize, p: &PsiParams) -> f64 {
    let local = 1.0 + p.eta * z_hat;
    let topo = (1.0 - p.kappa * hops as f64).max(0.0);
    q * local * topo * p.diversity_discount.powi(reuse_count as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reduce_to_q_order() {
        let p = PsiParams::default();
        assert!(psi(0.5, 10.0, 3, 0, &p) > psi(0.4, -10.0, 0, 0, &p));
    }

    #[test]
    fn eta_boosts_compatible_candidates() {
        let p = PsiParams { eta: 0.5, ..Default::default() };
        assert!(psi(0.4, 1.0, 0, 0, &p) > psi(0.4, 0.0, 0, 0, &p));
        assert!(psi(0.4, -1.0, 0, 0, &p) < psi(0.4, 0.0, 0, 0, &p));
    }

    #[test]
    fn kappa_penalizes_hops_and_clamps() {
        let p = PsiParams { kappa: 0.3, ..Default::default() };
        assert!(psi(0.5, 0.0, 1, 0, &p) < psi(0.5, 0.0, 0, 0, &p));
        // Never negative even for many hops.
        assert!(psi(0.5, 0.0, 10, 0, &p) >= 0.0);
    }

    #[test]
    fn reuse_discount_compounds() {
        let p = PsiParams { diversity_discount: 0.5, ..Default::default() };
        let s0 = psi(0.8, 0.0, 0, 0, &p);
        let s1 = psi(0.8, 0.0, 0, 1, &p);
        let s2 = psi(0.8, 0.0, 0, 2, &p);
        assert!((s1 - s0 * 0.5).abs() < 1e-12);
        assert!((s2 - s0 * 0.25).abs() < 1e-12);
    }
}
