//! The paper's contribution: buddy-expert identification and runtime
//! substitution.
//!
//! * [`profile`] — offline: conditional co-activation q_{j|i} (Eq. 4) →
//!   CFT buddy lists (Eqs. 5–6).
//! * [`gates`] — runtime admission: Token Activating Entropy gate (Eq. 1)
//!   with temperature smoothing / percentile calibration / margin option,
//!   and the batch-level expert-distribution gate (Eq. 2).
//! * [`score`] — the buddy selection priority score Ψ (Eq. 3).
//! * [`substitute`] — Algorithm 1: the runtime replacement engine with the
//!   per-token uniqueness constraint, search rank H, and replacement
//!   budget ρ; also implements the Random and Drop baselines.

mod gates;
mod profile;
mod score;
mod substitute;

pub use gates::{calibrate_tau_percentile, distribution_gate, tae_gate, temperature_renorm, GateParams};
pub use profile::{BuddyList, BuddyProfile};
pub use score::{psi, PsiParams};
pub use substitute::{SlotDecision, SubEvent, SubstitutionEngine, TokenRouting};
