//! Runtime admission gates (paper §3.1).
//!
//! Strict sequence: (1) the **TAE gate** decides whether this token
//! tolerates substitution at all; (2) the **distribution gate** decides
//! whether the batch-level CPU-residency fraction makes substitution too
//! risky. Only if both pass does buddy selection (Ψ) run.

use crate::util::math::{percentile, prob_margin, tae};

/// Gate thresholds (paper symbols).
#[derive(Debug, Clone, Copy)]
pub struct GateParams {
    /// TAE threshold τ: forbid substitution when TAE ≤ τ.
    pub tau: f64,
    /// Optional margin threshold γ: also forbid when p_max − p_2nd ≥ γ.
    pub margin_gamma: Option<f64>,
    /// Distribution threshold β: bypass when CPU fraction δ ≥ β.
    pub beta: f64,
    /// Optional temperature for TAE smoothing (paper: T ∈ [0.8, 1.2]).
    pub temperature: Option<f64>,
}

impl Default for GateParams {
    fn default() -> Self {
        Self { tau: 0.95, margin_gamma: None, beta: 0.9, temperature: None }
    }
}

/// Re-normalize top-k weights under temperature T: w_i ∝ w_i^(1/T).
///
/// Equivalent to softmax(z/T) restricted to the selected set when w came
/// from softmax(z) renormalized — exponent rules compose.
pub fn temperature_renorm(weights: &[f32], t: f64) -> Vec<f32> {
    let inv = (1.0 / t) as f32;
    let mut w: Vec<f32> = weights.iter().map(|&x| x.max(1e-30).powf(inv)).collect();
    let sum: f32 = w.iter().sum();
    for x in w.iter_mut() {
        *x /= sum;
    }
    w
}

/// TAE gate: `true` = substitution ALLOWED for this token.
///
/// Low TAE = peaky routing = sensitive token = forbid (paper Eq. 1 rule:
/// forbid when TAE ≤ τ). With `margin_gamma`, also forbid when the top-2
/// margin is large: forbid iff (TAE ≤ τ) ∨ (margin ≥ γ).
pub fn tae_gate(topk_weights: &[f32], p: &GateParams) -> bool {
    let t = match p.temperature {
        Some(temp) => tae(&temperature_renorm(topk_weights, temp)),
        None => tae(topk_weights),
    };
    if (t as f64) <= p.tau {
        return false;
    }
    if let Some(gamma) = p.margin_gamma {
        if (prob_margin(topk_weights) as f64) >= gamma {
            return false;
        }
    }
    true
}

/// Distribution gate: `true` = substitution ALLOWED for this micro-batch.
///
/// δ = |requested ∩ CPU| / |requested| (paper Eq. 2); bypass replacement
/// (return false) when δ ≥ β — too many offloaded experts means broad
/// replacement would compound errors.
pub fn distribution_gate(cpu_requested: usize, total_requested: usize, beta: f64) -> bool {
    if total_requested == 0 {
        return true;
    }
    let delta = cpu_requested as f64 / total_requested as f64;
    delta < beta
}

/// Percentile calibration of τ (paper §3.1 (iii)): pick τ as the p-th
/// percentile of a layer's observed TAE distribution so the gate adapts
/// across models and domains.
pub fn calibrate_tau_percentile(observed_taes: &[f32], p: f64) -> f64 {
    if observed_taes.is_empty() {
        return 0.0;
    }
    percentile(observed_taes, p) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peaky_token_forbidden() {
        let p = GateParams { tau: 0.5, ..Default::default() };
        assert!(!tae_gate(&[0.97, 0.01, 0.01, 0.01], &p)); // TAE ~ 0.06
        assert!(tae_gate(&[0.3, 0.25, 0.25, 0.2], &p)); // TAE ~ 0.99
    }

    #[test]
    fn tau_one_forbids_everything() {
        let p = GateParams { tau: 1.0, ..Default::default() };
        assert!(!tae_gate(&[0.25, 0.25, 0.25, 0.25], &p));
    }

    #[test]
    fn margin_gate_extra_caution() {
        let p = GateParams {
            tau: 0.1,
            margin_gamma: Some(0.3),
            ..Default::default()
        };
        // High TAE but large top-2 margin -> forbidden by margin.
        assert!(!tae_gate(&[0.55, 0.2, 0.15, 0.1], &p));
        // Small margin -> allowed.
        assert!(tae_gate(&[0.3, 0.27, 0.23, 0.2], &p));
    }

    #[test]
    fn temperature_smooths_tae() {
        let w = [0.7f32, 0.2, 0.07, 0.03];
        let hot = temperature_renorm(&w, 1.2); // T > 1 flattens
        let cold = temperature_renorm(&w, 0.8); // T < 1 sharpens
        assert!(crate::util::math::tae(&hot) > crate::util::math::tae(&w));
        assert!(crate::util::math::tae(&cold) < crate::util::math::tae(&w));
        assert!((hot.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn distribution_gate_threshold() {
        assert!(distribution_gate(1, 10, 0.5)); // δ=0.1 < β
        assert!(!distribution_gate(5, 10, 0.5)); // δ=0.5 >= β
        assert!(!distribution_gate(10, 10, 0.5));
        assert!(distribution_gate(0, 0, 0.5)); // empty batch allowed
    }

    #[test]
    fn calibration_matches_percentile() {
        let taes: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let tau = calibrate_tau_percentile(&taes, 10.0);
        assert!((tau - 0.099).abs() < 0.02);
        assert_eq!(calibrate_tau_percentile(&[], 10.0), 0.0);
    }
}
