//! Dynamic batcher: admission queue with max-batch and wait-timeout
//! semantics, running on the serving stack's [`SimClock`].
//!
//! * Real-time clock — thread-safe blocking queue: an intake thread feeds
//!   a serving thread, and `next_admissions` waits on a condvar with the
//!   configured batching-window timeout.
//! * Virtual clock — the batching window is *modeled*: a partial batch
//!   "waits" by advancing the virtual clock by the timeout, then admits
//!   whatever is queued. No blocking, fully deterministic. Virtual mode is
//!   single-driver: producers must enqueue (and `close`) before or between
//!   `next_admissions` calls, as offline benchmark runs do — there is no
//!   other thread whose arrival could end the window early. An empty,
//!   still-open queue is therefore unservable (no future arrival can
//!   exist) and is treated as drained, with a warning — never a busy-spin.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::InferenceRequest;
use crate::util::clock::SimClock;

#[derive(Default)]
struct QueueState {
    queue: VecDeque<InferenceRequest>,
    closed: bool,
}

pub struct DynamicBatcher {
    state: Mutex<QueueState>,
    cv: Condvar,
    clock: SimClock,
    pub max_batch: usize,
    pub timeout: Duration,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration, clock: SimClock) -> Self {
        assert!(max_batch >= 1);
        Self {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            clock,
            max_batch,
            timeout,
        }
    }

    /// Enqueue a request, stamping its arrival time off the shared clock.
    pub fn submit(&self, mut req: InferenceRequest) {
        req.enqueued = self.clock.now();
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(req);
        self.cv.notify_all();
    }

    /// No more submissions; pending requests still drain.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Pull up to `room` requests. Blocks (or advances virtual time) until
    /// at least one request is available, the batching window elapses, or
    /// the batcher is closed. Returns `None` when closed and drained — and,
    /// in virtual mode, when the queue is empty while still open: virtual
    /// mode is single-driver, so no future arrival can exist and blocking
    /// (or spinning) would hang forever. That case warns, since it usually
    /// means a caller forgot `close()` before `run()`.
    pub fn next_admissions(&self, room: usize) -> Option<Vec<InferenceRequest>> {
        if room == 0 {
            return Some(Vec::new());
        }
        let want = room.min(self.max_batch);
        if self.clock.is_virtual() {
            let mut st = self.state.lock().unwrap();
            if st.queue.is_empty() {
                if !st.closed {
                    log::warn!(
                        "virtual-clock batcher polled while empty and open: \
                         treating as drained (submit + close before run)"
                    );
                }
                return None;
            }
            if st.queue.len() < want && !st.closed {
                // Partial batch: model holding the window open for more
                // arrivals (none can come — single-driver — so the full
                // timeout elapses).
                self.clock.advance(self.timeout);
            }
            let n = st.queue.len().min(want);
            return Some(st.queue.drain(..n).collect());
        }

        let deadline = Instant::now() + self.timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                // Wait briefly for more arrivals to batch together, unless
                // we already have a full batch — or the batcher is closed,
                // in which case no arrival can come (matching the virtual
                // path's closed-drains-immediately behavior).
                while st.queue.len() < want && !st.closed && Instant::now() < deadline {
                    let (guard, timeout_res) = self
                        .cv
                        .wait_timeout(st, deadline.saturating_duration_since(Instant::now()))
                        .unwrap();
                    st = guard;
                    if timeout_res.timed_out() || st.closed {
                        break;
                    }
                }
                let n = st.queue.len().min(want);
                return Some(st.queue.drain(..n).collect());
            }
            if st.closed {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(st, self.timeout).unwrap();
            st = guard;
        }
    }

    /// Non-blocking pull (scheduler already busy with active sequences).
    pub fn try_admissions(&self, room: usize) -> Vec<InferenceRequest> {
        if room == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        let n = st.queue.len().min(room).min(self.max_batch);
        st.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2], 4)
    }

    fn virt(max_batch: usize, timeout_ms: u64) -> (DynamicBatcher, SimClock) {
        let clock = SimClock::virtual_clock();
        (
            DynamicBatcher::new(max_batch, Duration::from_millis(timeout_ms), clock.clone()),
            clock,
        )
    }

    #[test]
    fn submit_and_drain() {
        let (b, _) = virt(4, 1);
        b.submit(req(1));
        b.submit(req(2));
        b.submit(req(3));
        let got = b.next_admissions(2).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn full_batch_admits_without_waiting() {
        let (b, clock) = virt(2, 50);
        b.submit(req(1));
        b.submit(req(2));
        let t0 = clock.now();
        assert_eq!(b.next_admissions(10).unwrap().len(), 2);
        assert_eq!(clock.now(), t0, "full batch must not spend the window");
    }

    #[test]
    fn partial_batch_spends_exactly_one_window() {
        let (b, clock) = virt(4, 50);
        b.submit(req(1));
        let t0 = clock.now();
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(
            clock.now() - t0,
            Duration::from_millis(50),
            "partial batch holds the window open for the full timeout"
        );
    }

    #[test]
    fn closed_partial_batch_skips_the_window() {
        let (b, clock) = virt(4, 50);
        b.submit(req(1));
        b.close();
        let t0 = clock.now();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert_eq!(clock.now(), t0, "closed batcher drains immediately");
    }

    #[test]
    fn empty_open_queue_is_drained_not_spun() {
        // Single-driver virtual mode: nothing can ever arrive while we
        // poll, so an empty open queue ends the serve loop (with a warning)
        // instead of spinning the virtual clock forever.
        let (b, clock) = virt(4, 7);
        let t0 = clock.now();
        assert!(b.next_admissions(4).is_none());
        assert_eq!(clock.now(), t0, "no virtual time burned on an unservable poll");
        // Later submissions still work: the batcher itself is not closed.
        b.submit(req(1));
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let (b, _) = virt(4, 1);
        b.submit(req(1));
        b.close();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert!(b.next_admissions(4).is_none());
    }

    #[test]
    fn max_batch_respected() {
        let (b, _) = virt(2, 1);
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_admissions(10).unwrap().len(), 2);
    }

    #[test]
    fn try_admissions_nonblocking() {
        let (b, _) = virt(4, 10_000);
        assert!(b.try_admissions(4).is_empty());
        b.submit(req(1));
        assert_eq!(b.try_admissions(4).len(), 1);
    }

    #[test]
    fn enqueue_timestamps_come_from_the_clock() {
        let (b, clock) = virt(4, 1);
        clock.advance(Duration::from_millis(30));
        b.submit(req(1));
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].enqueued, Duration::from_millis(30));
    }

    #[test]
    fn real_time_closed_partial_batch_drains_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_millis(200), SimClock::real_time());
        b.submit(req(1));
        b.close();
        let t0 = std::time::Instant::now();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "closed batcher must not wait out the batching window"
        );
    }

    #[test]
    fn cross_thread_submit_real_time() {
        let b = std::sync::Arc::new(DynamicBatcher::new(
            4,
            Duration::from_millis(50),
            SimClock::real_time(),
        ));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            b2.submit(req(42));
            b2.close();
        });
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].id, 42);
        t.join().unwrap();
    }
}
