//! Dynamic batcher: admission queue with max-batch and wait-timeout
//! semantics, running on the serving stack's [`SimClock`].
//!
//! * Real-time clock — thread-safe blocking queue: an intake thread feeds
//!   a serving thread, and `next_admissions` waits on a condvar with the
//!   configured batching-window timeout.
//! * Virtual clock — the batching window is *modeled* as a discrete-event
//!   simulation. Besides direct `submit` calls, the batcher owns an
//!   [`EventQueue`] of *staged* future arrivals
//!   (`stage_arrival`/`stage_process`): requests with known virtual
//!   timestamps, fed by the traffic subsystem's arrival processes
//!   ([`crate::traffic`]). The event-queue contract:
//!
//!   - Staged arrivals are **released** into the admission queue as the
//!     shared clock reaches their timestamps (at every poll).
//!   - An idle poll (empty admission queue) **jumps** the clock to the
//!     next staged arrival instead of giving up.
//!   - A partial batch holds the window open, releasing each staged
//!     arrival that lands inside the window at its own timestamp; a
//!     **full batch closes the window early** — virtual time advances
//!     only to the arrival that filled it, exactly as the real-time path
//!     returns early when a submitting thread completes the batch.
//!   - With no staged arrivals the old single-driver behavior is the
//!     degenerate case: a partial batch waits out the whole timeout, and
//!     an empty, still-open queue is unservable (no future arrival can
//!     exist) and treated as drained, with a warning — never a busy-spin.
//!
//!   `close()` only means "no more *direct* `submit` calls will be made":
//!   already-staged arrivals still release and drain, and hook-driven
//!   staging (closed-loop completions scheduling their follow-ups via
//!   `stage_arrival`) may continue after close — the serve loop ends when
//!   both queues are empty.
//!
//! With an [`AdmissionGate`] installed (admission control enabled), every
//! release/submit consults the gate at the request's own arrival instant:
//! shed requests never enter the queue — they accumulate in a shed log
//! the scheduler drains (`take_shed`) to account, trace, and report them.
//! Without a gate (the default) the queue is unbounded and the admit path
//! is byte-identical to the pre-admission system.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::admission::AdmissionGate;
use super::request::{InferenceRequest, ShedOutcome};
use crate::traffic::{ArrivalProcess, EventQueue};
use crate::util::clock::SimClock;

/// Saturation gauges sampled on *every* batcher poll (not just at
/// admission): overload onset is visible even when no request gets
/// through. Zero-valued with no polls; plain bookkeeping, never consulted
/// by any decision, so recording them cannot perturb goldens.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherPollStats {
    /// Total admission polls (blocking + non-blocking).
    pub polls: u64,
    /// Polls that observed a queue at least `max_batch` deep (the server
    /// cannot drain faster than one batch per step: saturation).
    pub saturated_polls: u64,
    /// Maximum instantaneous queue depth observed at any release, submit,
    /// or poll.
    pub max_depth: usize,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<InferenceRequest>,
    /// Staged future arrivals keyed on virtual time (traffic subsystem).
    events: EventQueue,
    closed: bool,
    /// Admission gate; `None` (default) = unbounded FIFO, byte-identical
    /// to the pre-admission batcher.
    gate: Option<AdmissionGate>,
    /// Shed decisions not yet drained by the scheduler.
    shed: Vec<ShedOutcome>,
    stats: BatcherPollStats,
}

impl QueueState {
    /// Release every staged arrival due by `now` into the admission queue,
    /// stamping `enqueued` (and `arrival_time`, when the generator did not)
    /// with the arrival timestamp — the instant the request "really"
    /// entered the queue on the virtual timeline. With a gate installed,
    /// each release is an admission decision at that instant: releases are
    /// processed in arrival order with the live depth, so a burst fills
    /// the queue head-first and the overflow is shed deterministically.
    fn release_due(&mut self, now: Duration) {
        for (at, mut req) in self.events.pop_due(now) {
            req.enqueued = at;
            if req.arrival_time.is_none() {
                req.arrival_time = Some(at);
            }
            if let Some(gate) = &self.gate {
                if let Some(reason) = gate.decide(self.queue.len(), &req) {
                    self.shed.push(ShedOutcome {
                        id: req.id,
                        slo: req.slo,
                        reason,
                        at,
                        arrived: req.arrived(),
                    });
                    continue;
                }
            }
            self.queue.push_back(req);
        }
        self.stats.max_depth = self.stats.max_depth.max(self.queue.len());
    }

    /// Per-poll saturation gauge (satellite: depth was previously sampled
    /// only at admission, hiding overload onset between admissions).
    fn note_poll(&mut self, max_batch: usize) {
        self.stats.polls += 1;
        self.stats.max_depth = self.stats.max_depth.max(self.queue.len());
        if self.queue.len() >= max_batch {
            self.stats.saturated_polls += 1;
        }
    }
}

pub struct DynamicBatcher {
    state: Mutex<QueueState>,
    cv: Condvar,
    clock: SimClock,
    pub max_batch: usize,
    pub timeout: Duration,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration, clock: SimClock) -> Self {
        assert!(max_batch >= 1);
        Self {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            clock,
            max_batch,
            timeout,
        }
    }

    /// Enqueue a request, stamping its arrival + enqueue time off the
    /// shared clock (unless the caller already stamped an arrival time).
    /// With an admission gate installed, the submit instant is the
    /// decision point and a shed request never enters the queue.
    pub fn submit(&self, mut req: InferenceRequest) {
        let now = self.clock.now();
        req.enqueued = now;
        if req.arrival_time.is_none() {
            req.arrival_time = Some(now);
        }
        let mut st = self.state.lock().unwrap();
        if let Some(gate) = &st.gate {
            if let Some(reason) = gate.decide(st.queue.len(), &req) {
                st.shed.push(ShedOutcome {
                    id: req.id,
                    slo: req.slo,
                    reason,
                    at: now,
                    arrived: req.arrived(),
                });
                self.cv.notify_all();
                return;
            }
        }
        st.queue.push_back(req);
        let depth = st.queue.len();
        st.stats.max_depth = st.stats.max_depth.max(depth);
        self.cv.notify_all();
    }

    /// Install the admission gate (admission control enabled). The
    /// scheduler sets this up before serving; `None` is never installed —
    /// the disabled config simply never calls this.
    pub fn set_admission_gate(&self, gate: AdmissionGate) {
        self.state.lock().unwrap().gate = Some(gate);
    }

    /// Drain shed decisions accumulated since the last call (arrival
    /// order). Empty — and allocation-free — without a gate.
    pub fn take_shed(&self) -> Vec<ShedOutcome> {
        std::mem::take(&mut self.state.lock().unwrap().shed)
    }

    /// Feed the gate's drain estimator with one completed request's
    /// per-slot service time. No-op without a gate.
    pub fn observe_service(&self, per_slot_s: f64) {
        if let Some(gate) = &mut self.state.lock().unwrap().gate {
            gate.observe_drain(per_slot_s);
        }
    }

    /// Feed the gate's prefill-tail estimator with one admitted request's
    /// admission→first-token seconds. No-op without a gate.
    pub fn observe_ttft_tail(&self, tail_s: f64) {
        if let Some(gate) = &mut self.state.lock().unwrap().gate {
            gate.observe_ttft_tail(tail_s);
        }
    }

    /// Saturation gauges sampled at every poll (see [`BatcherPollStats`]).
    pub fn poll_stats(&self) -> BatcherPollStats {
        self.state.lock().unwrap().stats
    }

    /// Stage a future arrival at virtual time `at`. The request is
    /// released into the admission queue when the shared clock reaches
    /// `at` (checked at every poll — under a real-time clock this is
    /// poll-granularity, so prefer `submit` from a thread there).
    pub fn stage_arrival(&self, at: Duration, req: InferenceRequest) {
        let mut st = self.state.lock().unwrap();
        st.events.push(at, req);
        self.cv.notify_all();
    }

    /// Drain an arrival process's open-loop stream into the staged event
    /// queue (closed-loop follow-ups arrive later via `stage_arrival`).
    pub fn stage_process(&self, process: &mut dyn ArrivalProcess) {
        let mut st = self.state.lock().unwrap();
        st.events.extend_from(process);
        self.cv.notify_all();
    }

    /// No more direct submissions; pending and staged requests still
    /// drain, and staging remains open for completion-hook follow-ups
    /// (closed-loop traffic schedules arrivals after close).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Requests in the admission queue (staged arrivals already due are
    /// released first, so this is the instantaneous queue depth).
    pub fn pending(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.release_due(self.clock.now());
        st.queue.len()
    }

    /// Staged future arrivals not yet released.
    pub fn staged(&self) -> usize {
        self.state.lock().unwrap().events.len()
    }

    /// The real-time intake path's sole wall-clock read. Virtual-clock
    /// serving never calls this; the genuine batching window in
    /// `next_admissions` is the one sanctioned consumer outside
    /// `util/clock.rs`.
    #[allow(clippy::disallowed_methods)]
    fn wall_now() -> Instant {
        // pallas-lint: allow(wall-clock, reason = "real-time intake: the batching window is a genuine wall-clock deadline")
        Instant::now()
    }

    /// Pull up to `room` requests. Blocks (or advances virtual time) until
    /// at least one request is available, the batching window elapses, or
    /// the batcher is closed. Returns `None` when closed and fully drained
    /// — and, in virtual mode, when both the admission queue and the
    /// staged event queue are empty while still open: no future arrival
    /// can exist, so blocking (or spinning) would hang forever. That case
    /// warns, since it usually means a caller forgot `close()` before
    /// `run()`.
    pub fn next_admissions(&self, room: usize) -> Option<Vec<InferenceRequest>> {
        if room == 0 {
            return Some(Vec::new());
        }
        let want = room.min(self.max_batch);
        if self.clock.is_virtual() {
            let mut st = self.state.lock().unwrap();
            st.release_due(self.clock.now());
            st.note_poll(self.max_batch);
            if st.queue.is_empty() {
                // Idle: jump the clock to the next staged arrival. With
                // nothing staged the poll is unservable (the degenerate
                // single-driver case).
                match st.events.peek_time() {
                    Some(t) => {
                        self.clock.advance_to(t);
                        st.release_due(self.clock.now());
                    }
                    None => {
                        if !st.closed {
                            log::warn!(
                                "virtual-clock batcher polled while empty and open with no \
                                 staged arrivals: treating as drained (submit/stage + close \
                                 before run)"
                            );
                        }
                        return None;
                    }
                }
            }
            if st.queue.len() < want && !(st.closed && st.events.is_empty()) {
                // Partial batch: hold the window open, releasing every
                // staged arrival that lands inside it. A full batch ends
                // the window early — the clock stops at the arrival that
                // filled it; otherwise the full timeout elapses. Closed
                // only short-circuits the window once nothing is staged:
                // "closed" means no *new* submissions, and with an empty
                // event queue no future arrival can exist — whereas staged
                // arrivals are exactly the future arrivals a real window
                // would wait for.
                let deadline = self.clock.now() + self.timeout;
                while st.queue.len() < want {
                    match st.events.peek_time().filter(|&t| t <= deadline) {
                        Some(t) => {
                            self.clock.advance_to(t);
                            st.release_due(t);
                        }
                        None => {
                            self.clock.advance_to(deadline);
                            break;
                        }
                    }
                }
            }
            let n = st.queue.len().min(want);
            return Some(st.queue.drain(..n).collect());
        }

        let deadline = Self::wall_now() + self.timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            st.release_due(self.clock.now());
            st.note_poll(self.max_batch);
            if !st.queue.is_empty() {
                // Wait briefly for more arrivals to batch together, unless
                // we already have a full batch — or the batcher is closed
                // with nothing staged, in which case no arrival can come
                // (matching the virtual path's closed-drains-immediately
                // behavior).
                while st.queue.len() < want
                    && !(st.closed && st.events.is_empty())
                    && Self::wall_now() < deadline
                {
                    let (guard, timeout_res) = self
                        .cv
                        .wait_timeout(st, deadline.saturating_duration_since(Self::wall_now()))
                        .unwrap();
                    st = guard;
                    st.release_due(self.clock.now());
                    if timeout_res.timed_out() || (st.closed && st.events.is_empty()) {
                        break;
                    }
                }
                let n = st.queue.len().min(want);
                return Some(st.queue.drain(..n).collect());
            }
            if st.closed && st.events.is_empty() {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(st, self.timeout).unwrap();
            st = guard;
        }
    }

    /// Non-blocking pull (scheduler already busy with active sequences).
    /// Staged arrivals that became due while the clock advanced — e.g.
    /// during decode steps — are released first, so mid-decode arrivals
    /// join the batch at the next step boundary.
    pub fn try_admissions(&self, room: usize) -> Vec<InferenceRequest> {
        if room == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        st.release_due(self.clock.now());
        st.note_poll(self.max_batch);
        let n = st.queue.len().min(room).min(self.max_batch);
        st.queue.drain(..n).collect()
    }

    /// Non-blocking pull with priority-aware batch composition: rank every
    /// queued request with `rank` (smaller wins; ties break on queue
    /// position, so equal-rank requests stay FIFO) and take the best
    /// `room`. The rest keep their arrival order. Only the scheduler's
    /// saturation path (admission control with `priority_compose`) calls
    /// this; FIFO admission never does, keeping the default byte-identical.
    pub fn try_admissions_ranked(
        &self,
        room: usize,
        rank: &dyn Fn(&InferenceRequest) -> (i64, i64),
    ) -> Vec<InferenceRequest> {
        if room == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        st.release_due(self.clock.now());
        st.note_poll(self.max_batch);
        let n = st.queue.len().min(room).min(self.max_batch);
        if n == 0 {
            return Vec::new();
        }
        let mut order: Vec<usize> = (0..st.queue.len()).collect();
        let keys: Vec<(i64, i64)> = st.queue.iter().map(|r| rank(r)).collect();
        // Deterministic total order: (key, original index) never ties.
        order.sort_by_key(|&i| (keys[i], i));
        let mut drained: Vec<Option<InferenceRequest>> = st.queue.drain(..).map(Some).collect();
        let mut picked = Vec::with_capacity(n);
        for &i in &order[..n] {
            picked.push(drained[i].take().expect("rank order indexes each queued request once"));
        }
        // Losers keep their arrival order for the next round.
        st.queue = drained.into_iter().flatten().collect();
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2], 4)
    }

    fn virt(max_batch: usize, timeout_ms: u64) -> (DynamicBatcher, SimClock) {
        let clock = SimClock::virtual_clock();
        (
            DynamicBatcher::new(max_batch, Duration::from_millis(timeout_ms), clock.clone()),
            clock,
        )
    }

    #[test]
    fn submit_and_drain() {
        let (b, _) = virt(4, 1);
        b.submit(req(1));
        b.submit(req(2));
        b.submit(req(3));
        let got = b.next_admissions(2).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn full_batch_admits_without_waiting() {
        let (b, clock) = virt(2, 50);
        b.submit(req(1));
        b.submit(req(2));
        let t0 = clock.now();
        assert_eq!(b.next_admissions(10).unwrap().len(), 2);
        assert_eq!(clock.now(), t0, "full batch must not spend the window");
    }

    #[test]
    fn partial_batch_spends_exactly_one_window() {
        let (b, clock) = virt(4, 50);
        b.submit(req(1));
        let t0 = clock.now();
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(
            clock.now() - t0,
            Duration::from_millis(50),
            "partial batch holds the window open for the full timeout"
        );
    }

    #[test]
    fn closed_partial_batch_skips_the_window() {
        let (b, clock) = virt(4, 50);
        b.submit(req(1));
        b.close();
        let t0 = clock.now();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert_eq!(clock.now(), t0, "closed batcher drains immediately");
    }

    #[test]
    fn empty_open_queue_is_drained_not_spun() {
        // Single-driver virtual mode: nothing queued, nothing staged, so an
        // empty open queue ends the serve loop (with a warning) instead of
        // spinning the virtual clock forever.
        let (b, clock) = virt(4, 7);
        let t0 = clock.now();
        assert!(b.next_admissions(4).is_none());
        assert_eq!(clock.now(), t0, "no virtual time burned on an unservable poll");
        // Later submissions still work: the batcher itself is not closed.
        b.submit(req(1));
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let (b, _) = virt(4, 1);
        b.submit(req(1));
        b.close();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert!(b.next_admissions(4).is_none());
    }

    #[test]
    fn max_batch_respected() {
        let (b, _) = virt(2, 1);
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_admissions(10).unwrap().len(), 2);
    }

    #[test]
    fn try_admissions_nonblocking() {
        let (b, _) = virt(4, 10_000);
        assert!(b.try_admissions(4).is_empty());
        b.submit(req(1));
        assert_eq!(b.try_admissions(4).len(), 1);
    }

    #[test]
    fn enqueue_timestamps_come_from_the_clock() {
        let (b, clock) = virt(4, 1);
        clock.advance(Duration::from_millis(30));
        b.submit(req(1));
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].enqueued, Duration::from_millis(30));
        assert_eq!(got[0].arrival_time, Some(Duration::from_millis(30)));
    }

    // --- staged-arrival (event queue) contract ---

    #[test]
    fn staged_arrival_fills_batch_and_closes_window_early() {
        // The acceptance case: one request queued, the batch-filling
        // arrival staged 10 ms out, window 50 ms. The window must close at
        // the arrival that filled it — t = 10 ms, not 50 ms.
        let (b, clock) = virt(2, 50);
        b.submit(req(1));
        b.stage_arrival(Duration::from_millis(10), req(2));
        let got = b.next_admissions(2).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(
            clock.now(),
            Duration::from_millis(10),
            "full batch must close the window at the filling arrival"
        );
        assert_eq!(got[1].enqueued, Duration::from_millis(10));
    }

    #[test]
    fn staged_arrival_beyond_window_does_not_extend_it() {
        let (b, clock) = virt(2, 50);
        b.submit(req(1));
        b.stage_arrival(Duration::from_millis(200), req(2));
        let got = b.next_admissions(2).unwrap();
        assert_eq!(got.len(), 1, "far-future arrival must not join this window");
        assert_eq!(clock.now(), Duration::from_millis(50));
        assert_eq!(b.staged(), 1);
    }

    #[test]
    fn idle_batcher_jumps_to_next_staged_arrival() {
        let (b, clock) = virt(4, 5);
        b.stage_arrival(Duration::from_millis(30), req(1));
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].enqueued, Duration::from_millis(30));
        // Jumped to the arrival, then held the (empty) window open.
        assert_eq!(clock.now(), Duration::from_millis(35));
    }

    #[test]
    fn window_releases_multiple_staged_arrivals_in_order() {
        let (b, clock) = virt(3, 50);
        b.submit(req(1));
        b.stage_arrival(Duration::from_millis(20), req(3));
        b.stage_arrival(Duration::from_millis(10), req(2));
        let got = b.next_admissions(3).unwrap();
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(clock.now(), Duration::from_millis(20), "window closed on the filler");
    }

    #[test]
    fn try_admissions_releases_due_staged_arrivals() {
        let (b, clock) = virt(4, 10_000);
        b.stage_arrival(Duration::from_millis(10), req(1));
        assert!(b.try_admissions(4).is_empty(), "not due yet");
        clock.advance(Duration::from_millis(15));
        let got = b.try_admissions(4);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].enqueued, Duration::from_millis(10), "stamped at arrival, not release");
    }

    #[test]
    fn close_still_drains_staged_arrivals() {
        let (b, clock) = virt(4, 50);
        b.stage_arrival(Duration::from_millis(10), req(1));
        b.close();
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(clock.now(), Duration::from_millis(10), "closed: no window wait");
        assert!(b.next_admissions(4).is_none());
    }

    #[test]
    fn generator_arrival_time_survives_release() {
        let (b, clock) = virt(4, 1);
        // A generator-stamped arrival keeps its own arrival_time.
        b.stage_arrival(
            Duration::from_millis(5),
            req(1).arriving_at(Duration::from_millis(5)),
        );
        clock.advance(Duration::from_millis(20));
        let got = b.try_admissions(4);
        assert_eq!(got[0].arrival_time, Some(Duration::from_millis(5)));
    }

    #[test]
    #[allow(clippy::disallowed_methods)]
    fn real_time_closed_partial_batch_drains_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_millis(200), SimClock::real_time());
        b.submit(req(1));
        b.close();
        // pallas-lint: allow(wall-clock, reason = "test measures that the real-time path returns without real waiting")
        let t0 = std::time::Instant::now();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        // pallas-lint: allow(wall-clock, reason = "the wall-clock bound is the assertion under test")
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_millis(150),
            "closed batcher must not wait out the batching window"
        );
    }

    #[test]
    fn cross_thread_submit_real_time() {
        let b = std::sync::Arc::new(DynamicBatcher::new(
            4,
            Duration::from_millis(50),
            SimClock::real_time(),
        ));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            b2.submit(req(42));
            b2.close();
        });
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].id, 42);
        t.join().unwrap();
    }

    #[test]
    fn real_time_releases_due_staged_arrivals() {
        let b = DynamicBatcher::new(4, Duration::from_millis(20), SimClock::real_time());
        // Due immediately (t=0 is already in the past for a real clock).
        b.stage_arrival(Duration::ZERO, req(7));
        b.close();
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].id, 7);
    }

    // --- admission gate / shed / poll-stat contract ---

    use crate::config::AdmissionControl;
    use crate::server::request::{ShedReason, SloClass};

    fn gated(cap: usize, max_batch: usize) -> (DynamicBatcher, SimClock) {
        let (b, clock) = virt(max_batch, 1);
        let ac = AdmissionControl::overload_protect(0.25, 2.5, cap);
        b.set_admission_gate(AdmissionGate::from_config(&ac).expect("enabled config"));
        (b, clock)
    }

    #[test]
    fn queue_cap_bounds_depth_and_sheds_overflow() {
        let (b, clock) = gated(2, 8);
        for i in 0..5 {
            b.stage_arrival(Duration::from_millis(i), req(i as u64));
        }
        clock.advance(Duration::from_millis(10));
        let _ = b.pending(); // forces release of due arrivals through the gate
        assert!(b.pending() <= 2, "hard cap must bound instantaneous depth");
        let shed = b.take_shed();
        assert_eq!(shed.len(), 3);
        assert!(shed.iter().all(|s| s.reason == ShedReason::QueueFull));
        // First-come-first-kept: ids 0,1 admitted, 2,3,4 shed.
        assert_eq!(shed.iter().map(|s| s.id).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert!(b.take_shed().is_empty(), "take_shed drains");
    }

    #[test]
    fn submit_is_gated_too() {
        let (b, _) = gated(1, 8);
        b.submit(req(1));
        b.submit(req(2));
        assert_eq!(b.pending(), 1);
        let shed = b.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 2);
        assert_eq!(shed[0].reason, ShedReason::QueueFull);
    }

    #[test]
    fn shed_records_arrival_instants() {
        let (b, clock) = gated(1, 8);
        b.stage_arrival(Duration::from_millis(3), req(1));
        b.stage_arrival(Duration::from_millis(9), req(2));
        clock.advance(Duration::from_millis(20));
        let _ = b.pending(); // release due arrivals through the gate
        let shed = b.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].at, Duration::from_millis(9), "decision at its own arrival");
        assert_eq!(shed[0].arrived, Duration::from_millis(9));
    }

    #[test]
    fn deadline_unmeetable_sheds_only_after_estimate() {
        let (b, _) = gated(0, 8);
        for i in 0..64 {
            b.submit(req(i));
        }
        assert_eq!(b.pending(), 64, "cold estimator admits everything");
        // 10 ms/slot behind a 64-deep queue blows the 0.25 s budget.
        b.observe_service(0.010);
        b.submit(req(100));
        let shed = b.take_shed();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].reason, ShedReason::DeadlineUnmeetable);
        // A Batch-class request with the same backlog fits its 2.5 s budget.
        b.submit(req(101).with_slo(SloClass::Batch));
        assert!(b.take_shed().is_empty());
    }

    #[test]
    fn poll_stats_gauge_saturation_without_a_gate() {
        let (b, _) = virt(2, 1);
        for i in 0..6 {
            b.submit(req(i));
        }
        assert_eq!(b.next_admissions(2).unwrap().len(), 2);
        let _ = b.try_admissions(0);
        let s = b.poll_stats();
        assert!(s.polls >= 2);
        assert!(s.saturated_polls >= 2, "queue ≥ max_batch on both polls");
        assert_eq!(s.max_depth, 6, "peak depth seen at submit, not only at polls");
    }

    #[test]
    fn ranked_admissions_take_best_and_keep_rest_in_order() {
        let (b, _) = virt(8, 1);
        for i in 0..5 {
            b.submit(req(i));
        }
        // Rank: even ids first (key 0), odds later (key 1).
        let got = b.try_admissions_ranked(2, &|r| ((r.id % 2) as i64, 0));
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        // Losers retain arrival order.
        let rest = b.try_admissions(8);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
    }

    #[test]
    fn ranked_admissions_tie_breaks_fifo() {
        let (b, _) = virt(8, 1);
        for i in 0..4 {
            b.submit(req(i));
        }
        let got = b.try_admissions_ranked(3, &|_| (0, 0));
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
