//! Dynamic batcher: admission queue with max-batch and wait-timeout
//! semantics. Thread-safe so an intake thread can feed a serving thread.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::InferenceRequest;

#[derive(Default)]
struct QueueState {
    queue: VecDeque<InferenceRequest>,
    closed: bool,
}

pub struct DynamicBatcher {
    state: Mutex<QueueState>,
    cv: Condvar,
    pub max_batch: usize,
    pub timeout: Duration,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, timeout: Duration) -> Self {
        assert!(max_batch >= 1);
        Self {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            max_batch,
            timeout,
        }
    }

    pub fn submit(&self, req: InferenceRequest) {
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(req);
        self.cv.notify_all();
    }

    /// No more submissions; pending requests still drain.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Pull up to `room` requests. Blocks until at least one request is
    /// available, the timeout elapses with a non-empty queue, or the
    /// batcher is closed. Returns `None` when closed and drained.
    pub fn next_admissions(&self, room: usize) -> Option<Vec<InferenceRequest>> {
        if room == 0 {
            return Some(Vec::new());
        }
        let deadline = Instant::now() + self.timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                // Wait briefly for more arrivals to batch together, unless
                // we already have a full batch.
                while st.queue.len() < room.min(self.max_batch) && Instant::now() < deadline {
                    let (guard, timeout_res) = self
                        .cv
                        .wait_timeout(st, deadline.saturating_duration_since(Instant::now()))
                        .unwrap();
                    st = guard;
                    if timeout_res.timed_out() || st.closed {
                        break;
                    }
                }
                let n = st.queue.len().min(room).min(self.max_batch);
                return Some(st.queue.drain(..n).collect());
            }
            if st.closed {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(st, self.timeout).unwrap();
            st = guard;
        }
    }

    /// Non-blocking pull (scheduler already busy with active sequences).
    pub fn try_admissions(&self, room: usize) -> Vec<InferenceRequest> {
        if room == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        let n = st.queue.len().min(room).min(self.max_batch);
        st.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2], 4)
    }

    #[test]
    fn submit_and_drain() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        b.submit(req(1));
        b.submit(req(2));
        b.submit(req(3));
        let got = b.next_admissions(2).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        b.submit(req(1));
        b.close();
        assert_eq!(b.next_admissions(4).unwrap().len(), 1);
        assert!(b.next_admissions(4).is_none());
    }

    #[test]
    fn max_batch_respected() {
        let b = DynamicBatcher::new(2, Duration::from_millis(1));
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_admissions(10).unwrap().len(), 2);
    }

    #[test]
    fn try_admissions_nonblocking() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        assert!(b.try_admissions(4).is_empty());
        b.submit(req(1));
        assert_eq!(b.try_admissions(4).len(), 1);
    }

    #[test]
    fn cross_thread_submit() {
        let b = std::sync::Arc::new(DynamicBatcher::new(4, Duration::from_millis(50)));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            b2.submit(req(42));
            b2.close();
        });
        let got = b.next_admissions(4).unwrap();
        assert_eq!(got[0].id, 42);
        t.join().unwrap();
    }
}
