//! Aggregate serving metrics: throughput, TTFT/latency distributions,
//! stall accounting — the numbers the paper's tables report.
//!
//! All timestamps come from the serving stack's [`SimClock`], so under a
//! virtual clock every figure here is a deterministic simulated
//! measurement and under a real-time clock a genuine elapsed one.

use std::time::Duration;

use crate::server::request::ShedOutcome;
use crate::stats::{Counters, Summary};
use crate::util::clock::SimClock;

#[derive(Debug)]
pub struct ServerMetrics {
    clock: SimClock,
    /// Clock timestamp at which this metrics window opened.
    pub started: Duration,
    pub ttft: Summary,
    /// TTFT restricted to admitted `SloClass::Interactive` requests (the
    /// population whose p99.9 the overload acceptance bound is about).
    /// Every request is Interactive when SLO tagging is unused, so this
    /// mirrors `ttft` then; never serialized by the pre-admission
    /// emitters.
    pub ttft_interactive: Summary,
    /// TTFT restricted to admitted `SloClass::Batch` requests.
    pub ttft_batch: Summary,
    /// Arrival → admission wait (the load-dependent part of TTFT).
    pub queue_delay: Summary,
    /// Per-sequence time between consecutive tokens (decode-step
    /// intervals as each request experienced them, admission pauses
    /// included).
    pub tbt: Summary,
    pub request_latency: Summary,
    pub step_latency: Summary,
    pub stall_seconds: Summary,
    /// Admission-queue depth sampled at every decode-step boundary.
    pub queue_depth: Summary,
    pub tokens_out: u64,
    pub requests_done: u64,
    /// Requests annotated as degraded (at least one of their steps ran a
    /// degradation-waterfall arm during a fault). Always 0 without an
    /// active fault plan.
    pub degraded_requests: u64,
    /// Requests refused by the admission gate (never admitted, disjoint
    /// from `requests_done`). Always 0 with admission control disabled.
    pub shed_requests: u64,
    pub shed_interactive: u64,
    pub shed_batch: u64,
    /// Shed breakdown by reason.
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    /// Brownout enter+exit edges over the run.
    pub brownout_transitions: u64,
    /// Total simulated seconds spent browned out.
    pub brownout_dwell_s: f64,
    /// Every shed decision in arrival order (typed outcomes; per-seed
    /// byte-identical — determinism-contract tests replay this log).
    pub shed_log: Vec<ShedOutcome>,
    pub counters: Counters,
}

impl ServerMetrics {
    pub fn new(clock: SimClock) -> Self {
        let started = clock.now();
        Self {
            clock,
            started,
            ttft: Summary::new(),
            ttft_interactive: Summary::new(),
            ttft_batch: Summary::new(),
            queue_delay: Summary::new(),
            tbt: Summary::new(),
            request_latency: Summary::new(),
            step_latency: Summary::new(),
            stall_seconds: Summary::new(),
            queue_depth: Summary::new(),
            tokens_out: 0,
            requests_done: 0,
            degraded_requests: 0,
            shed_requests: 0,
            shed_interactive: 0,
            shed_batch: 0,
            shed_queue_full: 0,
            shed_deadline: 0,
            brownout_transitions: 0,
            brownout_dwell_s: 0.0,
            shed_log: Vec::new(),
            counters: Counters::new(),
        }
    }

    /// Seconds (virtual or real) elapsed since this window opened.
    pub fn elapsed_seconds(&self) -> f64 {
        self.clock.since(self.started)
    }

    /// Decode throughput over the whole run (tokens/second).
    pub fn tokens_per_second(&self) -> f64 {
        let el = self.elapsed_seconds();
        if el <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / el
        }
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "throughput: {:.2} tok/s | requests: {} ({} degraded) | tokens: {}\n\
             ttft:    {}\n\
             qdelay:  {}\n\
             tbt:     {}\n\
             latency: {}\n\
             step:    {}\n\
             stalls:  {}\n\
             qdepth:  {}",
            self.tokens_per_second(),
            self.requests_done,
            self.degraded_requests,
            self.tokens_out,
            self.ttft.report("s"),
            self.queue_delay.report("s"),
            self.tbt.report("s"),
            self.request_latency.report("s"),
            self.step_latency.report("s"),
            self.stall_seconds.report("s"),
            self.queue_depth.report(""),
        );
        // Overload lines appear only when the admission layer acted, so
        // the default (admission-disabled) report is byte-identical to
        // the pre-admission format.
        if self.shed_requests > 0 || self.brownout_transitions > 0 {
            out.push_str(&format!(
                "\nshed:    {} (interactive {}, batch {}; queue-full {}, deadline {})\n\
                 brownout: {} transitions, {:.4} s dwell",
                self.shed_requests,
                self.shed_interactive,
                self.shed_batch,
                self.shed_queue_full,
                self.shed_deadline,
                self.brownout_transitions,
                self.brownout_dwell_s,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_tokens_in_virtual_time() {
        let clock = SimClock::virtual_clock();
        let mut m = ServerMetrics::new(clock.clone());
        m.tokens_out = 100;
        clock.advance(Duration::from_secs(2));
        assert!((m.tokens_per_second() - 50.0).abs() < 1e-9);
        m.ttft.add(0.5);
        assert!(m.report().contains("tok/s"));
    }

    #[test]
    fn zero_elapsed_is_safe() {
        let m = ServerMetrics::new(SimClock::virtual_clock());
        assert_eq!(m.tokens_per_second(), 0.0);
    }

    #[test]
    fn window_starts_at_construction() {
        let clock = SimClock::virtual_clock();
        clock.advance(Duration::from_secs(5));
        let mut m = ServerMetrics::new(clock.clone());
        m.tokens_out = 10;
        clock.advance(Duration::from_secs(1));
        assert!((m.tokens_per_second() - 10.0).abs() < 1e-9);
    }
}
