//! Aggregate serving metrics: throughput, TTFT/latency distributions,
//! stall accounting — the numbers the paper's tables report.

use std::time::Instant;

use crate::stats::{Counters, Summary};

#[derive(Debug)]
pub struct ServerMetrics {
    pub started: Instant,
    pub ttft: Summary,
    pub request_latency: Summary,
    pub step_latency: Summary,
    pub stall_seconds: Summary,
    pub tokens_out: u64,
    pub requests_done: u64,
    pub counters: Counters,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            ttft: Summary::new(),
            request_latency: Summary::new(),
            step_latency: Summary::new(),
            stall_seconds: Summary::new(),
            tokens_out: 0,
            requests_done: 0,
            counters: Counters::new(),
        }
    }

    /// Decode throughput over the whole run (tokens/second).
    pub fn tokens_per_second(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / el
        }
    }

    pub fn report(&self) -> String {
        format!(
            "throughput: {:.2} tok/s | requests: {} | tokens: {}\n\
             ttft:    {}\n\
             latency: {}\n\
             step:    {}\n\
             stalls:  {}",
            self.tokens_per_second(),
            self.requests_done,
            self.tokens_out,
            self.ttft.report("s"),
            self.request_latency.report("s"),
            self.step_latency.report("s"),
            self.stall_seconds.report("s"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_tokens() {
        let mut m = ServerMetrics::new();
        m.tokens_out = 100;
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(m.tokens_per_second() > 0.0);
        m.ttft.add(0.5);
        assert!(m.report().contains("tok/s"));
    }
}
