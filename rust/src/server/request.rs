//! Request / response types.

use std::time::Duration;

#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Clock timestamp ([`crate::util::clock::SimClock::now`]) at which the
    /// request *arrived* at the serving system: stamped by the traffic
    /// generator for event-queue arrivals (`DynamicBatcher::stage_arrival`),
    /// or set to the submit time for direct `DynamicBatcher::submit` calls.
    /// Queue delay is measured from this point.
    pub arrival_time: Option<Duration>,
    /// Clock timestamp at which the request entered the batcher queue;
    /// stamped by `DynamicBatcher::submit` (or, for staged arrivals, the
    /// arrival timestamp at which the event queue released it).
    pub enqueued: Duration,
    /// Teacher-forced token stream for scored (accuracy) runs.
    pub force_tokens: Option<Vec<i32>>,
}

impl InferenceRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        Self {
            id,
            prompt,
            max_new,
            arrival_time: None,
            enqueued: Duration::ZERO,
            force_tokens: None,
        }
    }

    pub fn forced(mut self, tokens: Vec<i32>) -> Self {
        self.force_tokens = Some(tokens);
        self
    }

    /// Builder: stamp an explicit arrival timestamp (traffic generators).
    pub fn arriving_at(mut self, at: Duration) -> Self {
        self.arrival_time = Some(at);
        self
    }

    /// The timestamp queue delay and end-to-end latency are measured from:
    /// the explicit arrival time when stamped, else the enqueue time.
    pub fn arrived(&self) -> Duration {
        self.arrival_time.unwrap_or(self.enqueued)
    }
}

#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// The model's own argmax at each position (prefill + decode steps);
    /// equals `tokens` on free-running runs, diverges under forcing.
    pub predictions: Vec<i32>,
    /// Per-position logits aligned with `predictions` (prefill first),
    /// present when the engine records them.
    pub logits: Vec<Vec<f32>>,
    /// Seconds (virtual or real) from arrival to first token (prefill
    /// complete).
    pub ttft: f64,
    /// Absolute clock timestamp (seconds since the clock's epoch, virtual
    /// or real) at which the first token was produced.
    pub first_token_time: f64,
    /// Seconds (virtual or real) from arrival to completion.
    pub total: f64,
    /// True when any step this request took part in was served degraded:
    /// a fault-displaced expert was covered by a replica or buddy, a
    /// demand fetch needed retries, or an expert was dropped after the
    /// degradation waterfall exhausted (always false without a fault
    /// plan).
    pub degraded: bool,
}
