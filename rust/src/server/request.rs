//! Request / response types, SLO classes, and typed request outcomes.

use std::time::Duration;

/// Service-level-objective class of a request. Deadline budgets (TTFT /
/// end-to-end) for each class live in
/// [`crate::config::AdmissionControl`]; the request only carries its
/// class. With admission control disabled (the default) every request is
/// `Interactive` and the class is inert — no code path reads it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloClass {
    /// Latency-sensitive traffic with a tight TTFT budget.
    Interactive,
    /// Throughput traffic with a loose budget; first to be shed or
    /// deprioritized at saturation.
    Batch,
}

impl SloClass {
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }
}

/// Why the admission gate refused a request (typed shed outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The staging queue hit its hard depth cap (backpressure).
    QueueFull,
    /// The deadline estimator (live queue depth × recent per-slot drain
    /// time + recent prefill tail) says the class's TTFT budget is
    /// already unmeetable at staging time.
    DeadlineUnmeetable,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::DeadlineUnmeetable => "deadline_unmeetable",
        }
    }
}

/// Record of a load-shed decision: the request was refused at staging and
/// never admitted. Deterministic per seed (the decision reads only the
/// virtual clock and seeded queue state).
#[derive(Debug, Clone)]
pub struct ShedOutcome {
    pub id: u64,
    pub slo: SloClass,
    pub reason: ShedReason,
    /// Virtual instant the shed decision was made (the request's staging
    /// release / submit time).
    pub at: Duration,
    /// The request's stamped arrival time.
    pub arrived: Duration,
}

/// Terminal outcome of a request: completed with a response, or shed by
/// the admission gate. The completion hook receives this, so closed-loop
/// traffic sees sheds as completions too (the simulated user gets the
/// rejection, thinks, and sends their next request — that is the
/// backpressure path).
#[derive(Debug, Clone)]
pub enum RequestOutcome {
    Done(InferenceResponse),
    Shed(ShedOutcome),
}

#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// SLO class; defaults to `Interactive` and is inert unless admission
    /// control is enabled.
    pub slo: SloClass,
    /// Clock timestamp ([`crate::util::clock::SimClock::now`]) at which the
    /// request *arrived* at the serving system: stamped by the traffic
    /// generator for event-queue arrivals (`DynamicBatcher::stage_arrival`),
    /// or set to the submit time for direct `DynamicBatcher::submit` calls.
    /// Queue delay is measured from this point.
    pub arrival_time: Option<Duration>,
    /// Clock timestamp at which the request entered the batcher queue;
    /// stamped by `DynamicBatcher::submit` (or, for staged arrivals, the
    /// arrival timestamp at which the event queue released it).
    pub enqueued: Duration,
    /// Teacher-forced token stream for scored (accuracy) runs.
    pub force_tokens: Option<Vec<i32>>,
}

impl InferenceRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        Self {
            id,
            prompt,
            max_new,
            slo: SloClass::Interactive,
            arrival_time: None,
            enqueued: Duration::ZERO,
            force_tokens: None,
        }
    }

    pub fn forced(mut self, tokens: Vec<i32>) -> Self {
        self.force_tokens = Some(tokens);
        self
    }

    /// Builder: tag the request with an SLO class.
    pub fn with_slo(mut self, slo: SloClass) -> Self {
        self.slo = slo;
        self
    }

    /// Builder: stamp an explicit arrival timestamp (traffic generators).
    pub fn arriving_at(mut self, at: Duration) -> Self {
        self.arrival_time = Some(at);
        self
    }

    /// The timestamp queue delay and end-to-end latency are measured from:
    /// the explicit arrival time when stamped, else the enqueue time.
    pub fn arrived(&self) -> Duration {
        self.arrival_time.unwrap_or(self.enqueued)
    }
}

#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// SLO class the request carried (always `Interactive` when admission
    /// control / SLO tagging is unused).
    pub slo: SloClass,
    pub tokens: Vec<i32>,
    /// The model's own argmax at each position (prefill + decode steps);
    /// equals `tokens` on free-running runs, diverges under forcing.
    pub predictions: Vec<i32>,
    /// Per-position logits aligned with `predictions` (prefill first),
    /// present when the engine records them.
    pub logits: Vec<Vec<f32>>,
    /// Seconds (virtual or real) from arrival to first token (prefill
    /// complete).
    pub ttft: f64,
    /// Absolute clock timestamp (seconds since the clock's epoch, virtual
    /// or real) at which the first token was produced.
    pub first_token_time: f64,
    /// Seconds (virtual or real) from arrival to completion.
    pub total: f64,
    /// True when any step this request took part in was served degraded:
    /// a fault-displaced expert was covered by a replica or buddy, a
    /// demand fetch needed retries, or an expert was dropped after the
    /// degradation waterfall exhausted (always false without a fault
    /// plan).
    pub degraded: bool,
}
