//! Request / response types.

use std::time::Duration;

#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Clock timestamp ([`crate::util::clock::SimClock::now`]) at which the
    /// request entered the batcher; stamped by `DynamicBatcher::submit`.
    pub enqueued: Duration,
    /// Teacher-forced token stream for scored (accuracy) runs.
    pub force_tokens: Option<Vec<i32>>,
}

impl InferenceRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        Self { id, prompt, max_new, enqueued: Duration::ZERO, force_tokens: None }
    }

    pub fn forced(mut self, tokens: Vec<i32>) -> Self {
        self.force_tokens = Some(tokens);
        self
    }
}

#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// The model's own argmax at each position (prefill + decode steps);
    /// equals `tokens` on free-running runs, diverges under forcing.
    pub predictions: Vec<i32>,
    /// Per-position logits aligned with `predictions` (prefill first),
    /// present when the engine records them.
    pub logits: Vec<Vec<f32>>,
    /// Seconds (virtual or real) from enqueue to first token (prefill
    /// complete).
    pub ttft: f64,
    /// Seconds (virtual or real) from enqueue to completion.
    pub total: f64,
}
