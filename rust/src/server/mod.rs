//! The serving front-end: request types, the dynamic batcher, continuous-
//! batching scheduler, per-request metrics, and the SLO-aware admission /
//! overload-protection layer — the vLLM-router-shaped substrate the
//! paper's runtime plugs into.

mod admission;
mod batcher;
mod metrics;
mod request;
mod scheduler;

pub use admission::{AdmissionGate, BrownoutController, BrownoutEdge, SloBudgets};
pub use batcher::{BatcherPollStats, DynamicBatcher};
pub use metrics::ServerMetrics;
pub use request::{
    InferenceRequest, InferenceResponse, RequestOutcome, ShedOutcome, ShedReason, SloClass,
};
pub use scheduler::{CompletionHook, Server};
