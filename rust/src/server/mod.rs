//! The serving front-end: request types, the dynamic batcher, continuous-
//! batching scheduler, and per-request metrics — the vLLM-router-shaped
//! substrate the paper's runtime plugs into.

mod batcher;
mod metrics;
mod request;
mod scheduler;

pub use batcher::DynamicBatcher;
pub use metrics::ServerMetrics;
pub use request::{InferenceRequest, InferenceResponse};
pub use scheduler::{CompletionHook, Server};
