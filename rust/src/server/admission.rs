//! SLO-aware admission control and brownout degradation.
//!
//! Two small, deterministic state machines implement the overload policy:
//!
//! * [`AdmissionGate`] — lives *inside* the batcher's queue mutex and
//!   decides, at the instant a request is staged into the admission queue
//!   (its virtual arrival timestamp), whether it is admitted or **shed**:
//!
//!   - `ShedReason::QueueFull` — the queue already holds `queue_cap`
//!     requests. This is the hard backpressure bound: depth can never
//!     exceed the cap, and closed-loop populations feel the rejection
//!     through the completion hook (a shed is a completion too).
//!   - `ShedReason::DeadlineUnmeetable` — the gate's live estimate of
//!     time-to-first-token (`queue depth × EWMA per-slot drain interval +
//!     EWMA prefill tail`) already exceeds the request's class TTFT
//!     budget. The estimators are fed by the scheduler from completed
//!     work, so the gate never sheds on a cold estimator — the first
//!     requests of a run are always admitted.
//!
//! * [`BrownoutController`] — owned by the scheduler loop. An EWMA of
//!   admitted queue delay, normalized by the Interactive TTFT budget, is
//!   the overload signal; crossing `enter_ratio` trips brownout and the
//!   engine shifts miss handling from demand-fetch toward ψ buddy
//!   substitution (permissive brownout τ) and tightens the transfer
//!   deadline so stragglers take the PR-7 degradation waterfall instead
//!   of stalling the batch. Dropping back below `exit_ratio`
//!   (hysteresis) relaxes both knobs to their configured values.
//!
//! Determinism contract (the `FaultPlan` shape): every decision reads
//! only the shared virtual clock, the queue state under its lock, and
//! EWMAs of virtual-time measurements — no wall clock, no ambient RNG.
//! With `AdmissionControl::enabled == false` neither object is even
//! constructed, so the disabled system is byte-identical to the
//! pre-admission one. Decisions for a given seed are byte-identical
//! across `PALLAS_THREADS` settings because all inputs are
//! orchestration-thread state.

use std::time::Duration;

use crate::config::AdmissionControl;
use crate::server::request::{InferenceRequest, ShedReason, SloClass};

/// Per-class TTFT budgets, simulated seconds.
#[derive(Debug, Clone, Copy)]
pub struct SloBudgets {
    pub interactive_ttft_s: f64,
    pub batch_ttft_s: f64,
}

impl SloBudgets {
    pub fn from_config(ac: &AdmissionControl) -> Self {
        Self {
            interactive_ttft_s: ac.interactive_ttft_slo_s,
            batch_ttft_s: ac.batch_ttft_slo_s,
        }
    }

    pub fn ttft_for(&self, slo: SloClass) -> f64 {
        match slo {
            SloClass::Interactive => self.interactive_ttft_s,
            SloClass::Batch => self.batch_ttft_s,
        }
    }
}

/// The staging-time shed decision. See the module docs for semantics.
#[derive(Debug, Clone)]
pub struct AdmissionGate {
    queue_cap: usize,
    shed_unmeetable: bool,
    budgets: SloBudgets,
    alpha: f64,
    /// EWMA of per-queue-slot drain interval: how long one queued request
    /// waits per request ahead of it (completed-request service time
    /// divided by the batch width it shared).
    drain_ewma_s: f64,
    /// EWMA of admission → first-token time (the prefill tail a request
    /// pays after its queue wait).
    ttft_tail_ewma_s: f64,
    /// The estimators have been fed at least once; deadline shedding is
    /// armed only then.
    have_estimate: bool,
}

impl AdmissionGate {
    /// `None` when admission control is disabled: the degenerate case
    /// constructs nothing.
    pub fn from_config(ac: &AdmissionControl) -> Option<Self> {
        if !ac.enabled {
            return None;
        }
        Some(Self {
            queue_cap: ac.queue_cap,
            shed_unmeetable: ac.shed_unmeetable,
            budgets: SloBudgets::from_config(ac),
            alpha: ac.ewma_alpha,
            drain_ewma_s: 0.0,
            ttft_tail_ewma_s: 0.0,
            have_estimate: false,
        })
    }

    /// Feed the drain estimator with one completed request's per-slot
    /// service time (its service duration / the batch width it ran at).
    pub fn observe_drain(&mut self, per_slot_s: f64) {
        if !(per_slot_s.is_finite() && per_slot_s >= 0.0) {
            return;
        }
        self.drain_ewma_s = if self.have_estimate {
            self.alpha * per_slot_s + (1.0 - self.alpha) * self.drain_ewma_s
        } else {
            per_slot_s
        };
        self.have_estimate = true;
    }

    /// Feed the tail estimator with one admitted request's
    /// admission→first-token seconds.
    pub fn observe_ttft_tail(&mut self, tail_s: f64) {
        if !(tail_s.is_finite() && tail_s >= 0.0) {
            return;
        }
        // Tail estimate only arms deadline shedding together with the
        // drain estimate (have_estimate flips there); before the first
        // completion this just pre-seeds.
        self.ttft_tail_ewma_s = if self.ttft_tail_ewma_s > 0.0 {
            self.alpha * tail_s + (1.0 - self.alpha) * self.ttft_tail_ewma_s
        } else {
            tail_s
        };
    }

    /// Estimated TTFT for a request staged now behind `depth` queued
    /// requests.
    pub fn estimated_ttft_s(&self, depth: usize) -> f64 {
        depth as f64 * self.drain_ewma_s + self.ttft_tail_ewma_s
    }

    /// Decide a request's fate at its staging instant, with `depth`
    /// requests already queued ahead of it. `Some(reason)` = shed.
    pub fn decide(&self, depth: usize, req: &InferenceRequest) -> Option<ShedReason> {
        if self.queue_cap > 0 && depth >= self.queue_cap {
            return Some(ShedReason::QueueFull);
        }
        if self.shed_unmeetable && self.have_estimate {
            let budget = self.budgets.ttft_for(req.slo);
            if self.estimated_ttft_s(depth) > budget {
                return Some(ShedReason::DeadlineUnmeetable);
            }
        }
        None
    }

    pub fn budgets(&self) -> SloBudgets {
        self.budgets
    }
}

/// Edge emitted by [`BrownoutController::observe`] when the overload
/// signal crosses a threshold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BrownoutEdge {
    Enter,
    Exit,
}

/// Hysteresis thermostat for the brownout overload signal. See module
/// docs.
#[derive(Debug, Clone)]
pub struct BrownoutController {
    alpha: f64,
    /// Reference SLO the signal is normalized by (Interactive TTFT).
    slo_s: f64,
    enter_ratio: f64,
    exit_ratio: f64,
    ewma_s: f64,
    primed: bool,
    active: bool,
    entered_at: Option<Duration>,
    /// Enter + exit edges over the run.
    pub transitions: u64,
    /// Total simulated seconds spent browned out.
    pub dwell_s: f64,
}

impl BrownoutController {
    /// `None` when admission control is disabled or `brownout_enter_ratio`
    /// is 0 (brownout off).
    pub fn from_config(ac: &AdmissionControl) -> Option<Self> {
        if !ac.enabled || ac.brownout_enter_ratio == 0.0 {
            return None;
        }
        Some(Self {
            alpha: ac.ewma_alpha,
            slo_s: ac.interactive_ttft_slo_s,
            enter_ratio: ac.brownout_enter_ratio,
            exit_ratio: ac.brownout_exit_ratio,
            ewma_s: 0.0,
            primed: false,
            active: false,
            entered_at: None,
            transitions: 0,
            dwell_s: 0.0,
        })
    }

    /// Feed one admitted request's queue delay (seconds, virtual) at
    /// admission instant `now`; returns the threshold edge, if any.
    pub fn observe(&mut self, queue_delay_s: f64, now: Duration) -> Option<BrownoutEdge> {
        if !(queue_delay_s.is_finite() && queue_delay_s >= 0.0) {
            return None;
        }
        self.ewma_s = if self.primed {
            self.alpha * queue_delay_s + (1.0 - self.alpha) * self.ewma_s
        } else {
            self.primed = true;
            queue_delay_s
        };
        let ratio = self.ratio();
        if !self.active && ratio >= self.enter_ratio {
            self.active = true;
            self.entered_at = Some(now);
            self.transitions += 1;
            Some(BrownoutEdge::Enter)
        } else if self.active && ratio <= self.exit_ratio {
            self.active = false;
            if let Some(t0) = self.entered_at.take() {
                self.dwell_s += now.saturating_sub(t0).as_secs_f64();
            }
            self.transitions += 1;
            Some(BrownoutEdge::Exit)
        } else {
            None
        }
    }

    /// Close the accounting window: a run that ends browned out charges
    /// the residual dwell up to `now`.
    pub fn finish(&mut self, now: Duration) {
        if self.active {
            if let Some(t0) = self.entered_at.take() {
                self.dwell_s += now.saturating_sub(t0).as_secs_f64();
            }
        }
    }

    /// Current overload signal: EWMA(queue delay) / reference SLO.
    pub fn ratio(&self) -> f64 {
        self.ewma_s / self.slo_s
    }

    pub fn active(&self) -> bool {
        self.active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::request::InferenceRequest;

    fn enabled(cap: usize) -> AdmissionControl {
        AdmissionControl::overload_protect(0.25, 2.5, cap)
    }

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2], 4)
    }

    #[test]
    fn disabled_config_constructs_nothing() {
        let ac = AdmissionControl::disabled();
        assert!(AdmissionGate::from_config(&ac).is_none());
        assert!(BrownoutController::from_config(&ac).is_none());
    }

    #[test]
    fn queue_cap_sheds_at_depth() {
        let g = AdmissionGate::from_config(&enabled(4)).unwrap();
        assert_eq!(g.decide(3, &req(1)), None);
        assert_eq!(g.decide(4, &req(1)), Some(ShedReason::QueueFull));
        assert_eq!(g.decide(9, &req(1)), Some(ShedReason::QueueFull));
    }

    #[test]
    fn zero_cap_is_unbounded() {
        let g = AdmissionGate::from_config(&enabled(0)).unwrap();
        assert_eq!(g.decide(1_000_000, &req(1)), None);
    }

    #[test]
    fn deadline_shed_requires_an_estimate() {
        let mut g = AdmissionGate::from_config(&enabled(0)).unwrap();
        // Cold estimator: even an absurd depth is admitted.
        assert_eq!(g.decide(10_000, &req(1)), None);
        // 10 ms per queued slot: depth 100 → 1 s ≫ 0.25 s interactive
        // budget, still ≪ 2.5 s batch budget.
        g.observe_drain(0.010);
        assert_eq!(g.decide(100, &req(1)), Some(ShedReason::DeadlineUnmeetable));
        assert_eq!(g.decide(100, &req(2).with_slo(SloClass::Batch)), None);
        assert_eq!(g.decide(10, &req(3)), None, "0.1 s estimate fits the budget");
    }

    #[test]
    fn ttft_tail_counts_toward_the_estimate() {
        let mut g = AdmissionGate::from_config(&enabled(0)).unwrap();
        g.observe_drain(0.001);
        g.observe_ttft_tail(0.3); // tail alone blows the 0.25 s budget
        assert_eq!(g.decide(0, &req(1)), Some(ShedReason::DeadlineUnmeetable));
    }

    #[test]
    fn ewma_converges_on_repeated_observations() {
        let mut g = AdmissionGate::from_config(&enabled(0)).unwrap();
        g.observe_drain(0.010);
        for _ in 0..200 {
            g.observe_drain(0.002);
        }
        let est = g.estimated_ttft_s(10);
        assert!(
            (est - 0.020).abs() < 0.002,
            "estimator should converge to ~2 ms/slot, got {est}"
        );
    }

    #[test]
    fn non_finite_observations_are_ignored() {
        let mut g = AdmissionGate::from_config(&enabled(0)).unwrap();
        g.observe_drain(f64::NAN);
        g.observe_drain(-1.0);
        assert_eq!(g.decide(10_000, &req(1)), None, "estimator must stay cold");
    }

    #[test]
    fn brownout_hysteresis_and_dwell() {
        let mut ac = enabled(0);
        ac.ewma_alpha = 1.0; // no smoothing: the signal is the observation
        let mut b = BrownoutController::from_config(&ac).unwrap();
        // enter at ratio 0.5 (0.125 s), exit at 0.25 (0.0625 s).
        assert_eq!(b.observe(0.05, Duration::from_secs(1)), None);
        assert_eq!(
            b.observe(0.20, Duration::from_secs(2)),
            Some(BrownoutEdge::Enter)
        );
        assert!(b.active());
        // Between the thresholds: no edge (hysteresis).
        assert_eq!(b.observe(0.10, Duration::from_secs(3)), None);
        assert!(b.active());
        assert_eq!(
            b.observe(0.01, Duration::from_secs(5)),
            Some(BrownoutEdge::Exit)
        );
        assert!(!b.active());
        assert_eq!(b.transitions, 2);
        assert!((b.dwell_s - 3.0).abs() < 1e-9, "entered t=2, exited t=5");
    }

    #[test]
    fn finish_charges_residual_dwell() {
        let mut ac = enabled(0);
        ac.ewma_alpha = 1.0;
        let mut b = BrownoutController::from_config(&ac).unwrap();
        b.observe(1.0, Duration::from_secs(1));
        assert!(b.active());
        b.finish(Duration::from_secs(4));
        assert!((b.dwell_s - 3.0).abs() < 1e-9);
        assert_eq!(b.transitions, 1, "run ended browned out: one edge");
    }

    #[test]
    fn brownout_decisions_are_replayable() {
        // Same observation stream → byte-identical controller state.
        let ac = enabled(0);
        let mut a = BrownoutController::from_config(&ac).unwrap();
        let mut b = BrownoutController::from_config(&ac).unwrap();
        let stream = [0.01, 0.2, 0.5, 0.3, 0.02, 0.01, 0.9, 0.001];
        for (i, q) in stream.iter().enumerate() {
            let t = Duration::from_millis(100 * (i as u64 + 1));
            assert_eq!(a.observe(*q, t), b.observe(*q, t));
        }
        assert_eq!(a.transitions, b.transitions);
        assert_eq!(a.dwell_s.to_bits(), b.dwell_s.to_bits());
        assert_eq!(a.ratio().to_bits(), b.ratio().to_bits());
    }
}
