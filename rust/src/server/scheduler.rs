//! Continuous-batching scheduler: the serving main loop.
//!
//! Holds up to `max_batch` active sequences; every iteration admits new
//! requests into free slots (prefill), then runs one decode step across
//! all active sequences, retiring finished ones. This is the standard
//! continuous-batching shape (Orca/vLLM) with the paper's offloading +
//! substitution machinery inside `Engine::decode_step`. All timing reads
//! the engine's [`crate::util::clock::SimClock`], so the same loop serves
//! both deterministic virtual-time sweeps and real-time measurement runs.
//!
//! Under load (arrivals staged on the batcher's event queue, see
//! [`crate::traffic`]) the loop also records tail-latency ingredients:
//! queue delay (arrival → admission), TTFT (arrival → first token),
//! time-between-tokens per sequence, end-to-end latency, and the
//! admission-queue depth sampled at every step. A completion hook lets
//! closed-loop workloads schedule their next arrival off each finished
//! request.
//!
//! # Admission control & overload (enabled via `scfg.admission`)
//!
//! With [`crate::config::AdmissionControl`] enabled the loop grows three
//! deterministic overload behaviors — all SimClock-driven, all absent
//! (not merely inert) in the disabled default:
//!
//! * **Shed processing** — the batcher's [`AdmissionGate`] refuses
//!   requests at staging (queue cap, or unmeetable TTFT deadline); the
//!   loop drains those typed [`ShedOutcome`]s every iteration, counts
//!   them per class/reason, emits a `shed` instant on
//!   `Track::Admission`, and fires the completion hook with
//!   [`RequestOutcome::Shed`] so closed-loop populations feel the
//!   backpressure (the simulated user gets the rejection and thinks
//!   before their next request). A shed request is never admitted and
//!   never double-counted as done or dropped.
//! * **Priority batch composition** — at saturation (more queued than
//!   free slots) batches are composed by tightest remaining TTFT slack
//!   (bucketed), tie-broken by largest expert-working-set overlap with
//!   the device-0 residency mask ([`Engine::admission_affinity`] ×
//!   `EngineState::residency_mask`), instead of FIFO.
//! * **Brownout coupling** — admitted queue delays feed the
//!   [`BrownoutController`] EWMA; threshold crossings call
//!   [`Engine::set_brownout`], shifting miss handling toward ψ buddy
//!   substitution and tightening the transfer deadline, and emit
//!   `brownout_enter`/`brownout_exit` instants on `Track::Admission`.
//!
//! The estimators close the loop: each admission feeds its
//! admission→first-token tail and each completion its per-slot service
//! time back into the gate, so the deadline-unmeetable test tracks the
//! live service rate.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::admission::{AdmissionGate, BrownoutController, BrownoutEdge, SloBudgets};
use super::batcher::DynamicBatcher;
use super::metrics::ServerMetrics;
use super::request::{
    InferenceRequest, InferenceResponse, RequestOutcome, ShedReason, SloClass,
};
use crate::model::{Engine, Sequence};
use crate::trace::Track;

/// Called for each terminal request outcome: `(completion_time, outcome,
/// batcher)` — completed responses *and* admission sheds. Closed-loop
/// traffic uses this to stage the population's next arrival
/// (`DynamicBatcher::stage_arrival`).
pub type CompletionHook = Box<dyn FnMut(Duration, &RequestOutcome, &DynamicBatcher)>;

pub struct Server {
    pub engine: Engine,
    pub batcher: Arc<DynamicBatcher>,
    pub metrics: ServerMetrics,
    /// Invoked as each request reaches a terminal outcome (before a
    /// completed response is returned). Used by the traffic subsystem's
    /// closed-loop generator; `None` for offline runs.
    pub on_complete: Option<CompletionHook>,
}

struct Active {
    seq: Sequence,
    slo: SloClass,
    /// Clock timestamp the request arrived (generator timestamp, or the
    /// submit instant when none was stamped).
    arrived: Duration,
    ttft: f64,
    /// Arrival → admission seconds (subtracted from the total at retire
    /// time to feed the gate's per-slot service estimator).
    queue_delay: f64,
    /// Absolute clock seconds at which the first token was produced.
    first_token_s: f64,
    /// Clock timestamp of this sequence's latest token (TBT accounting).
    last_token: Duration,
    /// Any step this request took part in ran a degradation-waterfall arm
    /// (fault recovery); propagated into the response annotation.
    degraded: bool,
}

impl Server {
    pub fn new(engine: Engine) -> Self {
        let max_batch = engine.scfg.max_batch;
        let timeout = Duration::from_micros(engine.scfg.batch_timeout_us);
        let clock = engine.clock();
        let batcher = Arc::new(DynamicBatcher::new(max_batch, timeout, clock.clone()));
        if let Some(gate) = AdmissionGate::from_config(&engine.scfg.admission) {
            batcher.set_admission_gate(gate);
        }
        Self {
            batcher,
            metrics: ServerMetrics::new(clock),
            engine,
            on_complete: None,
        }
    }

    /// Serve until the batcher is closed and drained. Returns responses in
    /// completion order.
    pub fn run(&mut self) -> Result<Vec<InferenceResponse>> {
        let clock = self.engine.clock();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<InferenceResponse> = Vec::new();
        self.metrics = ServerMetrics::new(clock.clone());
        let admission_on = self.engine.scfg.admission.enabled;
        let priority_on = admission_on && self.engine.scfg.admission.priority_compose;
        let budgets = SloBudgets::from_config(&self.engine.scfg.admission);
        let mut brownout = BrownoutController::from_config(&self.engine.scfg.admission);

        loop {
            // Account sheds the gate produced since the last iteration
            // (no-op without a gate: the shed log is always empty).
            if admission_on {
                self.process_shed()?;
            }
            // Admit into free slots.
            let room = self.engine.scfg.max_batch - active.len();
            let admissions = if priority_on && self.batcher.pending() > room {
                // Saturation: compose the batch by (tightest remaining
                // budget, largest resident-working-set overlap) instead
                // of FIFO. Never taken when admission is disabled.
                self.ranked_admissions(room, budgets)
            } else if active.is_empty() {
                match self.batcher.next_admissions(room) {
                    Some(a) => a,
                    None => {
                        // Drained — but a final burst may have been shed
                        // at release; those sheds can stage closed-loop
                        // follow-ups through the hook, so process them
                        // and re-poll before concluding the run is over.
                        if admission_on && self.process_shed()? > 0 {
                            continue;
                        }
                        break; // closed + drained + nothing active
                    }
                }
            } else {
                self.batcher.try_admissions(room)
            };
            for req in admissions {
                let act = self.admit(req, &mut brownout)?;
                active.push(act);
            }
            if active.is_empty() {
                continue;
            }
            // Queue depth as seen at this step boundary (requests that
            // arrived but could not be admitted).
            self.metrics.queue_depth.add(self.batcher.pending() as f64);

            // One decode step over all active sequences.
            let t0 = clock.now();
            let mut refs: Vec<&mut Sequence> = active.iter_mut().map(|a| &mut a.seq).collect();
            let tel = self.engine.decode_step(&mut refs)?;
            drop(refs);
            self.metrics.step_latency.add(clock.since(t0));
            self.metrics.stall_seconds.add(tel.stall_seconds);
            self.metrics.counters.add("substitutions", tel.substitutions);
            self.metrics.counters.add("fetches", tel.fetches);
            self.metrics.counters.add("peer_hops", tel.peer_hops);
            self.metrics.counters.add("replica_hits", tel.replica_hits);
            self.metrics.counters.add("retried_fetches", tel.retried_fetches);
            self.metrics.counters.add("waterfall_drops", tel.waterfall_drops);
            self.metrics.tokens_out += active.len() as u64;
            let now = clock.now();
            for a in active.iter_mut() {
                self.metrics.tbt.add(clock.since(a.last_token));
                a.last_token = now;
                // Step-level annotation: every request in a degraded step
                // shared the recovery (the batch computes together).
                a.degraded |= tel.degraded;
            }

            // Retire finished sequences.
            let batch_width = active.len();
            let mut i = 0;
            while i < active.len() {
                if active[i].seq.done() {
                    let a = active.swap_remove(i);
                    let total = clock.since(a.arrived);
                    self.metrics.request_latency.add(total);
                    self.metrics.requests_done += 1;
                    let mut logits = Vec::new();
                    if let Some(p) = &a.seq.prefill_logits {
                        logits.push(p.clone());
                        logits.extend(a.seq.logits_log.iter().cloned());
                    }
                    if a.degraded {
                        self.metrics.degraded_requests += 1;
                    }
                    if admission_on {
                        // Close the estimator loop: this request's
                        // in-service seconds, amortized over the batch
                        // width it shared, approximate the per-queue-slot
                        // drain interval the gate projects with.
                        let service = (total - a.queue_delay).max(0.0);
                        self.batcher.observe_service(service / batch_width as f64);
                    }
                    let _ = self.engine.tracer().finish_request(
                        a.seq.id,
                        clock.now(),
                        a.degraded,
                    );
                    let resp = InferenceResponse {
                        id: a.seq.id,
                        slo: a.slo,
                        tokens: a.seq.generated.clone(),
                        predictions: a.seq.predictions.clone(),
                        logits,
                        ttft: a.ttft,
                        first_token_time: a.first_token_s,
                        total,
                        degraded: a.degraded,
                    };
                    let outcome = RequestOutcome::Done(resp);
                    if let Some(hook) = self.on_complete.as_mut() {
                        hook(clock.now(), &outcome, &self.batcher);
                    }
                    if let RequestOutcome::Done(resp) = outcome {
                        done.push(resp);
                    }
                } else {
                    i += 1;
                }
            }
        }
        if let Some(b) = brownout.as_mut() {
            // A run that ends browned out still owes its residual dwell;
            // make sure the engine is back in its configured mode too.
            b.finish(clock.now());
            self.metrics.brownout_transitions = b.transitions;
            self.metrics.brownout_dwell_s = b.dwell_s;
            self.engine.set_brownout(false);
        }
        Ok(done)
    }

    /// Convenience: submit a fixed request list, close, and run to
    /// completion (offline benchmark mode).
    pub fn run_offline(&mut self, requests: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        for r in requests {
            self.batcher.submit(r);
        }
        self.batcher.close();
        self.run()
    }

    /// Drain the batcher's shed log: count, trace, and surface each shed
    /// through the completion hook. Returns how many were processed.
    fn process_shed(&mut self) -> Result<usize> {
        let shed = self.batcher.take_shed();
        let n = shed.len();
        if n == 0 {
            return Ok(0);
        }
        let clock = self.engine.clock();
        for o in shed {
            self.metrics.shed_requests += 1;
            match o.slo {
                SloClass::Interactive => self.metrics.shed_interactive += 1,
                SloClass::Batch => self.metrics.shed_batch += 1,
            }
            match o.reason {
                ShedReason::QueueFull => self.metrics.shed_queue_full += 1,
                ShedReason::DeadlineUnmeetable => self.metrics.shed_deadline += 1,
            }
            self.engine.tracer().instant(
                o.at,
                Track::Admission,
                "shed",
                &[
                    ("id", o.id as i64),
                    ("interactive", i64::from(o.slo == SloClass::Interactive)),
                    ("queue_full", i64::from(o.reason == ShedReason::QueueFull)),
                ],
            );
            let outcome = RequestOutcome::Shed(o.clone());
            if let Some(hook) = self.on_complete.as_mut() {
                hook(clock.now(), &outcome, &self.batcher);
            }
            self.metrics.shed_log.push(o);
        }
        Ok(n)
    }

    /// Saturation-mode batch composition: rank every queued request by
    /// `(remaining-TTFT-slack bucket, -resident-working-set overlap)` —
    /// tightest budget first, ties to the request whose predicted experts
    /// are already GPU-resident (cheapest to serve *now*). Slack is
    /// bucketed at a quarter of the Interactive budget so overlap gets to
    /// matter between near-equal deadlines; within one (bucket, overlap)
    /// key the batcher keeps FIFO order, so the composition is
    /// deterministic.
    fn ranked_admissions(&self, room: usize, budgets: SloBudgets) -> Vec<InferenceRequest> {
        let now_s = self.engine.clock().now().as_secs_f64();
        let residency = self
            .engine
            .transfer_handle()
            .with_state(|st| st.residency_mask(0));
        let bucket_s = (budgets.interactive_ttft_s / 4.0).max(1e-6);
        let engine = &self.engine;
        let rank = move |req: &InferenceRequest| -> (i64, i64) {
            let slack_s = req.arrived().as_secs_f64() + budgets.ttft_for(req.slo) - now_s;
            let slack_bucket = (slack_s / bucket_s).floor() as i64;
            let overlap = engine
                .admission_affinity(&req.prompt)
                .into_iter()
                .filter(|&e| residency.get(e).copied().unwrap_or(false))
                .count() as i64;
            (slack_bucket, -overlap)
        };
        self.batcher.try_admissions_ranked(room, &rank)
    }

    fn admit(
        &mut self,
        req: InferenceRequest,
        brownout: &mut Option<BrownoutController>,
    ) -> Result<Active> {
        let clock = self.engine.clock();
        let arrived = req.arrived();
        let slo = req.slo;
        // Admission instant: the queue-delay measurement point (prefill
        // below advances the clock in virtual mode).
        let queue_delay = clock.since(arrived);
        self.metrics.queue_delay.add(queue_delay);
        // Queue delay vs SLO is the overload signal; threshold crossings
        // toggle the engine's brownout mode.
        if let Some(b) = brownout.as_mut() {
            if let Some(edge) = b.observe(queue_delay, clock.now()) {
                let ratio_ppm = (b.ratio() * 1e6) as i64;
                let (name, engage) = match edge {
                    BrownoutEdge::Enter => ("brownout_enter", true),
                    BrownoutEdge::Exit => ("brownout_exit", false),
                };
                self.engine.set_brownout(engage);
                self.engine.tracer().instant(
                    clock.now(),
                    Track::Admission,
                    name,
                    &[("ratio_ppm", ratio_ppm)],
                );
            }
        }
        let mut seq = self.engine.new_sequence(req.prompt, req.max_new);
        seq.id = req.id;
        seq.force_tokens = req.force_tokens;
        self.engine.tracer().begin_request(seq.id, arrived, clock.now());
        let tel = self.engine.prefill(&mut seq)?;
        self.metrics.stall_seconds.add(tel.stall_seconds);
        self.metrics.counters.add("substitutions", tel.substitutions);
        self.metrics.counters.add("fetches", tel.fetches);
        self.metrics.counters.add("peer_hops", tel.peer_hops);
        self.metrics.counters.add("replica_hits", tel.replica_hits);
        self.metrics.counters.add("retried_fetches", tel.retried_fetches);
        self.metrics.counters.add("waterfall_drops", tel.waterfall_drops);
        // Prefill complete = first token out.
        let ttft = clock.since(arrived);
        self.metrics.ttft.add(ttft);
        match slo {
            SloClass::Interactive => self.metrics.ttft_interactive.add(ttft),
            SloClass::Batch => self.metrics.ttft_batch.add(ttft),
        }
        if self.engine.scfg.admission.enabled {
            self.batcher.observe_ttft_tail((ttft - queue_delay).max(0.0));
        }
        Ok(Active {
            seq,
            slo,
            arrived,
            ttft,
            queue_delay,
            first_token_s: clock.now_s(),
            last_token: clock.now(),
            degraded: tel.degraded,
        })
    }
}
