//! Continuous-batching scheduler: the serving main loop.
//!
//! Holds up to `max_batch` active sequences; every iteration admits new
//! requests into free slots (prefill), then runs one decode step across
//! all active sequences, retiring finished ones. This is the standard
//! continuous-batching shape (Orca/vLLM) with the paper's offloading +
//! substitution machinery inside `Engine::decode_step`. All timing reads
//! the engine's [`crate::util::clock::SimClock`], so the same loop serves
//! both deterministic virtual-time sweeps and real-time measurement runs.
//!
//! Under load (arrivals staged on the batcher's event queue, see
//! [`crate::traffic`]) the loop also records tail-latency ingredients:
//! queue delay (arrival → admission), TTFT (arrival → first token),
//! time-between-tokens per sequence, end-to-end latency, and the
//! admission-queue depth sampled at every step. A completion hook lets
//! closed-loop workloads schedule their next arrival off each finished
//! request.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::batcher::DynamicBatcher;
use super::metrics::ServerMetrics;
use super::request::{InferenceRequest, InferenceResponse};
use crate::model::{Engine, Sequence};

/// Called for each completed request: `(completion_time, response,
/// batcher)`. Closed-loop traffic uses this to stage the population's next
/// arrival (`DynamicBatcher::stage_arrival`).
pub type CompletionHook = Box<dyn FnMut(Duration, &InferenceResponse, &DynamicBatcher)>;

pub struct Server {
    pub engine: Engine,
    pub batcher: Arc<DynamicBatcher>,
    pub metrics: ServerMetrics,
    /// Invoked as each request completes (before it is returned). Used by
    /// the traffic subsystem's closed-loop generator; `None` for offline
    /// runs.
    pub on_complete: Option<CompletionHook>,
}

struct Active {
    seq: Sequence,
    /// Clock timestamp the request arrived (generator timestamp, or the
    /// submit instant when none was stamped).
    arrived: Duration,
    ttft: f64,
    /// Absolute clock seconds at which the first token was produced.
    first_token_s: f64,
    /// Clock timestamp of this sequence's latest token (TBT accounting).
    last_token: Duration,
    /// Any step this request took part in ran a degradation-waterfall arm
    /// (fault recovery); propagated into the response annotation.
    degraded: bool,
}

impl Server {
    pub fn new(engine: Engine) -> Self {
        let max_batch = engine.scfg.max_batch;
        let timeout = Duration::from_micros(engine.scfg.batch_timeout_us);
        let clock = engine.clock();
        Self {
            batcher: Arc::new(DynamicBatcher::new(max_batch, timeout, clock.clone())),
            metrics: ServerMetrics::new(clock),
            engine,
            on_complete: None,
        }
    }

    /// Serve until the batcher is closed and drained. Returns responses in
    /// completion order.
    pub fn run(&mut self) -> Result<Vec<InferenceResponse>> {
        let clock = self.engine.clock();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<InferenceResponse> = Vec::new();
        self.metrics = ServerMetrics::new(clock.clone());

        loop {
            // Admit into free slots.
            let room = self.engine.scfg.max_batch - active.len();
            let admissions = if active.is_empty() {
                match self.batcher.next_admissions(room) {
                    Some(a) => a,
                    None => break, // closed + drained + nothing active
                }
            } else {
                self.batcher.try_admissions(room)
            };
            for req in admissions {
                let act = self.admit(req)?;
                active.push(act);
            }
            if active.is_empty() {
                continue;
            }
            // Queue depth as seen at this step boundary (requests that
            // arrived but could not be admitted).
            self.metrics.queue_depth.add(self.batcher.pending() as f64);

            // One decode step over all active sequences.
            let t0 = clock.now();
            let mut refs: Vec<&mut Sequence> = active.iter_mut().map(|a| &mut a.seq).collect();
            let tel = self.engine.decode_step(&mut refs)?;
            drop(refs);
            self.metrics.step_latency.add(clock.since(t0));
            self.metrics.stall_seconds.add(tel.stall_seconds);
            self.metrics.counters.add("substitutions", tel.substitutions);
            self.metrics.counters.add("fetches", tel.fetches);
            self.metrics.counters.add("peer_hops", tel.peer_hops);
            self.metrics.counters.add("replica_hits", tel.replica_hits);
            self.metrics.counters.add("retried_fetches", tel.retried_fetches);
            self.metrics.counters.add("waterfall_drops", tel.waterfall_drops);
            self.metrics.tokens_out += active.len() as u64;
            let now = clock.now();
            for a in active.iter_mut() {
                self.metrics.tbt.add(clock.since(a.last_token));
                a.last_token = now;
                // Step-level annotation: every request in a degraded step
                // shared the recovery (the batch computes together).
                a.degraded |= tel.degraded;
            }

            // Retire finished sequences.
            let mut i = 0;
            while i < active.len() {
                if active[i].seq.done() {
                    let a = active.swap_remove(i);
                    let total = clock.since(a.arrived);
                    self.metrics.request_latency.add(total);
                    self.metrics.requests_done += 1;
                    let mut logits = Vec::new();
                    if let Some(p) = &a.seq.prefill_logits {
                        logits.push(p.clone());
                        logits.extend(a.seq.logits_log.iter().cloned());
                    }
                    if a.degraded {
                        self.metrics.degraded_requests += 1;
                    }
                    let _ = self.engine.tracer().finish_request(
                        a.seq.id,
                        clock.now(),
                        a.degraded,
                    );
                    let resp = InferenceResponse {
                        id: a.seq.id,
                        tokens: a.seq.generated.clone(),
                        predictions: a.seq.predictions.clone(),
                        logits,
                        ttft: a.ttft,
                        first_token_time: a.first_token_s,
                        total,
                        degraded: a.degraded,
                    };
                    if let Some(hook) = self.on_complete.as_mut() {
                        hook(clock.now(), &resp, &self.batcher);
                    }
                    done.push(resp);
                } else {
                    i += 1;
                }
            }
        }
        Ok(done)
    }

    /// Convenience: submit a fixed request list, close, and run to
    /// completion (offline benchmark mode).
    pub fn run_offline(&mut self, requests: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        for r in requests {
            self.batcher.submit(r);
        }
        self.batcher.close();
        self.run()
    }

    fn admit(&mut self, req: InferenceRequest) -> Result<Active> {
        let clock = self.engine.clock();
        let arrived = req.arrived();
        // Admission instant: the queue-delay measurement point (prefill
        // below advances the clock in virtual mode).
        self.metrics.queue_delay.add(clock.since(arrived));
        let mut seq = self.engine.new_sequence(req.prompt, req.max_new);
        seq.id = req.id;
        seq.force_tokens = req.force_tokens;
        self.engine.tracer().begin_request(seq.id, arrived, clock.now());
        let tel = self.engine.prefill(&mut seq)?;
        self.metrics.stall_seconds.add(tel.stall_seconds);
        self.metrics.counters.add("substitutions", tel.substitutions);
        self.metrics.counters.add("fetches", tel.fetches);
        self.metrics.counters.add("peer_hops", tel.peer_hops);
        self.metrics.counters.add("replica_hits", tel.replica_hits);
        self.metrics.counters.add("retried_fetches", tel.retried_fetches);
        self.metrics.counters.add("waterfall_drops", tel.waterfall_drops);
        // Prefill complete = first token out.
        let ttft = clock.since(arrived);
        self.metrics.ttft.add(ttft);
        Ok(Active {
            seq,
            arrived,
            ttft,
            first_token_s: clock.now_s(),
            last_token: clock.now(),
            degraded: tel.degraded,
        })
    }
}
