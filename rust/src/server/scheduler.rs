//! Continuous-batching scheduler: the serving main loop.
//!
//! Holds up to `max_batch` active sequences; every iteration admits new
//! requests into free slots (prefill), then runs one decode step across
//! all active sequences, retiring finished ones. This is the standard
//! continuous-batching shape (Orca/vLLM) with the paper's offloading +
//! substitution machinery inside `Engine::decode_step`. All timing reads
//! the engine's [`crate::util::clock::SimClock`], so the same loop serves
//! both deterministic virtual-time sweeps and real-time measurement runs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::batcher::DynamicBatcher;
use super::metrics::ServerMetrics;
use super::request::{InferenceRequest, InferenceResponse};
use crate::model::{Engine, Sequence};

pub struct Server {
    pub engine: Engine,
    pub batcher: Arc<DynamicBatcher>,
    pub metrics: ServerMetrics,
}

struct Active {
    seq: Sequence,
    /// Clock timestamp the request entered the batcher.
    enqueued: Duration,
    ttft: f64,
}

impl Server {
    pub fn new(engine: Engine) -> Self {
        let max_batch = engine.scfg.max_batch;
        let timeout = Duration::from_micros(engine.scfg.batch_timeout_us);
        let clock = engine.clock();
        Self {
            batcher: Arc::new(DynamicBatcher::new(max_batch, timeout, clock.clone())),
            metrics: ServerMetrics::new(clock),
            engine,
        }
    }

    /// Serve until the batcher is closed and drained. Returns responses in
    /// completion order.
    pub fn run(&mut self) -> Result<Vec<InferenceResponse>> {
        let clock = self.engine.clock();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<InferenceResponse> = Vec::new();
        self.metrics = ServerMetrics::new(clock.clone());

        loop {
            // Admit into free slots.
            let room = self.engine.scfg.max_batch - active.len();
            let admissions = if active.is_empty() {
                match self.batcher.next_admissions(room) {
                    Some(a) => a,
                    None => break, // closed + drained + nothing active
                }
            } else {
                self.batcher.try_admissions(room)
            };
            for req in admissions {
                let mut act = self.admit(req)?;
                act.ttft = clock.since(act.enqueued);
                self.metrics.ttft.add(act.ttft);
                active.push(act);
            }
            if active.is_empty() {
                continue;
            }

            // One decode step over all active sequences.
            let t0 = clock.now();
            let mut refs: Vec<&mut Sequence> = active.iter_mut().map(|a| &mut a.seq).collect();
            let tel = self.engine.decode_step(&mut refs)?;
            drop(refs);
            self.metrics.step_latency.add(clock.since(t0));
            self.metrics.stall_seconds.add(tel.stall_seconds);
            self.metrics.counters.add("substitutions", tel.substitutions);
            self.metrics.counters.add("fetches", tel.fetches);
            self.metrics.tokens_out += active.len() as u64;

            // Retire finished sequences.
            let mut i = 0;
            while i < active.len() {
                if active[i].seq.done() {
                    let a = active.swap_remove(i);
                    let total = clock.since(a.enqueued);
                    self.metrics.request_latency.add(total);
                    self.metrics.requests_done += 1;
                    let mut logits = Vec::new();
                    if let Some(p) = &a.seq.prefill_logits {
                        logits.push(p.clone());
                        logits.extend(a.seq.logits_log.iter().cloned());
                    }
                    done.push(InferenceResponse {
                        id: a.seq.id,
                        tokens: a.seq.generated.clone(),
                        predictions: a.seq.predictions.clone(),
                        logits,
                        ttft: a.ttft,
                        total,
                    });
                } else {
                    i += 1;
                }
            }
        }
        Ok(done)
    }

    /// Convenience: submit a fixed request list, close, and run to
    /// completion (offline benchmark mode).
    pub fn run_offline(&mut self, requests: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        for r in requests {
            self.batcher.submit(r);
        }
        self.batcher.close();
        self.run()
    }

    fn admit(&mut self, req: InferenceRequest) -> Result<Active> {
        let mut seq = self.engine.new_sequence(req.prompt, req.max_new);
        seq.id = req.id;
        seq.force_tokens = req.force_tokens;
        let tel = self.engine.prefill(&mut seq)?;
        self.metrics.stall_seconds.add(tel.stall_seconds);
        self.metrics.counters.add("substitutions", tel.substitutions);
        self.metrics.counters.add("fetches", tel.fetches);
        Ok(Active { seq, enqueued: req.enqueued, ttft: 0.0 })
    }
}
