//! Expert-demand predictors.
//!
//! * [`TopFreq`] — historical activation frequency (MoE-Infinity-style):
//!   statically predicts each layer's most-activated experts.
//! * [`PreGate`] — Pre-gated-MoE-style lookahead: run layer *l+1*'s router
//!   on layer *l*'s hidden states (host-side matmul; the router is tiny).
//!   Contextual but imperfect — exactly the paper's premise.
//! * [`OracleNoisy`] — knows the true selection, forgets each expert with
//!   probability `miss_rate`: the controllable-miss-rate harness behind
//!   Table 1.

use crate::profilecollect::ProfileCollector;
use crate::util::math::{softmax, top_k};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;
use crate::weights::WeightStore;

/// Context available when predicting layer `layer`'s experts.
pub struct PredictContext<'a> {
    /// Hidden states leaving the previous block, [T, D].
    pub hidden: Option<&'a Tensor>,
    /// True selection for the layer (oracle only).
    pub actual: Option<&'a [Vec<usize>]>,
}

pub trait Predictor: Send {
    /// Predict up to `width` experts needed at `layer`.
    fn predict(&mut self, layer: usize, width: usize, ctx: &PredictContext) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------

/// Historical-frequency predictor.
pub struct TopFreq {
    /// Experts per layer, descending activation count.
    ranked: Vec<Vec<usize>>,
}

impl TopFreq {
    pub fn from_profile(collector: &ProfileCollector) -> Self {
        let ranked = (0..collector.n_layers())
            .map(|l| {
                let acts = &collector.layer(l).activations;
                let mut idx: Vec<usize> = (0..acts.len()).collect();
                // total_cmp: NaN activations rank deterministically
                // instead of panicking the sort.
                idx.sort_by(|&a, &b| acts[b].total_cmp(&acts[a]).then(a.cmp(&b)));
                idx
            })
            .collect();
        Self { ranked }
    }

    /// From pre-ranked expert lists (e.g. router-bias popularity when no
    /// profiling corpus has been run yet).
    pub fn from_ranked(ranked: Vec<Vec<usize>>) -> Self {
        Self { ranked }
    }
}

impl Predictor for TopFreq {
    fn predict(&mut self, layer: usize, width: usize, _ctx: &PredictContext) -> Vec<usize> {
        self.ranked[layer].iter().copied().take(width).collect()
    }

    fn name(&self) -> &'static str {
        "topfreq"
    }
}

// ---------------------------------------------------------------------------

/// Host-side router evaluation: probs = softmax(rmsnorm(x) @ wg + b), the
/// same math as the `router` artifact but on the CPU for lookahead.
pub fn host_router_probs(
    x: &[f32],
    d: usize,
    ln2: &[f32],
    wg: &Tensor,
    rbias: &[f32],
    eps: f32,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), d);
    let e = wg.dims[1];
    // RMS norm.
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    let mut logits = rbias.to_vec();
    for (i, &xi) in x.iter().enumerate() {
        let h = xi * inv * ln2[i];
        let row = &wg.data[i * e..(i + 1) * e];
        for (j, &w) in row.iter().enumerate() {
            logits[j] += h * w;
        }
    }
    softmax(&mut logits);
    logits
}

/// Lookahead predictor: applies the *next* layer's router to the hidden
/// state leaving the current layer.
pub struct PreGate {
    store: std::sync::Arc<WeightStore>,
    d_model: usize,
    top_k: usize,
    rms_eps: f32,
}

impl PreGate {
    pub fn new(
        store: std::sync::Arc<WeightStore>,
        d_model: usize,
        top_k: usize,
        rms_eps: f32,
    ) -> Self {
        Self { store, d_model, top_k, rms_eps }
    }
}

impl Predictor for PreGate {
    fn predict(&mut self, layer: usize, width: usize, ctx: &PredictContext) -> Vec<usize> {
        let Some(hidden) = ctx.hidden else {
            return Vec::new();
        };
        let (Ok(ln2), Ok(wg), Ok(rbias)) = (
            self.store.tensor(&format!("L{layer}.ln2")),
            self.store.tensor(&format!("L{layer}.wg")),
            self.store.tensor(&format!("L{layer}.rbias")),
        ) else {
            return Vec::new();
        };
        // Union of per-token top-k predictions, ranked by summed prob.
        let e = wg.dims[1];
        let mut mass = vec![0.0f32; e];
        let t = hidden.dims[0];
        for ti in 0..t {
            let probs = host_router_probs(
                hidden.row(ti),
                self.d_model,
                &ln2.data,
                wg,
                &rbias.data,
                self.rms_eps,
            );
            let (idx, _) = top_k(&probs, self.top_k);
            for i in idx {
                mass[i] += probs[i];
            }
        }
        let mut ranked: Vec<usize> = (0..e).filter(|&i| mass[i] > 0.0).collect();
        ranked.sort_by(|&a, &b| mass[b].total_cmp(&mass[a]).then(a.cmp(&b)));
        ranked.truncate(width);
        ranked
    }

    fn name(&self) -> &'static str {
        "pregate"
    }
}

// ---------------------------------------------------------------------------

/// Oracle with controllable false-negative rate.
pub struct OracleNoisy {
    pub miss_rate: f64,
    rng: Rng,
}

impl OracleNoisy {
    pub fn new(miss_rate: f64, seed: u64) -> Self {
        Self { miss_rate, rng: Rng::new(seed) }
    }
}

impl Predictor for OracleNoisy {
    fn predict(&mut self, _layer: usize, width: usize, ctx: &PredictContext) -> Vec<usize> {
        let Some(actual) = ctx.actual else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for sel in actual {
            for &e in sel {
                if !out.contains(&e) && !self.rng.bool(self.miss_rate) {
                    out.push(e);
                }
            }
        }
        out.truncate(width);
        out
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn topfreq_ranks_by_activation() {
        let mut p = ProfileCollector::new(1, 4);
        for _ in 0..5 {
            p.record(0, &[2, 1], &[0.5, 0.5]).unwrap();
        }
        p.record(0, &[0, 3], &[0.5, 0.5]).unwrap();
        let mut tf = TopFreq::from_profile(&p);
        let ctx = PredictContext { hidden: None, actual: None };
        assert_eq!(tf.predict(0, 2, &ctx), vec![1, 2]);
        assert_eq!(tf.predict(0, 10, &ctx).len(), 4);
    }

    #[test]
    fn oracle_perfect_when_noiseless() {
        let mut o = OracleNoisy::new(0.0, 1);
        let actual = vec![vec![3, 1], vec![1, 2]];
        let ctx = PredictContext { hidden: None, actual: Some(&actual) };
        let p = o.predict(0, 10, &ctx);
        assert_eq!(p, vec![3, 1, 2]);
    }

    #[test]
    fn oracle_noise_drops_experts() {
        let mut o = OracleNoisy::new(1.0, 1);
        let actual = vec![vec![3, 1]];
        let ctx = PredictContext { hidden: None, actual: Some(&actual) };
        assert!(o.predict(0, 10, &ctx).is_empty());
    }

    #[test]
    fn host_router_matches_softmax_props() {
        let cfg = ModelConfig::test_tiny();
        let store = WeightStore::synthetic(&cfg, 3);
        let x: Vec<f32> = (0..cfg.d_model).map(|i| (i as f32) / 7.0 - 1.0).collect();
        let probs = host_router_probs(
            &x,
            cfg.d_model,
            &store.tensor("L0.ln2").unwrap().data,
            store.tensor("L0.wg").unwrap(),
            &store.tensor("L0.rbias").unwrap().data,
            1e-5,
        );
        assert_eq!(probs.len(), cfg.n_experts);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(probs.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn pregate_predicts_from_hidden() {
        let cfg = ModelConfig::test_tiny();
        let store = std::sync::Arc::new(WeightStore::synthetic(&cfg, 3));
        let mut pg = PreGate::new(store, cfg.d_model, cfg.top_k, 1e-5);
        let hidden = Tensor::new(
            vec![2, cfg.d_model],
            (0..2 * cfg.d_model).map(|i| (i % 5) as f32 - 2.0).collect(),
        )
        .unwrap();
        let ctx = PredictContext { hidden: Some(&hidden), actual: None };
        let pred = pg.predict(1, 4, &ctx);
        assert!(!pred.is_empty() && pred.len() <= 4);
        assert!(pred.iter().all(|&e| e < cfg.n_experts));
    }
}
