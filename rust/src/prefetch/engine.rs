//! The prefetch scheduler: turns predictions into prefetch-priority
//! transfers and verifies them against actual routing (Fig 3's
//! "verification step"), escalating mispredictions to demand priority and
//! accounting prefetch hits / speculative waste.

use crate::memory::{LoadDecision, TransferHandle, TransferPriority};
use crate::prefetch::predictor::{PredictContext, Predictor};
use crate::stats::Counters;
use crate::weights::ExpertKey;

pub struct PrefetchEngine {
    handle: TransferHandle,
    /// Max experts to prefetch per (layer, step).
    pub width: usize,
    /// Issued but not yet verified, per layer.
    outstanding: Vec<Vec<usize>>,
    pub counters: Counters,
}

impl PrefetchEngine {
    pub fn new(handle: TransferHandle, n_layers: usize, width: usize) -> Self {
        Self {
            handle,
            width,
            outstanding: vec![Vec::new(); n_layers],
            counters: Counters::new(),
        }
    }

    /// Predict and enqueue prefetches for `layer`.
    pub fn prefetch_layer(
        &mut self,
        layer: usize,
        predictor: &mut dyn Predictor,
        ctx: &PredictContext,
    ) {
        let predicted = predictor.predict(layer, self.width, ctx);
        for &e in &predicted {
            let key = ExpertKey::new(layer, e);
            match self.handle.request(key, TransferPriority::Prefetch) {
                LoadDecision::StartLoad { .. } => {
                    self.counters.inc("prefetch_issued");
                    self.outstanding[layer].push(e);
                }
                LoadDecision::AlreadyGpu => self.counters.inc("prefetch_already_resident"),
                LoadDecision::AlreadyLoading => self.counters.inc("prefetch_inflight"),
                LoadDecision::NoRoom => self.counters.inc("prefetch_no_room"),
            }
        }
    }

    /// Verification step: compare the layer's actual routed experts with
    /// what was prefetched. Escalates still-queued useful prefetches to
    /// demand priority, cancels still-queued useless ones (freeing PCIe
    /// occupancy), and accounts hits vs speculative waste.
    pub fn verify(&mut self, layer: usize, actual_unique: &[usize]) {
        let issued = std::mem::take(&mut self.outstanding[layer]);
        for &e in &issued {
            if actual_unique.contains(&e) {
                self.counters.inc("prefetch_useful");
                self.handle.escalate(ExpertKey::new(layer, e));
            } else {
                self.counters.inc("prefetch_waste");
                if self.handle.cancel_prefetch(ExpertKey::new(layer, e)) {
                    self.counters.inc("prefetch_cancelled");
                }
            }
        }
        for &e in actual_unique {
            if !issued.contains(&e) {
                self.counters.inc("prefetch_unpredicted");
            }
        }
    }

    /// Prefetch hit rate so far (useful / issued).
    pub fn hit_rate(&self) -> f64 {
        self.counters.ratio("prefetch_useful", "prefetch_issued")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::{EvictPolicy, ExpertCache, PcieSim, TransferEngine};
    use crate::prefetch::predictor::{OracleNoisy, TopFreq};
    use crate::profilecollect::ProfileCollector;
    use crate::weights::WeightStore;
    use std::sync::Arc;

    fn handle() -> TransferHandle {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        TransferEngine::spawn(
            cache,
            PcieSim::new(16e9, 0.0, 1.0),
            store,
            crate::util::clock::SimClock::virtual_clock(),
        )
    }

    #[test]
    fn issues_and_verifies() {
        let h = handle();
        let mut pf = PrefetchEngine::new(h.clone(), 3, 2);
        let mut p = ProfileCollector::new(3, 8);
        p.record(0, &[1, 2], &[0.5, 0.5]).unwrap();
        p.record(0, &[1, 3], &[0.5, 0.5]).unwrap();
        let mut tf = TopFreq::from_profile(&p);
        let ctx = PredictContext { hidden: None, actual: None };
        pf.prefetch_layer(0, &mut tf, &ctx);
        assert_eq!(pf.counters.get("prefetch_issued"), 2); // experts 1, 2|3
        pf.verify(0, &[1, 5]);
        assert_eq!(pf.counters.get("prefetch_useful"), 1);
        assert_eq!(pf.counters.get("prefetch_waste"), 1);
        assert_eq!(pf.counters.get("prefetch_unpredicted"), 1);
        assert!((pf.hit_rate() - 0.5).abs() < 1e-9);
        h.shutdown();
    }

    #[test]
    fn oracle_gives_full_hit_rate() {
        let h = handle();
        let mut pf = PrefetchEngine::new(h.clone(), 3, 8);
        let mut o = OracleNoisy::new(0.0, 1);
        let actual = vec![vec![0usize, 1], vec![2usize]];
        let ctx = PredictContext { hidden: None, actual: Some(&actual) };
        pf.prefetch_layer(1, &mut o, &ctx);
        pf.verify(1, &[0, 1, 2]);
        assert_eq!(pf.counters.get("prefetch_waste"), 0);
        assert_eq!(pf.counters.get("prefetch_unpredicted"), 0);
        assert!((pf.hit_rate() - 1.0).abs() < 1e-9);
        h.shutdown();
    }

    #[test]
    fn resident_experts_not_reissued() {
        let h = handle();
        h.with_state(|st| st.admit(ExpertKey::new(0, 1)).unwrap());
        let mut pf = PrefetchEngine::new(h.clone(), 1, 4);
        let mut o = OracleNoisy::new(0.0, 1);
        let actual = vec![vec![1usize]];
        let ctx = PredictContext { hidden: None, actual: Some(&actual) };
        pf.prefetch_layer(0, &mut o, &ctx);
        assert_eq!(pf.counters.get("prefetch_issued"), 0);
        assert_eq!(pf.counters.get("prefetch_already_resident"), 1);
        h.shutdown();
    }
}
