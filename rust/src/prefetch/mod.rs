//! Predictive expert prefetching (paper §2.3, Fig 3).
//!
//! While the GPU computes block *l*, the predictor guesses which experts
//! block *l+1* will need and enqueues prefetch transfers; a verification
//! step escalates mispredicted-but-needed experts to demand priority.
//! Speculative waste (prefetched-but-unused) is accounted for Fig 8.

mod engine;
mod predictor;

pub use engine::PrefetchEngine;
pub use predictor::{host_router_probs, OracleNoisy, PreGate, PredictContext, Predictor, TopFreq};
