//! Named event counters for the serving pipeline (hits, misses,
//! substitutions, gate rejections, ...).

use std::collections::BTreeMap;

use crate::util::json::{num, Json};

#[derive(Debug, Clone, Default)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, n: u64) {
        // Lookup with the borrowed key first: the counter set is tiny and
        // stable, so after warm-up the per-increment hot path never
        // allocates a `String` (asserted by the counting-allocator row in
        // `benches/micro_hotpath.rs`).
        if let Some(v) = self.map.get_mut(name) {
            *v += n;
        } else {
            self.map.insert(name.to_string(), n);
        }
    }

    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.map {
            *self.map.entry(k.clone()).or_insert(0) += v;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.map
                .iter()
                .map(|(k, &v)| (k.clone(), num(v as f64)))
                .collect(),
        )
    }

    /// `a/b` as a fraction, 0 when b == 0 (e.g. hit rates).
    pub fn ratio(&self, a: &str, b: &str) -> f64 {
        let d = self.get(b);
        if d == 0 {
            0.0
        } else {
            self.get(a) as f64 / d as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_get_merge() {
        let mut a = Counters::new();
        a.inc("x");
        a.add("x", 2);
        assert_eq!(a.get("x"), 3);
        assert_eq!(a.get("missing"), 0);
        let mut b = Counters::new();
        b.add("x", 1);
        b.add("y", 5);
        a.merge(&b);
        assert_eq!(a.get("x"), 4);
        assert_eq!(a.get("y"), 5);
    }

    #[test]
    fn ratio_safe() {
        let mut c = Counters::new();
        assert_eq!(c.ratio("a", "b"), 0.0);
        c.add("a", 1);
        c.add("b", 4);
        assert!((c.ratio("a", "b") - 0.25).abs() < 1e-9);
    }

    #[test]
    fn json_export() {
        let mut c = Counters::new();
        c.add("hits", 7);
        assert_eq!(c.to_json().get("hits").unwrap().as_usize().unwrap(), 7);
    }
}
