//! Fixed-bucket histogram (linear buckets) for latency distributions.

#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; n_buckets],
            underflow: 0,
            overflow: 0,
            count: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = (((x - self.lo) / w) as usize).min(n - 1);
            self.buckets[i] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// (bucket midpoint, count) pairs — ready for plotting.
    pub fn series(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + w * (i as f64 + 0.5), c))
            .collect()
    }

    /// Simple ASCII rendering for terminal reports.
    pub fn ascii(&self, width: usize) -> String {
        let max = self.buckets.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (mid, c) in self.series() {
            let bar = "#".repeat((c as usize * width / max as usize).max(usize::from(c > 0)));
            out.push_str(&format!("{mid:10.4} | {bar} {c}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_and_flows() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-1.0);
        h.add(0.5);
        h.add(9.9);
        h.add(10.0);
        h.add(42.0);
        assert_eq!(h.count(), 5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.bucket_counts()[0], 1);
        assert_eq!(h.bucket_counts()[9], 1);
    }

    #[test]
    fn series_midpoints() {
        let mut h = Histogram::new(0.0, 2.0, 2);
        h.add(0.1);
        let s = h.series();
        assert_eq!(s.len(), 2);
        assert!((s[0].0 - 0.5).abs() < 1e-9);
        assert_eq!(s[0].1, 1);
    }

    #[test]
    fn ascii_renders() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(0.1);
        h.add(0.1);
        let a = h.ascii(20);
        assert!(a.contains('#'));
    }
}
