//! Streaming summary: count/mean/min/max plus exact percentiles over the
//! retained samples (sample counts here are small enough to retain all).

use crate::util::math::percentile;

#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f32>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x as f32);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&x| x as f64).sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY as f32, f32::min) as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64
    }

    pub fn p(&self, pct: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        percentile(&self.samples, pct) as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let v = self
            .samples
            .iter()
            .map(|&x| (x as f64 - m).powi(2))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        v.sqrt()
    }

    /// `mean ± std [p50 p95 p99] (n)` line for reports.
    pub fn report(&self, unit: &str) -> String {
        format!(
            "{:.4}{u} ± {:.4} [p50 {:.4} p95 {:.4} p99 {:.4}] (n={})",
            self.mean(),
            self.std(),
            self.p(50.0),
            self.p(95.0),
            self.p(99.0),
            self.count(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-9);
        assert!((s.min() - 1.0).abs() < 1e-9);
        assert!((s.max() - 4.0).abs() < 1e-9);
        assert!((s.p(50.0) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p(50.0), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Summary::new();
        for _ in 0..5 {
            s.add(7.0);
        }
        assert!(s.std() < 1e-9);
    }
}
