//! Lightweight metrics primitives: streaming summaries, fixed-bucket
//! histograms, and named counters used by the server and benches.

mod counters;
mod hist;
mod summary;

pub use counters::Counters;
pub use hist::Histogram;
pub use summary::Summary;
