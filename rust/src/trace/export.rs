//! Trace exporters: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`) and compact JSONL.
//!
//! Both formats are rendered through `util::json`, so output bytes are
//! a pure function of the event list — per-seed byte-identity of the
//! trace file follows from per-seed byte-identity of the ring.

use std::collections::BTreeSet;

use super::event::{TraceEvent, Track};
use crate::util::json::{num, obj, s, Json};

/// Microseconds (Chrome's native trace unit) from a virtual timestamp,
/// keeping sub-microsecond precision as a fraction.
fn micros(d: std::time::Duration) -> f64 {
    d.as_nanos() as f64 / 1000.0
}

fn args_json(ev: &TraceEvent) -> Json {
    obj(ev.args().iter().map(|&(k, v)| (k, num(v as f64))).collect())
}

/// Render events as a Chrome trace-event JSON document: one `pid` for
/// the sim, one `tid` (with a `thread_name` metadata record) per
/// [`Track`], `X` complete events for spans and `i` instants for point
/// events. Tracks are numbered in sorted `Track` order so the mapping is
/// stable across runs.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let tracks: BTreeSet<Track> = events.iter().map(|e| e.track).collect();
    let tid_of = |t: Track| -> i64 {
        tracks.iter().position(|&x| x == t).map(|i| i as i64 + 1).unwrap_or(0)
    };

    let mut out: Vec<Json> = Vec::with_capacity(events.len() + tracks.len() + 1);
    out.push(obj(vec![
        ("ph", s("M")),
        ("pid", num(0.0)),
        ("tid", num(0.0)),
        ("name", s("process_name")),
        ("args", obj(vec![("name", s("buddymoe-sim"))])),
    ]));
    for &track in &tracks {
        out.push(obj(vec![
            ("ph", s("M")),
            ("pid", num(0.0)),
            ("tid", num(tid_of(track) as f64)),
            ("name", s("thread_name")),
            ("args", obj(vec![("name", s(&track.label()))])),
        ]));
    }
    for ev in events {
        let mut fields = vec![
            ("pid", num(0.0)),
            ("tid", num(tid_of(ev.track) as f64)),
            ("ts", num(micros(ev.ts))),
            ("name", s(ev.name)),
            ("args", args_json(ev)),
        ];
        match ev.dur {
            Some(d) => {
                fields.push(("ph", s("X")));
                fields.push(("dur", num(micros(d))));
            }
            None => {
                fields.push(("ph", s("i")));
                fields.push(("s", s("t")));
            }
        }
        out.push(obj(fields));
    }

    obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", s("ms")),
    ])
    .to_string()
        + "\n"
}

/// Render events as compact JSONL: one object per line, integer
/// nanosecond timestamps, args nested under `"args"`.
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        let mut fields = vec![
            ("ts_ns", num(ev.ts.as_nanos() as f64)),
            ("track", s(&ev.track.label())),
            ("name", s(ev.name)),
        ];
        if let Some(d) = ev.dur {
            fields.push(("dur_ns", num(d.as_nanos() as f64)));
        }
        if ev.n_args > 0 {
            fields.push(("args", args_json(ev)));
        }
        out.push_str(&obj(fields).to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent::new(
                Duration::from_millis(1),
                Some(Duration::from_millis(2)),
                Track::Engine,
                "decode_step",
                &[("batch", 4)],
            ),
            TraceEvent::new(
                Duration::from_micros(1500),
                None,
                Track::HostLink(0),
                "enqueue",
                &[("layer", 2), ("expert", 7)],
            ),
        ]
    }

    #[test]
    fn chrome_trace_has_metadata_and_both_phases() {
        let text = chrome_trace(&sample());
        let j = Json::parse(&text).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // process_name + 2 thread_names + 2 events.
        assert_eq!(events.len(), 5);
        assert!(text.contains("\"thread_name\""));
        assert!(text.contains("\"engine\""));
        assert!(text.contains("\"host-link-0\""));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
        // Chrome ts unit is microseconds: 1 ms span starts at ts 1000.
        assert!(text.contains("\"ts\":1000"));
        assert!(text.contains("\"dur\":2000"));
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let text = jsonl(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("ts_ns").is_ok());
            assert!(j.get("track").is_ok());
        }
        assert!(lines[0].contains("\"dur_ns\":2000000"));
        assert!(lines[1].contains("\"expert\":7"));
    }

    #[test]
    fn export_is_deterministic() {
        let evs = sample();
        assert_eq!(chrome_trace(&evs), chrome_trace(&evs));
        assert_eq!(jsonl(&evs), jsonl(&evs));
    }
}
