//! The event vocabulary: named tracks and fixed-size, heap-free events.

use std::time::Duration;

/// Maximum key/value args an event may carry. Fixed so [`TraceEvent`] is
/// `Copy` and recording never allocates per event payload.
pub const MAX_TRACE_ARGS: usize = 4;

/// A named timeline in the exported trace. Tracks map 1:1 to Perfetto
/// "threads": one per device, host link, peer link, and request, plus
/// the engine / scheduler / fault singletons.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Track {
    /// The engine's orchestration loop: decode steps, pin windows,
    /// routing, stall windows.
    Engine,
    /// Admission and release decisions.
    Scheduler,
    /// Overload-protection decisions: load sheds and brownout
    /// enter/exit transitions (admission control enabled only — the
    /// track never appears in a default-config export).
    Admission,
    /// Fault ticks from the `FaultTimeline`.
    Fault,
    /// Per-device cache-side events.
    Device(usize),
    /// A device's serialized host PCIe link: enqueue → transfer → land,
    /// retries, timeouts.
    HostLink(usize),
    /// A contended peer-fabric link (per-edge on the ring).
    PeerLink(usize),
    /// One request's lifetime: admit → prefill → done.
    Request(u64),
}

impl Track {
    /// Stable display name used for the Perfetto `thread_name` metadata
    /// and the JSONL `track` field.
    pub fn label(&self) -> String {
        match self {
            Track::Engine => "engine".to_string(),
            Track::Scheduler => "scheduler".to_string(),
            Track::Admission => "admission".to_string(),
            Track::Fault => "faults".to_string(),
            Track::Device(d) => format!("device-{d}"),
            Track::HostLink(d) => format!("host-link-{d}"),
            Track::PeerLink(l) => format!("peer-link-{l}"),
            Track::Request(id) => format!("request-{id}"),
        }
    }
}

/// One recorded moment: an instant (`dur == None`) or a complete span.
/// `Copy` and allocation-free by construction — args are a bounded
/// inline array of integer key/values with `'static` keys.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Virtual timestamp (from `SimClock::now`) of the event start.
    pub ts: Duration,
    /// Span length; `None` marks an instant event.
    pub dur: Option<Duration>,
    pub track: Track,
    pub name: &'static str,
    /// Number of valid entries in `args`.
    pub n_args: u8,
    pub args: [(&'static str, i64); MAX_TRACE_ARGS],
}

impl TraceEvent {
    /// Build an event from a caller-side stack slice of args (extra args
    /// beyond [`MAX_TRACE_ARGS`] are dropped).
    pub fn new(
        ts: Duration,
        dur: Option<Duration>,
        track: Track,
        name: &'static str,
        args: &[(&'static str, i64)],
    ) -> Self {
        let mut packed = [("", 0i64); MAX_TRACE_ARGS];
        let n = args.len().min(MAX_TRACE_ARGS);
        packed[..n].copy_from_slice(&args[..n]);
        Self { ts, dur, track, name, n_args: n as u8, args: packed }
    }

    /// The valid arg entries.
    pub fn args(&self) -> &[(&'static str, i64)] {
        &self.args[..self.n_args as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn track_labels_are_stable() {
        assert_eq!(Track::Engine.label(), "engine");
        assert_eq!(Track::Scheduler.label(), "scheduler");
        assert_eq!(Track::Admission.label(), "admission");
        assert_eq!(Track::Fault.label(), "faults");
        assert_eq!(Track::Device(2).label(), "device-2");
        assert_eq!(Track::HostLink(0).label(), "host-link-0");
        assert_eq!(Track::PeerLink(3).label(), "peer-link-3");
        assert_eq!(Track::Request(17).label(), "request-17");
    }

    #[test]
    fn event_packs_and_truncates_args() {
        let ev = TraceEvent::new(
            Duration::from_millis(5),
            None,
            Track::Engine,
            "route",
            &[("layer", 1), ("unique", 4), ("fetches", 2), ("subs", 1), ("extra", 9)],
        );
        assert_eq!(ev.args().len(), MAX_TRACE_ARGS);
        assert_eq!(ev.args()[0], ("layer", 1));
        assert_eq!(ev.args()[3], ("subs", 1));
        assert!(ev.dur.is_none());
    }
}
