//! Bounded overwrite-oldest ring used by the global and per-request
//! flight recorders. Dropped-event counts are kept so an exported trace
//! can say it is a suffix, never silently pretend completeness.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: VecDeque<T>,
    cap: usize,
    dropped: u64,
}

impl<T> Ring<T> {
    /// `cap` is clamped to at least 1 so a ring can always hold the most
    /// recent event.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { buf: VecDeque::with_capacity(cap), cap, dropped: 0 }
    }

    pub fn push(&mut self, item: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(item);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events evicted to make room (0 means the ring saw everything).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Oldest-to-newest iteration.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overwrites_oldest_and_counts_drops() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = Ring::new(0);
        r.push(7);
        r.push(8);
        assert_eq!(r.len(), 1);
        assert_eq!(r.capacity(), 1);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![8]);
    }
}
