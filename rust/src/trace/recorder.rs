//! The shared [`Tracer`] handle and the [`Recorder`] behind it.
//!
//! `Tracer` is the only type instrumentation sites see. Cloning is an
//! `Arc` bump; the disabled handle ([`Tracer::off`]) holds no recorder,
//! so every record method is one branch and returns — no lock, no
//! allocation, no formatting. Callers therefore pass args as stack
//! slices (`&[("layer", l as i64)]`) and never pre-format strings.
//!
//! Determinism: all timestamps are supplied by callers from `SimClock`,
//! and all callers are single-threaded orchestration code (engine loop,
//! transfer handle under its state lock, scheduler), so ring order is
//! the deterministic discrete-event order regardless of kernel thread
//! count.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::attribution::{attribute, Intervals, RequestAttribution};
use super::event::{TraceEvent, Track};
use super::ring::Ring;

/// Categories of globally-recorded stall intervals consumed by the
/// attribution pass (see [`super::attribution`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallKind {
    /// `run_moe` blocked on demand fetches.
    TransferWait,
    /// Backoff between transfer re-issues (nested inside a
    /// `TransferWait` window).
    RetryBackoff,
    /// Transient stream-through rescue (degradation waterfall arm).
    Waterfall,
}

impl StallKind {
    fn span_name(&self) -> &'static str {
        match self {
            StallKind::TransferWait => "transfer_wait",
            StallKind::RetryBackoff => "retry_backoff",
            StallKind::Waterfall => "transient_fetch",
        }
    }
}

/// Per-request flight recorder: the request's own bounded ring plus the
/// bracketing timestamps the attribution pass needs.
#[derive(Debug, Clone)]
struct Flight {
    ring: Ring<TraceEvent>,
    arrived: Duration,
    admitted: Duration,
}

/// How many finished flight-recorder rings to retain for post-mortems.
const FINISHED_FLIGHTS_KEPT: usize = 64;

/// Default per-request flight-recorder capacity (events).
pub const PER_REQUEST_RING: usize = 512;

/// The in-memory sink: a bounded global ring, per-request flight
/// recorders, the global stall-interval categories, and finished-request
/// attributions.
#[derive(Debug)]
pub struct Recorder {
    global: Ring<TraceEvent>,
    per_request_cap: usize,
    active: BTreeMap<u64, Flight>,
    finished_flights: VecDeque<(u64, Ring<TraceEvent>)>,
    finished: Vec<RequestAttribution>,
    transfer_wait: Intervals,
    retry_backoff: Intervals,
    waterfall: Intervals,
}

impl Recorder {
    pub fn new(global_cap: usize, per_request_cap: usize) -> Self {
        Self {
            global: Ring::new(global_cap),
            per_request_cap: per_request_cap.max(1),
            active: BTreeMap::new(),
            finished_flights: VecDeque::new(),
            finished: Vec::new(),
            transfer_wait: Intervals::default(),
            retry_backoff: Intervals::default(),
            waterfall: Intervals::default(),
        }
    }

    /// Append to the global ring and mirror into every active request's
    /// flight recorder (each bounded on its own).
    fn record(&mut self, ev: TraceEvent) {
        for flight in self.active.values_mut() {
            flight.ring.push(ev);
        }
        self.global.push(ev);
    }

    fn stall(&mut self, kind: StallKind, start: Duration, end: Duration) {
        match kind {
            StallKind::TransferWait => self.transfer_wait.push(start, end),
            StallKind::RetryBackoff => self.retry_backoff.push(start, end),
            StallKind::Waterfall => self.waterfall.push(start, end),
        }
    }

    fn begin_request(&mut self, id: u64, arrived: Duration, admitted: Duration) {
        self.active.insert(
            id,
            Flight { ring: Ring::new(self.per_request_cap), arrived, admitted },
        );
    }

    fn finish_request(
        &mut self,
        id: u64,
        done: Duration,
        degraded: bool,
    ) -> Option<RequestAttribution> {
        let flight = self.active.remove(&id)?;
        let attr = attribute(
            id,
            flight.arrived,
            flight.admitted,
            done,
            degraded,
            &self.transfer_wait,
            &self.retry_backoff,
            &self.waterfall,
        );
        self.finished.push(attr);
        self.finished_flights.push_back((id, flight.ring));
        if self.finished_flights.len() > FINISHED_FLIGHTS_KEPT {
            self.finished_flights.pop_front();
        }
        // Intervals older than every still-active request can never be
        // charged again — drop them so long runs stay bounded.
        let horizon = self.active.values().map(|f| f.admitted).min().unwrap_or(done);
        self.transfer_wait.prune(horizon);
        self.retry_backoff.prune(horizon);
        self.waterfall.prune(horizon);
        Some(attr)
    }

    pub fn events(&self) -> Vec<TraceEvent> {
        self.global.iter().copied().collect()
    }

    pub fn dropped(&self) -> u64 {
        self.global.dropped()
    }

    pub fn attributions(&self) -> &[RequestAttribution] {
        &self.finished
    }

    /// Flight-recorder contents for `id`: active requests first, then
    /// the bounded retained set of finished ones.
    pub fn request_events(&self, id: u64) -> Option<Vec<TraceEvent>> {
        if let Some(f) = self.active.get(&id) {
            return Some(f.ring.iter().copied().collect());
        }
        self.finished_flights
            .iter()
            .find(|(fid, _)| *fid == id)
            .map(|(_, ring)| ring.iter().copied().collect())
    }
}

/// The cheap, cloneable handle threaded through the serving stack.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Mutex<Recorder>>>,
}

impl Tracer {
    /// The no-op sink: no recorder exists, record calls are one branch.
    pub fn off() -> Self {
        Self { inner: None }
    }

    /// An enabled tracer backed by bounded in-memory rings.
    pub fn ring(global_cap: usize) -> Self {
        Self {
            inner: Some(Arc::new(Mutex::new(Recorder::new(global_cap, PER_REQUEST_RING)))),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn with<R>(&self, f: impl FnOnce(&mut Recorder) -> R) -> Option<R> {
        let inner = self.inner.as_ref()?;
        let mut rec = inner.lock().unwrap_or_else(|e| e.into_inner());
        Some(f(&mut rec))
    }

    /// Record an instant event. `args` is a caller stack slice — nothing
    /// is evaluated or allocated when the tracer is off.
    #[inline]
    pub fn instant(
        &self,
        ts: Duration,
        track: Track,
        name: &'static str,
        args: &[(&'static str, i64)],
    ) {
        if self.inner.is_none() {
            return;
        }
        self.with(|r| r.record(TraceEvent::new(ts, None, track, name, args)));
    }

    /// Record a complete span `[t0, t1]` (emitted once both ends are
    /// known, which keeps ring order deterministic).
    #[inline]
    pub fn span(
        &self,
        t0: Duration,
        t1: Duration,
        track: Track,
        name: &'static str,
        args: &[(&'static str, i64)],
    ) {
        if self.inner.is_none() {
            return;
        }
        self.with(|r| {
            r.record(TraceEvent::new(t0, Some(t1.saturating_sub(t0)), track, name, args))
        });
    }

    /// Record a categorized stall interval *and* its span event (named
    /// by the category, on `track`).
    #[inline]
    pub fn stall(
        &self,
        kind: StallKind,
        t0: Duration,
        t1: Duration,
        track: Track,
        args: &[(&'static str, i64)],
    ) {
        if self.inner.is_none() {
            return;
        }
        self.with(|r| {
            r.stall(kind, t0, t1);
            r.record(TraceEvent::new(
                t0,
                Some(t1.saturating_sub(t0)),
                track,
                kind.span_name(),
                args,
            ));
        });
    }

    /// Open a request's flight recorder and emit its `admit` instant and
    /// `queued` span.
    #[inline]
    pub fn begin_request(&self, id: u64, arrived: Duration, admitted: Duration) {
        if self.inner.is_none() {
            return;
        }
        self.with(|r| {
            r.begin_request(id, arrived, admitted);
            r.record(TraceEvent::new(
                arrived,
                Some(admitted.saturating_sub(arrived)),
                Track::Request(id),
                "queued",
                &[],
            ));
            r.record(TraceEvent::new(
                admitted,
                None,
                Track::Scheduler,
                "admit",
                &[("id", id as i64)],
            ));
        });
    }

    /// Close a request: run the attribution pass, emit the `done`
    /// instant, retire its flight recorder.
    #[inline]
    pub fn finish_request(
        &self,
        id: u64,
        done: Duration,
        degraded: bool,
    ) -> Option<RequestAttribution> {
        if self.inner.is_none() {
            return None;
        }
        self.with(|r| {
            r.record(TraceEvent::new(
                done,
                None,
                Track::Request(id),
                "done",
                &[("degraded", degraded as i64)],
            ));
            r.finish_request(id, done, degraded)
        })
        .flatten()
    }

    /// Snapshot of all finished-request attributions, in completion order.
    pub fn attributions(&self) -> Vec<RequestAttribution> {
        self.with(|r| r.attributions().to_vec()).unwrap_or_default()
    }

    /// Snapshot of the global ring.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.with(|r| r.events()).unwrap_or_default()
    }

    /// Events evicted from the global ring (trace is a suffix if > 0).
    pub fn dropped(&self) -> u64 {
        self.with(|r| r.dropped()).unwrap_or(0)
    }

    /// One request's flight-recorder contents, if still retained.
    pub fn request_events(&self, id: u64) -> Option<Vec<TraceEvent>> {
        self.with(|r| r.request_events(id)).flatten()
    }

    /// Export the global ring as Chrome trace-event JSON (Perfetto).
    pub fn export_chrome(&self) -> String {
        super::export::chrome_trace(&self.events())
    }

    /// Export the global ring as compact JSONL.
    pub fn export_jsonl(&self) -> String {
        super::export::jsonl(&self.events())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn off_tracer_records_nothing() {
        let t = Tracer::off();
        assert!(!t.enabled());
        t.instant(ms(1), Track::Engine, "route", &[("layer", 0)]);
        t.span(ms(1), ms(2), Track::Engine, "decode_step", &[]);
        t.stall(StallKind::TransferWait, ms(1), ms(2), Track::Engine, &[]);
        t.begin_request(1, ms(0), ms(1));
        assert!(t.finish_request(1, ms(3), false).is_none());
        assert!(t.events().is_empty());
        assert!(t.attributions().is_empty());
    }

    #[test]
    fn flight_recorder_mirrors_while_active() {
        let t = Tracer::ring(128);
        t.begin_request(7, ms(0), ms(1));
        t.instant(ms(2), Track::Engine, "route", &[("layer", 0)]);
        t.stall(StallKind::TransferWait, ms(2), ms(5), Track::Engine, &[]);
        let attr = t.finish_request(7, ms(6), false).unwrap();
        assert_eq!(attr.queue, ms(1));
        assert_eq!(attr.transfer_wait, ms(3));
        assert_eq!(attr.compute, ms(2));
        assert_eq!(attr.bucket_sum(), attr.total());
        // The flight recorder kept the events seen while active.
        let evs = t.request_events(7).unwrap();
        assert!(evs.iter().any(|e| e.name == "route"));
        assert!(evs.iter().any(|e| e.name == "transfer_wait"));
        // Events after the request finished do not retro-append.
        t.instant(ms(9), Track::Engine, "route", &[]);
        assert_eq!(t.request_events(7).unwrap().len(), evs.len());
    }

    #[test]
    fn attribution_is_per_request_overlap() {
        let t = Tracer::ring(128);
        t.begin_request(1, ms(0), ms(0));
        t.begin_request(2, ms(0), ms(10));
        // A stall both requests ride out, and one only request 2 sees.
        t.stall(StallKind::TransferWait, ms(12), ms(20), Track::Engine, &[]);
        let a1 = t.finish_request(1, ms(16), false).unwrap();
        t.stall(StallKind::Waterfall, ms(20), ms(24), Track::Engine, &[]);
        let a2 = t.finish_request(2, ms(30), true).unwrap();
        assert_eq!(a1.transfer_wait, ms(4)); // clipped at done=16
        assert_eq!(a2.transfer_wait, ms(8));
        assert_eq!(a2.waterfall, ms(4));
        assert_eq!(a1.bucket_sum(), a1.total());
        assert_eq!(a2.bucket_sum(), a2.total());
        assert_eq!(t.attributions().len(), 2);
    }
}
