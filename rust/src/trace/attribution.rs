//! Stall attribution: decompose a request's end-to-end latency into
//! buckets that sum *exactly* to the measured total.
//!
//! All arithmetic is integer [`Duration`] — no floats anywhere in the
//! accounting, so the decomposition is bit-exact and independent of
//! summation order. Seconds are derived only at JSON-export time.
//!
//! The engine records three categories of *global* stall intervals on
//! the virtual timeline (see [`super::recorder`]):
//!
//! - **transfer-wait** — `run_moe` blocked on demand fetches,
//! - **retry-backoff** — seeded-jitter backoff inside `wait_gpu`
//!   (always nested inside a transfer-wait window),
//! - **waterfall** — transient stream-through rescues (the waterfall's
//!   lossless arm), disjoint from the wait windows.
//!
//! Intervals within a category never overlap: they are opened and
//! closed sequentially by single-threaded orchestration code under a
//! monotone clock. A request admitted at `a` and finished at `d` is
//! charged the clipped overlap of each category with `[a, d]` — a stall
//! shared by a whole batch is charged to every co-resident request,
//! which is exactly what "where did *this* request's time go" means.
//! The remainder of `[a, d]` is compute; `[arrived, a]` is queueing.

use std::time::Duration;

use crate::util::json::{num, obj, Json};

/// Non-overlapping, time-ordered stall intervals for one category.
#[derive(Debug, Clone, Default)]
pub struct Intervals {
    spans: Vec<(Duration, Duration)>,
}

impl Intervals {
    /// Record `[start, end)`; empty or inverted intervals are ignored.
    /// Callers append in non-decreasing time order (enforced by the
    /// single-threaded orchestration contract, debug-asserted here).
    pub fn push(&mut self, start: Duration, end: Duration) {
        if end <= start {
            return;
        }
        if let Some(&(_, last_end)) = self.spans.last() {
            debug_assert!(start >= last_end, "stall intervals must not overlap");
        }
        self.spans.push((start, end));
    }

    /// Exact total overlap of the recorded intervals with `[a, b)`.
    pub fn overlap(&self, a: Duration, b: Duration) -> Duration {
        let mut total = Duration::ZERO;
        for &(s, e) in &self.spans {
            let lo = s.max(a);
            let hi = e.min(b);
            if hi > lo {
                total += hi - lo;
            }
        }
        total
    }

    /// Drop intervals that ended at or before `before` (no live request
    /// can overlap them anymore).
    pub fn prune(&mut self, before: Duration) {
        self.spans.retain(|&(_, e)| e > before);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }
}

/// Where one finished request's wall time went. The five buckets sum
/// exactly (integer nanoseconds) to `total()`:
///
/// `queue + compute + transfer_wait + retry_backoff + waterfall == done - arrived`
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestAttribution {
    pub id: u64,
    pub arrived: Duration,
    pub admitted: Duration,
    pub done: Duration,
    /// Waiting for admission: `admitted - arrived`.
    pub queue: Duration,
    /// Residual of the active span not charged to any stall bucket.
    pub compute: Duration,
    /// Blocked on demand fetches, excluding nested retry backoff.
    pub transfer_wait: Duration,
    /// Seeded-jitter backoff between transfer re-issues.
    pub retry_backoff: Duration,
    /// Transient stream-through rescues (degradation waterfall).
    pub waterfall: Duration,
    /// The response carried the degraded annotation.
    pub degraded: bool,
}

impl RequestAttribution {
    /// Measured end-to-end latency (`done - arrived`).
    pub fn total(&self) -> Duration {
        self.done.saturating_sub(self.arrived)
    }

    /// Exact bucket sum — equals `total()` bit-for-bit (property-tested).
    pub fn bucket_sum(&self) -> Duration {
        self.queue + self.compute + self.transfer_wait + self.retry_backoff + self.waterfall
    }

    /// JSON row for the bench artifacts: exact integer nanoseconds per
    /// bucket plus a human-scale `total_s`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("total_s", num(self.total().as_secs_f64())),
            ("total_ns", num(self.total().as_nanos() as f64)),
            ("queue_ns", num(self.queue.as_nanos() as f64)),
            ("compute_ns", num(self.compute.as_nanos() as f64)),
            ("transfer_wait_ns", num(self.transfer_wait.as_nanos() as f64)),
            ("retry_backoff_ns", num(self.retry_backoff.as_nanos() as f64)),
            ("waterfall_ns", num(self.waterfall.as_nanos() as f64)),
            ("degraded", Json::Bool(self.degraded)),
        ])
    }
}

/// Run the attribution pass for one finished request against the global
/// stall-interval categories. Exactness argument: the three categories
/// clip to the active span `[admitted, done)`; backoff intervals are
/// nested inside transfer-wait intervals and waterfall intervals are
/// disjoint from both, so `wait_total + waterfall <= active` and
/// `backoff <= wait_total`, making every `saturating_sub` exact and the
/// bucket identity hold bit-for-bit.
pub fn attribute(
    id: u64,
    arrived: Duration,
    admitted: Duration,
    done: Duration,
    degraded: bool,
    transfer_wait: &Intervals,
    retry_backoff: &Intervals,
    waterfall: &Intervals,
) -> RequestAttribution {
    let queue = admitted.saturating_sub(arrived);
    let active = done.saturating_sub(admitted);
    let wait_total = transfer_wait.overlap(admitted, done);
    let backoff = retry_backoff.overlap(admitted, done);
    let wf = waterfall.overlap(admitted, done);
    let wait = wait_total.saturating_sub(backoff);
    let compute = active.saturating_sub(wait_total).saturating_sub(wf);
    RequestAttribution {
        id,
        arrived,
        admitted,
        done,
        queue,
        compute,
        transfer_wait: wait,
        retry_backoff: backoff,
        waterfall: wf,
        degraded,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn overlap_clips_exactly() {
        let mut iv = Intervals::default();
        iv.push(ms(10), ms(20));
        iv.push(ms(30), ms(40));
        assert_eq!(iv.overlap(ms(0), ms(100)), ms(20));
        assert_eq!(iv.overlap(ms(15), ms(35)), ms(10));
        assert_eq!(iv.overlap(ms(20), ms(30)), ms(0));
        iv.prune(ms(20));
        assert_eq!(iv.len(), 1);
    }

    #[test]
    fn degenerate_intervals_ignored() {
        let mut iv = Intervals::default();
        iv.push(ms(5), ms(5));
        iv.push(ms(7), ms(6));
        assert!(iv.is_empty());
    }

    #[test]
    fn buckets_sum_exactly_to_total() {
        let mut wait = Intervals::default();
        let mut backoff = Intervals::default();
        let mut wf = Intervals::default();
        // Wait window [10, 30) with a nested backoff [12, 18); a later
        // transient rescue [40, 45).
        wait.push(ms(10), ms(30));
        backoff.push(ms(12), ms(18));
        wf.push(ms(40), ms(45));
        let a = attribute(1, ms(2), ms(8), ms(50), true, &wait, &backoff, &wf);
        assert_eq!(a.queue, ms(6));
        assert_eq!(a.transfer_wait, ms(14));
        assert_eq!(a.retry_backoff, ms(6));
        assert_eq!(a.waterfall, ms(5));
        assert_eq!(a.compute, ms(17));
        assert_eq!(a.bucket_sum(), a.total());
        assert!(a.degraded);
    }

    #[test]
    fn partial_overlap_is_charged_pro_rata() {
        let mut wait = Intervals::default();
        wait.push(ms(0), ms(100));
        let empty = Intervals::default();
        // Active span [40, 60) sits inside the wait window.
        let a = attribute(2, ms(40), ms(40), ms(60), false, &wait, &empty, &empty);
        assert_eq!(a.queue, ms(0));
        assert_eq!(a.transfer_wait, ms(20));
        assert_eq!(a.compute, ms(0));
        assert_eq!(a.bucket_sum(), a.total());
    }
}
