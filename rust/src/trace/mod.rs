//! Deterministic tracing and stall attribution for the serving stack.
//!
//! Every interesting moment in the discrete-event sim — decode steps and
//! pin windows, per-layer routing, transfer lifecycles on host and peer
//! links, ψ substitutions and each degradation-waterfall arm, fault
//! ticks, scheduler admission/release — can be recorded as a
//! [`TraceEvent`] stamped *only* from the serving stack's
//! [`crate::util::clock::SimClock`]. Events land in a bounded global
//! ring plus a bounded per-request flight-recorder ring, and export as
//! Chrome trace-event JSON (loadable in Perfetto or `chrome://tracing`,
//! one named track per device/link/request) or compact JSONL.
//!
//! # Sink contract (who may emit, and when it costs nothing)
//!
//! - The sink is selected by [`TraceSink`] (`ServingConfig::trace`).
//!   With [`TraceSink::Off`] the shared [`Tracer`] handle holds no
//!   recorder at all: every record method is `#[inline]` and returns
//!   after one `Option` check — no allocation, no lock, no formatting.
//!   All golden sweeps are byte-identical with tracing off because the
//!   instrumentation is unobservable.
//! - Spans may be emitted only from single-threaded orchestration code:
//!   the engine's step loop, `TransferHandle` methods under the engine
//!   state lock, and the scheduler. Kernel worker threads
//!   (`util::par`) must never touch the tracer — that is what makes an
//!   enabled trace byte-identical across `PALLAS_THREADS` settings by
//!   construction.
//! - Timestamps come only from `SimClock`. No wall clock, ever. Under
//!   `ClockMode::Virtual` the same seed therefore replays the same
//!   trace file byte for byte (golden-tested in `tests/trace.rs`).
//!
//! # Stall attribution
//!
//! On top of the raw spans, [`Recorder::finish_request`] decomposes
//! each finished request's end-to-end latency into
//! queue / compute / transfer-wait / retry-backoff / waterfall-arm
//! buckets ([`RequestAttribution`]). All arithmetic is integer
//! [`std::time::Duration`] — the buckets sum *exactly* (bit for bit) to
//! the measured total, no float drift, property-tested including
//! degraded and faulted requests. The load and fault sweeps surface the
//! p99 request's breakdown per cell in `BENCH_load.json` /
//! `BENCH_faults.json`.
//!
//! # Reading a trace in Perfetto
//!
//! 1. Run a traced cell, e.g.
//!    `cargo run --release --example sweep_load -- --fast` — it writes
//!    `TRACE_load.json` next to `Cargo.toml` (CI uploads it as an
//!    artifact). A small checked-in example lives at
//!    `rust/tests/data/example_trace_perfetto.json`.
//! 2. Open <https://ui.perfetto.dev> (or `chrome://tracing`) and drag
//!    the JSON file in.
//! 3. Tracks: `engine` carries `decode_step` / `pin_window` /
//!    `transfer_wait` spans and per-layer `route` instants;
//!    `host-link-N` carries each device's host-PCIe lifecycle
//!    (`enqueue` → `transfer` → `land`, plus `retry_backoff` /
//!    `timeout`); `peer-link-N` carries `peer_xfer` hops; `faults`
//!    carries fault ticks; `request-N` brackets each request from
//!    `admit` to `done` with its `queued` span and prefill.
//! 4. The `dur` of a `transfer_wait` span on `engine` is exactly the
//!    time `run_moe` blocked on demand fetches — the same interval the
//!    attribution pass charges to overlapping requests.

pub mod attribution;
pub mod event;
pub mod export;
pub mod recorder;
pub mod ring;

pub use attribution::RequestAttribution;
pub use event::{TraceEvent, Track, MAX_TRACE_ARGS};
pub use export::{chrome_trace, jsonl};
pub use recorder::{Recorder, StallKind, Tracer};
pub use ring::Ring;

/// Where trace events go. The `Off` arm is the zero-cost no-op sink;
/// `Ring` records into the bounded in-memory rings this module owns.
/// (Streaming sinks can slot in here later without touching call sites.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceSink {
    /// No recorder is allocated; every record call is a single branch.
    #[default]
    Off,
    /// Bounded in-memory global + per-request rings, exportable as
    /// Chrome trace JSON or JSONL.
    Ring,
}

impl TraceSink {
    pub fn is_on(&self) -> bool {
        matches!(self, TraceSink::Ring)
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceSink::Off => "off",
            TraceSink::Ring => "ring",
        }
    }

    /// Parse a config string (`off` / `ring`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(TraceSink::Off),
            "ring" => Some(TraceSink::Ring),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_parse_roundtrip() {
        for sink in [TraceSink::Off, TraceSink::Ring] {
            assert_eq!(TraceSink::parse(sink.name()), Some(sink));
        }
        assert_eq!(TraceSink::parse("tcp"), None);
        assert!(!TraceSink::default().is_on());
        assert!(TraceSink::Ring.is_on());
    }
}
