//! PJRT runtime bridge: load AOT HLO-text artifacts, compile them on the
//! CPU PJRT client, and execute them from the serving hot path.
//!
//! Interchange is HLO **text** (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).

mod artifacts;
mod exec;

pub use artifacts::{ArtifactRegistry, Runtime};
pub use exec::{lit_i32, lit_tensor, tensor_from_lit, ExecOutputs};
