//! Stage-execution backends.
//!
//! The serving engine orchestrates the model as a sequence of *stages*
//! (embed, attention, router, expert FFN, lm head). This module defines
//! the [`StageRunner`] contract the engine drives, with two backends:
//!
//! * **Reference** ([`RefStages`], always available) — a pure-Rust
//!   interpreter of the stage math, numerically mirroring
//!   `python/compile/kernels/ref.py` / `model.py`. It needs no artifacts
//!   and no PJRT, so the full serving pipeline (cache, transfers, buddy
//!   substitution, continuous batching) runs anywhere — this is what the
//!   integration tests exercise against synthetic weights.
//! * **PJRT** (`PjrtStages`, behind the `pjrt` cargo feature) — loads AOT
//!   HLO-text artifacts, compiles them on the CPU PJRT client (`xla`
//!   crate), and executes them from the hot path. Interchange is HLO
//!   **text** (not serialized protos): jax >= 0.5 emits 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
//!   (see DESIGN.md).
//!
//! Decode attention reads KV caches through the borrowed [`KvSource`]
//! view instead of owned `[bb, s, d]` tensors (PR 5): the reference
//! backend indexes each sequence's cache in place, the PJRT backend
//! materializes the view once at this boundary ([`materialize_kv`],
//! audited by [`kv_copy_bytes`]).

pub mod kernels;
mod reference;

#[cfg(feature = "pjrt")]
mod artifacts;
#[cfg(feature = "pjrt")]
mod exec;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use reference::{KernelMode, RefStages};

#[cfg(feature = "pjrt")]
pub use artifacts::{ArtifactRegistry, Runtime};
#[cfg(feature = "pjrt")]
pub use exec::{lit_i32, lit_tensor, tensor_from_lit, ExecOutputs};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtStages;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::util::tensor::{Tensor, TensorView};
use crate::weights::{ExpertKey, ExpertWeights};

/// Bytes of KV cache copied across a backend boundary by
/// [`materialize_kv`] since process start. The zero-copy contract: the
/// reference backend reads KV through [`KvSource`] in place and must
/// never bump this (asserted in `tests/zero_copy_decode.rs`); the PJRT
/// backend pays it once per `attn_decode` call, the one place the device
/// genuinely needs contiguous input.
static KV_COPY_BYTES: AtomicU64 = AtomicU64::new(0);

/// Monotonic [`KV_COPY_BYTES`] reading; diff two readings to measure a
/// region.
pub fn kv_copy_bytes() -> u64 {
    KV_COPY_BYTES.load(Ordering::Relaxed)
}

/// One layer's KV caches for a decode batch, borrowed in place.
///
/// `batch()` is the number of *real* sequences — it may be smaller than
/// the batch bucket `bb` the attention kernel pads to; lanes `>= batch()`
/// carry no cache and their `pos_mask` row must be all-invalid (the lane
/// then attends only to its own current token). `k(i)` / `v(i)` return
/// sequence `i`'s cache for the layer, shape `[max_seq, d_model]`,
/// row-major. Implementations must be cheap, allocation-free accessors;
/// `Sync` because the reference backend fans attention lanes out across
/// scoped threads.
pub trait KvSource: Sync {
    fn batch(&self) -> usize;
    fn k(&self, i: usize) -> &Tensor;
    fn v(&self, i: usize) -> &Tensor;
}

/// [`KvSource`] over explicit per-sequence tensor refs — tests, benches,
/// and anywhere the sequences themselves are out of reach.
pub struct KvSlices<'a> {
    pub k: &'a [&'a Tensor],
    pub v: &'a [&'a Tensor],
}

impl KvSource for KvSlices<'_> {
    fn batch(&self) -> usize {
        // Hard assert (not debug): a k/v length mismatch in a release
        // build would otherwise surface as a bare index panic deep in a
        // kernel loop instead of pointing at the malformed view.
        assert_eq!(self.k.len(), self.v.len(), "KvSlices k/v length mismatch");
        self.k.len()
    }

    fn k(&self, i: usize) -> &Tensor {
        self.k[i]
    }

    fn v(&self, i: usize) -> &Tensor {
        self.v[i]
    }
}

/// Copy a [`KvSource`] into contiguous `[bb, s, d]` K and V tensors,
/// zero-padding lanes `>= kv.batch()` — byte-for-byte the layout the seed
/// engine assembled every layer. This is the only sanctioned KV copy
/// (PJRT trait boundary; counted in [`kv_copy_bytes`]).
pub fn materialize_kv(
    kv: &dyn KvSource,
    bb: usize,
    s: usize,
    d: usize,
) -> Result<(Tensor, Tensor)> {
    let n = kv.batch();
    anyhow::ensure!(n <= bb, "materialize_kv: batch {n} exceeds bucket {bb}");
    let mut kc = vec![0.0f32; bb * s * d];
    let mut vc = vec![0.0f32; bb * s * d];
    for i in 0..n {
        let (kt, vt) = (kv.k(i), kv.v(i));
        anyhow::ensure!(
            kt.dims == [s, d] && vt.dims == [s, d],
            "materialize_kv: seq {i} cache shape {:?}/{:?}, want [{s}, {d}]",
            kt.dims,
            vt.dims
        );
        kc[i * s * d..(i + 1) * s * d].copy_from_slice(&kt.data);
        vc[i * s * d..(i + 1) * s * d].copy_from_slice(&vt.data);
    }
    KV_COPY_BYTES.fetch_add((2 * bb * s * d * 4) as u64, Ordering::Relaxed);
    Ok((Tensor::new(vec![bb, s, d], kc)?, Tensor::new(vec![bb, s, d], vc)?))
}

/// Which stage backend the engine should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts are present; reference otherwise.
    #[default]
    Auto,
    /// The pure-Rust interpreter (no artifacts needed).
    Reference,
    /// The PJRT artifact executor (requires the `pjrt` feature).
    Pjrt,
}

/// One model-stage executor. All tensors are host-side row-major f32; a
/// backend is free to stage them onto a device internally. `tb`/`bb` are
/// the token/batch shape buckets the AOT artifacts were compiled for — the
/// reference backend accepts any shape and ignores them beyond the padded
/// tensor sizes it receives.
///
/// `Send + Sync` because the engine fans independent expert groups out
/// across scoped threads, sharing `&dyn StageRunner` (the `&self` stage
/// methods must be safe to call concurrently).
pub trait StageRunner: Send + Sync {
    /// tokens (padded to `tb`) -> x [tb, D].
    fn embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor>;

    /// Full-prompt causal attention with residual:
    /// (x [S, D], len_mask [S]) -> [y [S, D], k [S, D], v [S, D]].
    fn attn_prefill(&self, layer: usize, x: &Tensor, len_mask: &Tensor) -> Result<[Tensor; 3]>;

    /// Single-step attention for a decode batch of up to `bb` lanes
    /// against per-sequence KV caches read **in place** through `kv`:
    /// -> [y [bb, D], k_new [bb, D], v_new [bb, D]].
    ///
    /// View contract (PR 5): the caller lends each sequence's `[s, D]`
    /// cache via [`KvSource`]; the reference backend must not copy it
    /// (its attention lanes index the borrowed rows directly), while a
    /// device backend that needs contiguous input materializes the view
    /// once via [`materialize_kv`] — the only sanctioned copy, counted in
    /// [`kv_copy_bytes`]. `pos_mask` is `[bb, s]`; lanes `>= kv.batch()`
    /// must carry an all-invalid mask row.
    fn attn_decode(
        &self,
        layer: usize,
        bb: usize,
        x: &Tensor,
        kv: &dyn KvSource,
        pos_mask: &Tensor,
    ) -> Result<[Tensor; 3]>;

    /// MoE pre-norm + router softmax: y [T, D] -> (h [T, D], probs [T, E]).
    fn router(&self, layer: usize, y: &Tensor) -> Result<(Tensor, Tensor)>;

    /// Run one *admitted* expert over a routed token group h [tb, D]. The
    /// input is a borrowed view so callers can stage token groups in
    /// pooled scratch instead of allocating a tensor per group.
    fn expert_resident(&self, tb: usize, key: ExpertKey, h: &TensorView) -> Result<Tensor>;

    /// Run an expert from explicitly-provided weights (the transient-fetch
    /// path: weights streamed through without cache admission).
    fn expert_transient(&self, tb: usize, w: &ExpertWeights, h: &TensorView) -> Result<Tensor>;

    /// x [tb, D] -> logits [tb, V] (tied embedding).
    fn lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor>;

    /// Admit an expert's weights "onto the device" (the arrival side of a
    /// PCIe transfer). `expert_resident` may only be called for admitted
    /// keys.
    fn admit_expert(&mut self, key: ExpertKey, w: &ExpertWeights) -> Result<()>;

    /// Drop an evicted expert's device-side weights.
    fn evict_expert(&mut self, key: ExpertKey);

    /// Whether the engine may call the `&self` stage methods from several
    /// scoped worker threads at once (the expert-group fan-out). Defaults
    /// to false; backends whose stage math is genuinely re-entrant (the
    /// reference interpreter) opt in. The PJRT backend must stay false —
    /// its device handles are thread-confined.
    fn supports_parallel(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}
