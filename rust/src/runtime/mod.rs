//! Stage-execution backends.
//!
//! The serving engine orchestrates the model as a sequence of *stages*
//! (embed, attention, router, expert FFN, lm head). This module defines
//! the [`StageRunner`] contract the engine drives, with two backends:
//!
//! * **Reference** ([`RefStages`], always available) — a pure-Rust
//!   interpreter of the stage math, numerically mirroring
//!   `python/compile/kernels/ref.py` / `model.py`. It needs no artifacts
//!   and no PJRT, so the full serving pipeline (cache, transfers, buddy
//!   substitution, continuous batching) runs anywhere — this is what the
//!   integration tests exercise against synthetic weights.
//! * **PJRT** (`PjrtStages`, behind the `pjrt` cargo feature) — loads AOT
//!   HLO-text artifacts, compiles them on the CPU PJRT client (`xla`
//!   crate), and executes them from the hot path. Interchange is HLO
//!   **text** (not serialized protos): jax >= 0.5 emits 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
//!   (see DESIGN.md).

pub mod kernels;
mod reference;

#[cfg(feature = "pjrt")]
mod artifacts;
#[cfg(feature = "pjrt")]
mod exec;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use reference::{KernelMode, RefStages};

#[cfg(feature = "pjrt")]
pub use artifacts::{ArtifactRegistry, Runtime};
#[cfg(feature = "pjrt")]
pub use exec::{lit_i32, lit_tensor, tensor_from_lit, ExecOutputs};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtStages;

use anyhow::Result;

use crate::util::tensor::Tensor;
use crate::weights::{ExpertKey, ExpertWeights};

/// Which stage backend the engine should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts are present; reference otherwise.
    #[default]
    Auto,
    /// The pure-Rust interpreter (no artifacts needed).
    Reference,
    /// The PJRT artifact executor (requires the `pjrt` feature).
    Pjrt,
}

/// One model-stage executor. All tensors are host-side row-major f32; a
/// backend is free to stage them onto a device internally. `tb`/`bb` are
/// the token/batch shape buckets the AOT artifacts were compiled for — the
/// reference backend accepts any shape and ignores them beyond the padded
/// tensor sizes it receives.
///
/// `Send + Sync` because the engine fans independent expert groups out
/// across scoped threads, sharing `&dyn StageRunner` (the `&self` stage
/// methods must be safe to call concurrently).
pub trait StageRunner: Send + Sync {
    /// tokens (padded to `tb`) -> x [tb, D].
    fn embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor>;

    /// Full-prompt causal attention with residual:
    /// (x [S, D], len_mask [S]) -> [y [S, D], k [S, D], v [S, D]].
    fn attn_prefill(&self, layer: usize, x: &Tensor, len_mask: &Tensor) -> Result<[Tensor; 3]>;

    /// Single-step attention for `bb` sequences against padded KV caches:
    /// -> [y [bb, D], k_new [bb, D], v_new [bb, D]].
    fn attn_decode(
        &self,
        layer: usize,
        bb: usize,
        x: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        pos_mask: &Tensor,
    ) -> Result<[Tensor; 3]>;

    /// MoE pre-norm + router softmax: y [T, D] -> (h [T, D], probs [T, E]).
    fn router(&self, layer: usize, y: &Tensor) -> Result<(Tensor, Tensor)>;

    /// Run one *admitted* expert over a routed token group h [tb, D].
    fn expert_resident(&self, tb: usize, key: ExpertKey, h: &Tensor) -> Result<Tensor>;

    /// Run an expert from explicitly-provided weights (the transient-fetch
    /// path: weights streamed through without cache admission).
    fn expert_transient(&self, tb: usize, w: &ExpertWeights, h: &Tensor) -> Result<Tensor>;

    /// x [tb, D] -> logits [tb, V] (tied embedding).
    fn lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor>;

    /// Admit an expert's weights "onto the device" (the arrival side of a
    /// PCIe transfer). `expert_resident` may only be called for admitted
    /// keys.
    fn admit_expert(&mut self, key: ExpertKey, w: &ExpertWeights) -> Result<()>;

    /// Drop an evicted expert's device-side weights.
    fn evict_expert(&mut self, key: ExpertKey);

    /// Whether the engine may call the `&self` stage methods from several
    /// scoped worker threads at once (the expert-group fan-out). Defaults
    /// to false; backends whose stage math is genuinely re-entrant (the
    /// reference interpreter) opt in. The PJRT backend must stay false —
    /// its device handles are thread-confined.
    fn supports_parallel(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}
