//! The PJRT client wrapper and the compiled-artifact registry.
//!
//! Artifacts are compiled once at startup (stage x shape-bucket) and looked
//! up by name on the hot path. The registry also owns device-resident
//! expert weight buffers — creating one of those buffers is the "GPU side"
//! of a PCIe transfer.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactInfo, ModelConfig};
use crate::runtime::exec::ExecOutputs;
use crate::util::tensor::Tensor;
use crate::weights::{ExpertKey, ExpertWeights};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Compile every artifact in the manifest.
    pub fn load_artifacts(&self, cfg: &ModelConfig) -> Result<ArtifactRegistry> {
        let mut exes = BTreeMap::new();
        for (name, info) in &cfg.artifacts {
            let path = cfg.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            exes.insert(name.clone(), (exe, info.clone()));
        }
        log::info!("compiled {} artifacts", exes.len());
        Ok(ArtifactRegistry { exes, expert_buffers: BTreeMap::new() })
    }

    /// Host f32 slice -> device buffer.
    pub fn to_device(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("host->device transfer")
    }

    /// Host i32 slice -> device buffer (token ids).
    pub fn to_device_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("host->device transfer (i32)")
    }
}

/// Compiled executables plus device-resident expert weights.
pub struct ArtifactRegistry {
    exes: BTreeMap<String, (xla::PjRtLoadedExecutable, ArtifactInfo)>,
    /// Device buffers for GPU-resident experts: the engine-side mirror of
    /// `memory::ExpertCache` residency.
    expert_buffers: BTreeMap<ExpertKey, [xla::PjRtBuffer; 3]>,
}

impl ArtifactRegistry {
    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn info(&self, name: &str) -> Result<&ArtifactInfo> {
        Ok(&self.exe(name)?.1)
    }

    fn exe(&self, name: &str) -> Result<&(xla::PjRtLoadedExecutable, ArtifactInfo)> {
        self.exes
            .get(name)
            .with_context(|| format!("artifact {name} not compiled"))
    }

    /// Execute a stage with host-tensor arguments (literal path).
    pub fn run(&self, name: &str, args: &[&Tensor]) -> Result<ExecOutputs> {
        let (exe, info) = self.exe(name)?;
        if args.len() != info.num_args {
            bail!("{name}: expected {} args, got {}", info.num_args, args.len());
        }
        let lits = args
            .iter()
            .map(|t| super::exec::lit_tensor(t))
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        ExecOutputs::from_result(result, info.tuple_output)
    }

    /// Execute a stage with pre-built literals (mix of fresh activations
    /// and cached weight literals; embed takes i32 tokens).
    pub fn run_lits(&self, name: &str, lits: &[&xla::Literal]) -> Result<ExecOutputs> {
        let (exe, info) = self.exe(name)?;
        if lits.len() != info.num_args {
            bail!("{name}: expected {} args, got {}", info.num_args, lits.len());
        }
        let result = exe.execute::<&xla::Literal>(lits)?;
        ExecOutputs::from_result(result, info.tuple_output)
    }

    /// Execute a stage with device buffers (the expert hot path: cached
    /// expert weights stay on device across calls).
    pub fn run_buffers(&self, name: &str, bufs: &[&xla::PjRtBuffer]) -> Result<ExecOutputs> {
        let (exe, info) = self.exe(name)?;
        if bufs.len() != info.num_args {
            bail!("{name}: expected {} args, got {}", info.num_args, bufs.len());
        }
        let result = exe.execute_b::<&xla::PjRtBuffer>(bufs)?;
        ExecOutputs::from_result(result, info.tuple_output)
    }

    // --- device expert-buffer mirror ------------------------------------

    /// Admit an expert's weights to the device (the arrival side of a PCIe
    /// transfer).
    pub fn admit_expert(&mut self, rt: &Runtime, key: ExpertKey, w: &ExpertWeights) -> Result<()> {
        let b1 = rt.to_device(&w.0.data, &w.0.dims)?;
        let b3 = rt.to_device(&w.1.data, &w.1.dims)?;
        let b2 = rt.to_device(&w.2.data, &w.2.dims)?;
        self.expert_buffers.insert(key, [b1, b3, b2]);
        Ok(())
    }

    pub fn evict_expert(&mut self, key: ExpertKey) {
        self.expert_buffers.remove(&key);
    }

    pub fn expert_resident(&self, key: ExpertKey) -> bool {
        self.expert_buffers.contains_key(&key)
    }

    pub fn expert_buffers(&self, key: ExpertKey) -> Result<&[xla::PjRtBuffer; 3]> {
        self.expert_buffers
            .get(&key)
            .with_context(|| format!("expert L{}.E{} has no device buffers", key.layer, key.expert))
    }

    pub fn resident_expert_count(&self) -> usize {
        self.expert_buffers.len()
    }
}
