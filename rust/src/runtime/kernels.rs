//! Reference-backend compute kernels, in two bitwise-identical forms:
//!
//! * [`naive`] — the original triple-loop kernels. They are the numeric
//!   contract (mirroring `python/compile/kernels/ref.py`) and the
//!   benchmark baseline (`PALLAS_NAIVE=1` selects them end-to-end).
//! * the module-level `*_into` kernels — cache-blocked over the i/j
//!   (row/column) loops, multi-threaded over disjoint output rows or
//!   column panels via [`crate::util::par`], and writing into
//!   caller-provided buffers so the hot path reuses scratch memory
//!   instead of allocating per call.
//!
//! The invariant every optimized kernel preserves: **the floating-point
//! summation order of each output element never changes**. Tiling splits
//! only the i and j loops; the k reduction always runs `0..k` ascending
//! in a single accumulator (including the `a == 0.0` skip), and parallel
//! workers own disjoint outputs. Consequently blocked output is
//! bit-for-bit equal to naive output at every thread count — property
//! tested in `tests/kernel_equivalence.rs`, and the reason the golden
//! virtual-clock sweeps stay byte-identical under `PALLAS_THREADS=4`.

use crate::util::par;

/// Column-tile width for blocked matmuls: 128 f32 = 512 B of accumulator
/// per row tile, L1-resident alongside the streamed weight rows.
const TILE_J: usize = 128;

/// Row-group height: each pass over a weight row updates up to this many
/// output rows, dividing b-matrix memory traffic by the same factor
/// (weight matrices are the operands that overflow L1).
const TILE_I: usize = 4;

/// The original allocating kernels — numeric contract and bench baseline.
pub mod naive {
    /// Row-major matmul: a [m, k] @ b [k, n] -> [m, n].
    pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let or = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let br = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// Matmul against a transposed second operand: a [m, k] @ bt^T where
    /// bt is [n, k] row-major — i.e. out[i][j] = dot(a_row_i, bt_row_j).
    /// The tied-embedding lm_head layout.
    pub fn matmul_bt(a: &[f32], m: usize, k: usize, bt: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(bt.len(), n * k);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let or = &mut out[i * n..(i + 1) * n];
            for (j, o) in or.iter_mut().enumerate() {
                let br = &bt[j * k..(j + 1) * k];
                let mut dot = 0.0f32;
                for jj in 0..k {
                    dot += ar[jj] * br[jj];
                }
                *o = dot;
            }
        }
        out
    }

    /// RMSNorm each row of x [rows, d]: x * rsqrt(mean(x^2) + eps) * gain.
    pub fn rms_norm_rows(x: &[f32], rows: usize, d: usize, gain: &[f32], eps: f32) -> Vec<f32> {
        debug_assert_eq!(x.len(), rows * d);
        debug_assert_eq!(gain.len(), d);
        let mut out = vec![0.0f32; rows * d];
        for r in 0..rows {
            let xr = &x[r * d..(r + 1) * d];
            let ms: f32 = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            let or = &mut out[r * d..(r + 1) * d];
            for i in 0..d {
                or[i] = xr[i] * inv * gain[i];
            }
        }
        out
    }
}

/// Blocked, parallel matmul into `out` (must be m*n long; fully
/// overwritten): a [m, k] @ b [k, n] -> out [m, n]. Bitwise identical to
/// [`naive::matmul`].
pub fn matmul_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    par::par_rows(out, m, k.saturating_mul(n), |row0, rows| {
        // i/j tiling only: for every output element the k reduction still
        // runs 0..k ascending in one accumulator (with the same zero-skip),
        // so each element's summation order matches the naive kernel
        // exactly. The j-tile keeps the accumulator rows L1-hot; the
        // i-group reuses each streamed b row for up to TILE_I output rows.
        let nrows = rows.len() / n;
        let mut ri0 = 0;
        while ri0 < nrows {
            let ri1 = (ri0 + TILE_I).min(nrows);
            rows[ri0 * n..ri1 * n].fill(0.0);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + TILE_J).min(n);
                for kk in 0..k {
                    let br = &b[kk * n + j0..kk * n + j1];
                    for ri in ri0..ri1 {
                        let av = a[(row0 + ri) * k + kk];
                        if av == 0.0 {
                            continue;
                        }
                        let ot = &mut rows[ri * n + j0..ri * n + j1];
                        for (o, &bv) in ot.iter_mut().zip(br) {
                            *o += av * bv;
                        }
                    }
                }
                j0 = j1;
            }
            ri0 = ri1;
        }
    });
}

/// Allocating wrapper over [`matmul_into`].
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, m, k, b, n, &mut out);
    out
}

/// Blocked, parallel transposed matmul into `out` [m, n]: out[i][j] =
/// dot(a row i, bt row j) with bt [n, k] row-major. Workers own disjoint
/// column panels; the dot runs `0..k` ascending in one accumulator, so
/// output is bitwise identical to [`naive::matmul_bt`].
pub fn matmul_bt_into(a: &[f32], m: usize, k: usize, bt: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    // j outer / i inner: each bt row is streamed once and dotted against
    // all m activation rows (which stay L1-resident). The dot itself runs
    // 0..k ascending in one accumulator — naive order, bit-identical.
    let dot_panel = |j0: usize, j1: usize, panel: &mut [f32]| {
        let bw = j1 - j0;
        for (pj, j) in (j0..j1).enumerate() {
            let br = &bt[j * k..(j + 1) * k];
            for i in 0..m {
                let ar = &a[i * k..(i + 1) * k];
                let mut dot = 0.0f32;
                for jj in 0..k {
                    dot += ar[jj] * br[jj];
                }
                panel[i * bw + pj] = dot;
            }
        }
    };
    let threads = par::plan_threads(n, m.saturating_mul(k));
    if threads <= 1 {
        dot_panel(0, n, out);
        return;
    }
    // Fan out over contiguous column panels; each worker returns its
    // [m, panel] block, scattered back into the row-major output (the
    // scatter is O(m*n) copies against O(m*n*k) math).
    let block = n.div_ceil(threads);
    let panels = par::par_map(threads, block.saturating_mul(m).saturating_mul(k), |ci| {
        let j0 = (ci * block).min(n);
        let j1 = ((ci + 1) * block).min(n);
        let mut panel = vec![0.0f32; m * (j1 - j0)];
        dot_panel(j0, j1, &mut panel);
        panel
    });
    for (ci, panel) in panels.iter().enumerate() {
        let j0 = (ci * block).min(n);
        let bw = panel.len() / m;
        for i in 0..m {
            out[i * n + j0..i * n + j0 + bw].copy_from_slice(&panel[i * bw..(i + 1) * bw]);
        }
    }
}

/// Allocating wrapper over [`matmul_bt_into`].
pub fn matmul_bt(a: &[f32], m: usize, k: usize, bt: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_bt_into(a, m, k, bt, n, &mut out);
    out
}

/// Parallel per-row RMSNorm into `out` (rows*d long; fully overwritten).
/// Bitwise identical to [`naive::rms_norm_rows`].
pub fn rms_norm_rows_into(
    x: &[f32],
    rows: usize,
    d: usize,
    gain: &[f32],
    eps: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(gain.len(), d);
    debug_assert_eq!(out.len(), rows * d);
    if rows == 0 || d == 0 {
        return;
    }
    par::par_rows(out, rows, 2 * d, |row0, chunk| {
        for (ri, or) in chunk.chunks_mut(d).enumerate() {
            let r = row0 + ri;
            let xr = &x[r * d..(r + 1) * d];
            let ms: f32 = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            for i in 0..d {
                or[i] = xr[i] * inv * gain[i];
            }
        }
    });
}

/// Allocating wrapper over [`rms_norm_rows_into`].
pub fn rms_norm_rows(x: &[f32], rows: usize, d: usize, gain: &[f32], eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    rms_norm_rows_into(x, rows, d, gain, eps, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [2,2] @ [2,2]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        assert_eq!(naive::matmul(&a, 2, 2, &b, 2), vec![19.0, 22.0, 43.0, 50.0]);
        assert_eq!(matmul(&a, 2, 2, &b, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_bt_is_transposed_matmul() {
        // a [1,3] @ b [3,2] where bt is b transposed to [2,3].
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 4.0, 2.0, 5.0, 3.0, 6.0]; // [3,2]
        let bt = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2,3]
        let want = naive::matmul(&a, 1, 3, &b, 2);
        assert_eq!(naive::matmul_bt(&a, 1, 3, &bt, 2), want);
        assert_eq!(matmul_bt(&a, 1, 3, &bt, 2), want);
    }

    #[test]
    fn blocked_matmul_crosses_tile_boundary() {
        // n > TILE_J so at least two column tiles run.
        let (m, k, n) = (3, 7, TILE_J + 13);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 13) as f32 / 13.0 - 0.5).collect();
        assert_eq!(matmul(&a, m, k, &b, n), naive::matmul(&a, m, k, &b, n));
    }

    #[test]
    fn rms_norm_unit_gain_scale() {
        let x = [3.0f32, 4.0];
        for out in [
            naive::rms_norm_rows(&x, 1, 2, &[1.0, 1.0], 0.0),
            rms_norm_rows(&x, 1, 2, &[1.0, 1.0], 0.0),
        ] {
            // rms = sqrt((9+16)/2) = sqrt(12.5)
            let rms = 12.5f32.sqrt();
            assert!((out[0] - 3.0 / rms).abs() < 1e-6);
            assert!((out[1] - 4.0 / rms).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_skip_matches() {
        // Rows containing exact zeros take the skip path in both forms.
        let a = [0.0f32, 2.0, 0.0, 0.0, 1.0, 0.0];
        let b: Vec<f32> = (0..3 * 4).map(|i| i as f32).collect();
        assert_eq!(matmul(&a, 2, 3, &b, 4), naive::matmul(&a, 2, 3, &b, 4));
    }
}
