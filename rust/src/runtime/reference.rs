//! The reference stage backend: a pure-Rust interpreter of the dsv2-mini
//! stage math, numerically mirroring `python/compile/kernels/ref.py` and
//! `python/compile/model.py`.
//!
//! This backend needs no AOT artifacts and no PJRT, so the complete
//! serving pipeline — expert cache, PCIe transfer simulation, buddy
//! substitution, continuous batching — runs end-to-end against a
//! synthetic [`WeightStore`]. The integration tests and the virtual-clock
//! table sweeps use it; with real artifacts present (and the `pjrt`
//! feature) the engine picks the PJRT backend instead.
//!
//! "Device residency" here is an accounting map of admitted expert
//! weights: running a non-admitted expert is a bug upstream (the cache /
//! transfer bookkeeping went wrong) and errors just like the PJRT
//! registry's missing-buffer lookup would.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::runtime::StageRunner;
use crate::util::math::softmax;
use crate::util::tensor::Tensor;
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

pub struct RefStages {
    cfg: ModelConfig,
    store: Arc<WeightStore>,
    resident: BTreeMap<ExpertKey, ExpertWeights>,
}

/// Row-major matmul: a [m, k] @ b [k, n] -> [m, n].
fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let br = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
    out
}

/// RMSNorm each row of x [rows, d]: x * rsqrt(mean(x^2) + eps) * gain.
fn rms_norm_rows(x: &[f32], rows: usize, d: usize, gain: &[f32], eps: f32) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(gain.len(), d);
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms: f32 = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for i in 0..d {
            or[i] = xr[i] * inv * gain[i];
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl RefStages {
    pub fn new(cfg: ModelConfig, store: Arc<WeightStore>) -> Self {
        debug_assert_eq!(cfg.d_model, cfg.n_heads * cfg.head_dim);
        Self { cfg, store, resident: BTreeMap::new() }
    }

    fn layer_tensor(&self, layer: usize, name: &str) -> Result<&Tensor> {
        self.store.tensor(&format!("L{layer}.{name}"))
    }

    /// Shared FFN math: (silu(h @ w1) * (h @ w3)) @ w2 over h [t, D].
    fn expert_ffn(&self, h: &Tensor, w: &ExpertWeights) -> Result<Tensor> {
        let (t, d) = (h.dims[0], self.cfg.d_model);
        let f = self.cfg.d_ff;
        let a = matmul(&h.data, t, d, &w.0.data, f);
        let b = matmul(&h.data, t, d, &w.1.data, f);
        let mut g = vec![0.0f32; t * f];
        for i in 0..t * f {
            g[i] = silu(a[i]) * b[i];
        }
        let out = matmul(&g, t, f, &w.2.data, d);
        Tensor::new(vec![t, d], out)
    }

    /// Multi-head attention core for one query row against a key/value
    /// window laid out as index closures; writes the context into `o_row`.
    #[allow(clippy::too_many_arguments)]
    fn attend(
        &self,
        q_row: &[f32],
        n_keys: usize,
        key_at: impl Fn(usize, usize) -> f32,   // (t, dim) -> k value
        value_at: impl Fn(usize, usize) -> f32, // (t, dim) -> v value
        valid: impl Fn(usize) -> bool,
        o_row: &mut [f32],
    ) {
        let (heads, hd) = (self.cfg.n_heads, self.cfg.head_dim);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0.0f32; n_keys];
        for head in 0..heads {
            let base = head * hd;
            for (t, s) in scores.iter_mut().enumerate() {
                if valid(t) {
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += q_row[base + j] * key_at(t, base + j);
                    }
                    *s = dot * scale;
                } else {
                    *s = f32::NEG_INFINITY;
                }
            }
            softmax(&mut scores);
            for j in 0..hd {
                let mut acc = 0.0f32;
                for (t, &w) in scores.iter().enumerate() {
                    if w > 0.0 {
                        acc += w * value_at(t, base + j);
                    }
                }
                o_row[base + j] = acc;
            }
        }
    }
}

impl StageRunner for RefStages {
    fn embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor> {
        anyhow::ensure!(toks.len() == tb, "embed: {} tokens for bucket {tb}", toks.len());
        let emb = self.store.tensor("embed")?;
        let d = self.cfg.d_model;
        let mut out = vec![0.0f32; tb * d];
        for (i, &t) in toks.iter().enumerate() {
            let t = t as usize;
            anyhow::ensure!(t < self.cfg.vocab_size, "token {t} out of vocab");
            out[i * d..(i + 1) * d].copy_from_slice(emb.row(t));
        }
        Tensor::new(vec![tb, d], out)
    }

    fn attn_prefill(&self, layer: usize, x: &Tensor, len_mask: &Tensor) -> Result<[Tensor; 3]> {
        let (s, d) = (x.dims[0], self.cfg.d_model);
        let ln1 = self.layer_tensor(layer, "ln1")?;
        let wq = self.layer_tensor(layer, "wq")?;
        let wk = self.layer_tensor(layer, "wk")?;
        let wv = self.layer_tensor(layer, "wv")?;
        let wo = self.layer_tensor(layer, "wo")?;

        let h = rms_norm_rows(&x.data, s, d, &ln1.data, self.cfg.rms_eps as f32);
        let q = matmul(&h, s, d, &wq.data, d);
        let k = matmul(&h, s, d, &wk.data, d);
        let v = matmul(&h, s, d, &wv.data, d);

        let mask = &len_mask.data;
        let mut o = vec![0.0f32; s * d];
        for si in 0..s {
            let mut o_row = vec![0.0f32; d];
            self.attend(
                &q[si * d..(si + 1) * d],
                s,
                |t, j| k[t * d + j],
                |t, j| v[t * d + j],
                |t| t <= si && mask[t] > 0.0,
                &mut o_row,
            );
            o[si * d..(si + 1) * d].copy_from_slice(&o_row);
        }
        // y = x + o @ wo
        let proj = matmul(&o, s, d, &wo.data, d);
        let mut y = x.data.clone();
        for (a, b) in y.iter_mut().zip(&proj) {
            *a += b;
        }
        Ok([
            Tensor::new(vec![s, d], y)?,
            Tensor::new(vec![s, d], k)?,
            Tensor::new(vec![s, d], v)?,
        ])
    }

    fn attn_decode(
        &self,
        layer: usize,
        bb: usize,
        x: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        pos_mask: &Tensor,
    ) -> Result<[Tensor; 3]> {
        let d = self.cfg.d_model;
        let s = k_cache.dims[1];
        anyhow::ensure!(x.dims == vec![bb, d], "attn_decode x shape {:?}", x.dims);
        let ln1 = self.layer_tensor(layer, "ln1")?;
        let wq = self.layer_tensor(layer, "wq")?;
        let wk = self.layer_tensor(layer, "wk")?;
        let wv = self.layer_tensor(layer, "wv")?;
        let wo = self.layer_tensor(layer, "wo")?;

        let h = rms_norm_rows(&x.data, bb, d, &ln1.data, self.cfg.rms_eps as f32);
        let q = matmul(&h, bb, d, &wq.data, d);
        let k_new = matmul(&h, bb, d, &wk.data, d);
        let v_new = matmul(&h, bb, d, &wv.data, d);

        let mut o = vec![0.0f32; bb * d];
        for b in 0..bb {
            let kc = &k_cache.data[b * s * d..(b + 1) * s * d];
            let vc = &v_cache.data[b * s * d..(b + 1) * s * d];
            let kn = &k_new[b * d..(b + 1) * d];
            let vn = &v_new[b * d..(b + 1) * d];
            let mask = &pos_mask.data[b * s..(b + 1) * s];
            let mut o_row = vec![0.0f32; d];
            // Window = S cached slots plus the current token appended at
            // index S (always valid), exactly like attn_decode_stage.
            self.attend(
                &q[b * d..(b + 1) * d],
                s + 1,
                |t, j| if t < s { kc[t * d + j] } else { kn[j] },
                |t, j| if t < s { vc[t * d + j] } else { vn[j] },
                |t| t >= s || mask[t] > 0.0,
                &mut o_row,
            );
            o[b * d..(b + 1) * d].copy_from_slice(&o_row);
        }
        let proj = matmul(&o, bb, d, &wo.data, d);
        let mut y = x.data.clone();
        for (a, b) in y.iter_mut().zip(&proj) {
            *a += b;
        }
        Ok([
            Tensor::new(vec![bb, d], y)?,
            Tensor::new(vec![bb, d], k_new)?,
            Tensor::new(vec![bb, d], v_new)?,
        ])
    }

    fn router(&self, layer: usize, y: &Tensor) -> Result<(Tensor, Tensor)> {
        let (t, d) = (y.dims[0], self.cfg.d_model);
        let e = self.cfg.n_experts;
        let ln2 = self.layer_tensor(layer, "ln2")?;
        let wg = self.layer_tensor(layer, "wg")?;
        let rbias = self.layer_tensor(layer, "rbias")?;
        let h = rms_norm_rows(&y.data, t, d, &ln2.data, self.cfg.rms_eps as f32);
        let mut logits = matmul(&h, t, d, &wg.data, e);
        for r in 0..t {
            let row = &mut logits[r * e..(r + 1) * e];
            for (l, &b) in row.iter_mut().zip(&rbias.data) {
                *l += b;
            }
            softmax(row);
        }
        Ok((Tensor::new(vec![t, d], h)?, Tensor::new(vec![t, e], logits)?))
    }

    fn expert_resident(&self, _tb: usize, key: ExpertKey, h: &Tensor) -> Result<Tensor> {
        let w = self
            .resident
            .get(&key)
            .with_context(|| {
                format!("expert L{}.E{} has no device buffers", key.layer, key.expert)
            })?
            .clone();
        self.expert_ffn(h, &w)
    }

    fn expert_transient(&self, _tb: usize, w: &ExpertWeights, h: &Tensor) -> Result<Tensor> {
        self.expert_ffn(h, w)
    }

    fn lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor> {
        let d = self.cfg.d_model;
        anyhow::ensure!(x.dims == vec![tb, d], "lm_head x shape {:?}", x.dims);
        let gain = self.store.tensor("final_gain")?;
        let emb = self.store.tensor("embed")?;
        let v = self.cfg.vocab_size;
        let h = rms_norm_rows(&x.data, tb, d, &gain.data, self.cfg.rms_eps as f32);
        let mut logits = vec![0.0f32; tb * v];
        for t in 0..tb {
            let hr = &h[t * d..(t + 1) * d];
            let lr = &mut logits[t * v..(t + 1) * v];
            for (vi, l) in lr.iter_mut().enumerate() {
                let er = emb.row(vi);
                let mut dot = 0.0f32;
                for j in 0..d {
                    dot += hr[j] * er[j];
                }
                *l = dot;
            }
        }
        Tensor::new(vec![tb, v], logits)
    }

    fn admit_expert(&mut self, key: ExpertKey, w: &ExpertWeights) -> Result<()> {
        if key.layer >= self.cfg.n_layers || key.expert >= self.cfg.n_experts {
            bail!("admit_expert: key L{}.E{} out of range", key.layer, key.expert);
        }
        self.resident.insert(key, w.clone());
        Ok(())
    }

    fn evict_expert(&mut self, key: ExpertKey) {
        self.resident.remove(&key);
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stages() -> RefStages {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 7));
        RefStages::new(cfg, store)
    }

    #[test]
    fn matmul_small() {
        // [2,2] @ [2,2]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let c = matmul(&a, 2, 2, &b, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn rms_norm_unit_gain_scale() {
        let x = [3.0f32, 4.0];
        let out = rms_norm_rows(&x, 1, 2, &[1.0, 1.0], 0.0);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn router_probs_are_distributions() {
        let s = stages();
        let t = 3;
        let y = Tensor::new(
            vec![t, 16],
            (0..t * 16).map(|i| (i % 7) as f32 / 7.0 - 0.5).collect(),
        )
        .unwrap();
        let (h, probs) = s.router(0, &y).unwrap();
        assert_eq!(h.dims, vec![t, 16]);
        assert_eq!(probs.dims, vec![t, 8]);
        for r in 0..t {
            let sum: f32 = probs.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(probs.row(r).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn router_matches_host_router_probs() {
        // The PreGate predictor's host router math is an independent
        // implementation of the same stage; they must agree.
        let s = stages();
        let y = Tensor::new(vec![1, 16], (0..16).map(|i| i as f32 / 9.0 - 0.8).collect()).unwrap();
        let (_, probs) = s.router(1, &y).unwrap();
        let expect = crate::prefetch::host_router_probs(
            y.row(0),
            16,
            &s.store.tensor("L1.ln2").unwrap().data,
            s.store.tensor("L1.wg").unwrap(),
            &s.store.tensor("L1.rbias").unwrap().data,
            s.cfg.rms_eps as f32,
        );
        for (a, b) in probs.row(0).iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "router mismatch: {a} vs {b}");
        }
    }

    #[test]
    fn expert_requires_admission() {
        let mut s = stages();
        let key = ExpertKey::new(0, 3);
        let h = Tensor::zeros(vec![2, 16]);
        assert!(s.expert_resident(2, key, &h).is_err());
        let w = s.store.expert(key).unwrap();
        s.admit_expert(key, &w).unwrap();
        let y = s.expert_resident(2, key, &h).unwrap();
        assert_eq!(y.dims, vec![2, 16]);
        s.evict_expert(key);
        assert!(s.expert_resident(2, key, &h).is_err());
    }

    #[test]
    fn expert_zero_input_zero_output() {
        let s = stages();
        let w = s.store.expert(ExpertKey::new(1, 1)).unwrap();
        let h = Tensor::zeros(vec![1, 16]);
        let y = s.expert_transient(1, &w, &h).unwrap();
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn attn_decode_shapes_and_mask() {
        let s = stages();
        let (bb, d, sq) = (2, 16, 16);
        let x = Tensor::new(vec![bb, d], (0..bb * d).map(|i| (i % 5) as f32 - 2.0).collect())
            .unwrap();
        let kc = Tensor::zeros(vec![bb, sq, d]);
        let vc = Tensor::zeros(vec![bb, sq, d]);
        // No cached positions valid: attention sees only the current token.
        let pm = Tensor::zeros(vec![bb, sq]);
        let [y, kn, vn] = s.attn_decode(0, bb, &x, &kc, &vc, &pm).unwrap();
        assert_eq!(y.dims, vec![bb, d]);
        assert_eq!(kn.dims, vec![bb, d]);
        assert_eq!(vn.dims, vec![bb, d]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prefill_is_causal() {
        // Changing a later token must not change an earlier row's output.
        let s = stages();
        let d = 16;
        let sq = 8;
        let mk = |last: f32| {
            let mut x = Tensor::zeros(vec![sq, d]);
            for t in 0..sq {
                for j in 0..d {
                    x.row_mut(t)[j] = ((t * d + j) % 11) as f32 / 11.0 - 0.5;
                }
            }
            x.row_mut(sq - 1)[0] = last;
            x
        };
        let mask = Tensor::new(vec![sq], vec![1.0; sq]).unwrap();
        let [y_a, _, _] = s.attn_prefill(0, &mk(0.3), &mask).unwrap();
        let [y_b, _, _] = s.attn_prefill(0, &mk(9.0), &mask).unwrap();
        for t in 0..sq - 1 {
            assert_eq!(y_a.row(t), y_b.row(t), "row {t} must not see the future");
        }
        assert_ne!(y_a.row(sq - 1), y_b.row(sq - 1));
    }

    #[test]
    fn lm_head_is_tied_embedding() {
        let s = stages();
        // With unit final_gain, logits of a row equal rms-normed dot with
        // each embedding row; check against a direct computation.
        let x = Tensor::new(vec![1, 16], (0..16).map(|i| i as f32 / 16.0).collect()).unwrap();
        let logits = s.lm_head(1, &x).unwrap();
        assert_eq!(logits.dims, vec![1, 64]);
        let emb = s.store.tensor("embed").unwrap();
        let h = rms_norm_rows(&x.data, 1, 16, &[1.0; 16], s.cfg.rms_eps as f32);
        let mut dot0 = 0.0f32;
        for j in 0..16 {
            dot0 += h[j] * emb.row(0)[j];
        }
        assert!((logits.row(0)[0] - dot0).abs() < 1e-5);
    }
}
