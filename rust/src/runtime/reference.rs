//! The reference stage backend: a pure-Rust interpreter of the dsv2-mini
//! stage math, numerically mirroring `python/compile/kernels/ref.py` and
//! `python/compile/model.py`.
//!
//! This backend needs no AOT artifacts and no PJRT, so the complete
//! serving pipeline — expert cache, PCIe transfer simulation, buddy
//! substitution, continuous batching — runs end-to-end against a
//! synthetic [`WeightStore`]. The integration tests and the virtual-clock
//! table sweeps use it; with real artifacts present (and the `pjrt`
//! feature) the engine picks the PJRT backend instead.
//!
//! "Device residency" here is an accounting map of admitted expert
//! weights: running a non-admitted expert is a bug upstream (the cache /
//! transfer bookkeeping went wrong) and errors just like the PJRT
//! registry's missing-buffer lookup would.
//!
//! # Performance notes
//!
//! The hot path is allocation-light, zero-copy, and multi-core:
//!
//! * **Zero-copy residency** — [`ExpertWeights`] is `Arc`-shared, so
//!   admission stores a pointer bump and [`RefStages::expert_resident`]
//!   borrows the resident entry directly; no tensor bytes are copied
//!   anywhere on the admit/evict/lookup path (`Arc::ptr_eq`-tested).
//! * **Zero-copy KV views** — decode attention reads each sequence's
//!   `[max_seq, d_model]` cache **in place** through the borrowed
//!   [`KvSource`] view; the seed's per-layer `[bb, s, d]` assembly copy
//!   (2 × bb × s × d f32 per layer per token) is gone. Only this
//!   backend may borrow KV like that — the engine guarantees the caches
//!   are not mutated for the duration of the call (the step's new row is
//!   returned as `k_new`/`v_new` and written back *after* attention) —
//!   while the PJRT backend materializes the view once at the trait
//!   boundary because its AOT artifacts want contiguous device input.
//!   Either way the per-lane reduction order is untouched, so the
//!   bitwise guarantee below is unaffected (golden-tested against an
//!   independent copy-path reimplementation in
//!   `tests/zero_copy_decode.rs`).
//! * **Blocked kernels** — matmul / RMSNorm / the attention core /
//!   lm_head run through [`super::kernels`]: i/j cache tiling, a
//!   transposed-weight dot kernel for the tied-embedding lm head, and
//!   slice-based attention lanes. The k reduction order per output
//!   element is never changed, so results are bit-for-bit identical to
//!   the naive forms (property-tested), keeping the golden sweeps
//!   byte-identical.
//! * **Scratch arena** — per-stage temporaries (normed activations, q
//!   projections, attention outputs, FFN intermediates) come from a
//!   mutex-pooled arena on this struct instead of fresh `Vec`s per call;
//!   only tensors returned to the engine are freshly allocated.
//! * **Threading** — independent work units (attention lanes, output
//!   rows, lm-head vocab panels) fan out over `std::thread::scope` via
//!   [`crate::util::par`], sized by the `PALLAS_THREADS` env var and
//!   gated on a minimum work threshold so tiny test models stay inline.
//!   Because parallel units own disjoint outputs and per-unit math is
//!   unchanged, any thread count produces byte-identical results.
//!
//! Setting `PALLAS_NAIVE=1` (or constructing via
//! [`RefStages::with_mode`]) selects the original naive kernels — the
//! numeric contract and the `micro_hotpath` benchmark baseline.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::runtime::kernels::{self, naive};
use crate::runtime::{KvSource, StageRunner};
use crate::util::arena::Arena;
use crate::util::math::softmax;
use crate::util::par;
use crate::util::tensor::{Tensor, TensorView};
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

/// Which kernel implementations a [`RefStages`] instance executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// The original triple-loop kernels, allocating per call: the numeric
    /// contract and benchmark baseline (`PALLAS_NAIVE=1`).
    Naive,
    /// Cache-blocked, arena-backed, multi-threaded kernels with bitwise
    /// identical outputs (the default).
    Blocked,
}

impl KernelMode {
    fn from_env() -> Self {
        match std::env::var("PALLAS_NAIVE") {
            Ok(v) if !v.is_empty() && v != "0" => KernelMode::Naive,
            _ => KernelMode::Blocked,
        }
    }
}

pub struct RefStages {
    cfg: ModelConfig,
    store: Arc<WeightStore>,
    resident: BTreeMap<ExpertKey, ExpertWeights>,
    mode: KernelMode,
    arena: Arena,
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Lane `b`'s borrowed K/V cache rows. Lanes `>= n_real` (bucket padding)
/// have no cache: their `pos_mask` rows are all-invalid, so the empty
/// slice is never indexed and the lane attends only to its own current
/// token — numerically identical to the seed's zero-padded assembly.
fn lane_kv<'k>(kv: &'k dyn KvSource, n_real: usize, b: usize) -> (&'k [f32], &'k [f32]) {
    if b < n_real {
        (kv.k(b).data.as_slice(), kv.v(b).data.as_slice())
    } else {
        (&[], &[])
    }
}

impl RefStages {
    /// Kernel mode from the `PALLAS_NAIVE` env var (default: blocked).
    pub fn new(cfg: ModelConfig, store: Arc<WeightStore>) -> Self {
        Self::with_mode(cfg, store, KernelMode::from_env())
    }

    pub fn with_mode(cfg: ModelConfig, store: Arc<WeightStore>, mode: KernelMode) -> Self {
        debug_assert_eq!(cfg.d_model, cfg.n_heads * cfg.head_dim);
        Self { cfg, store, resident: BTreeMap::new(), mode, arena: Arena::new() }
    }

    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    /// The Arc-shared weights admitted for `key`, if resident (zero-copy
    /// contract inspection: `Arc::ptr_eq` against the store's handle).
    pub fn resident_weights(&self, key: ExpertKey) -> Option<&ExpertWeights> {
        self.resident.get(&key)
    }

    fn layer_tensor(&self, layer: usize, name: &str) -> Result<&Tensor> {
        self.store.tensor(&format!("L{layer}.{name}"))
    }

    /// Shared FFN math: (silu(h @ w1) * (h @ w3)) @ w2 over h [t, D].
    fn expert_ffn(&self, h: &TensorView, w: &ExpertWeights) -> Result<Tensor> {
        let (t, d) = (h.dims[0], self.cfg.d_model);
        let f = self.cfg.d_ff;
        match self.mode {
            KernelMode::Naive => {
                let a = naive::matmul(h.data, t, d, &w.0.data, f);
                let b = naive::matmul(h.data, t, d, &w.1.data, f);
                let mut g = vec![0.0f32; t * f];
                for i in 0..t * f {
                    g[i] = silu(a[i]) * b[i];
                }
                let out = naive::matmul(&g, t, f, &w.2.data, d);
                Tensor::new(vec![t, d], out)
            }
            KernelMode::Blocked => {
                let mut a = self.arena.take(t * f);
                let mut b = self.arena.take(t * f);
                kernels::matmul_into(h.data, t, d, &w.0.data, f, &mut a);
                kernels::matmul_into(h.data, t, d, &w.1.data, f, &mut b);
                // g = silu(a) * b, in place over a's buffer.
                for (g, &bv) in a.iter_mut().zip(b.iter()) {
                    *g = silu(*g) * bv;
                }
                let mut out = vec![0.0f32; t * d];
                kernels::matmul_into(&a, t, f, &w.2.data, d, &mut out);
                Tensor::new(vec![t, d], out)
            }
        }
    }

    /// Multi-head attention core for one query row against a key/value
    /// window laid out as index closures; writes the context into `o_row`.
    /// The naive-mode core (and the numeric contract the slice-based
    /// blocked lanes reproduce bit-for-bit).
    #[allow(clippy::too_many_arguments)]
    fn attend(
        &self,
        q_row: &[f32],
        n_keys: usize,
        key_at: impl Fn(usize, usize) -> f32,   // (t, dim) -> k value
        value_at: impl Fn(usize, usize) -> f32, // (t, dim) -> v value
        valid: impl Fn(usize) -> bool,
        o_row: &mut [f32],
    ) {
        let (heads, hd) = (self.cfg.n_heads, self.cfg.head_dim);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0.0f32; n_keys];
        for head in 0..heads {
            let base = head * hd;
            for (t, s) in scores.iter_mut().enumerate() {
                if valid(t) {
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += q_row[base + j] * key_at(t, base + j);
                    }
                    *s = dot * scale;
                } else {
                    *s = f32::NEG_INFINITY;
                }
            }
            softmax(&mut scores);
            for j in 0..hd {
                let mut acc = 0.0f32;
                for (t, &w) in scores.iter().enumerate() {
                    if w > 0.0 {
                        acc += w * value_at(t, base + j);
                    }
                }
                o_row[base + j] = acc;
            }
        }
    }

    fn rms(&self, x: &[f32], rows: usize, gain: &[f32], out: &mut [f32]) {
        let d = self.cfg.d_model;
        let eps = self.cfg.rms_eps as f32;
        match self.mode {
            KernelMode::Naive => out.copy_from_slice(&naive::rms_norm_rows(x, rows, d, gain, eps)),
            KernelMode::Blocked => kernels::rms_norm_rows_into(x, rows, d, gain, eps, out),
        }
    }

    fn mm(&self, a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
        match self.mode {
            KernelMode::Naive => out.copy_from_slice(&naive::matmul(a, m, k, b, n)),
            KernelMode::Blocked => kernels::matmul_into(a, m, k, b, n, out),
        }
    }
}

impl StageRunner for RefStages {
    fn embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor> {
        anyhow::ensure!(toks.len() == tb, "embed: {} tokens for bucket {tb}", toks.len());
        let emb = self.store.tensor("embed")?;
        let d = self.cfg.d_model;
        let mut out = vec![0.0f32; tb * d];
        for (i, &t) in toks.iter().enumerate() {
            let t = t as usize;
            anyhow::ensure!(t < self.cfg.vocab_size, "token {t} out of vocab");
            out[i * d..(i + 1) * d].copy_from_slice(emb.row(t));
        }
        Tensor::new(vec![tb, d], out)
    }

    fn attn_prefill(&self, layer: usize, x: &Tensor, len_mask: &Tensor) -> Result<[Tensor; 3]> {
        let (s, d) = (x.dims[0], self.cfg.d_model);
        let (heads, hd) = (self.cfg.n_heads, self.cfg.head_dim);
        let ln1 = self.layer_tensor(layer, "ln1")?;
        let wq = self.layer_tensor(layer, "wq")?;
        let wk = self.layer_tensor(layer, "wk")?;
        let wv = self.layer_tensor(layer, "wv")?;
        let wo = self.layer_tensor(layer, "wo")?;

        let mut h = self.arena.take(s * d);
        self.rms(&x.data, s, &ln1.data, &mut h);
        let mut q = self.arena.take(s * d);
        self.mm(&h, s, d, &wq.data, d, &mut q);
        // k and v are returned to the engine as tensors: fresh allocations.
        let mut k = vec![0.0f32; s * d];
        self.mm(&h, s, d, &wk.data, d, &mut k);
        let mut v = vec![0.0f32; s * d];
        self.mm(&h, s, d, &wv.data, d, &mut v);

        let mask = &len_mask.data;
        let mut o = self.arena.take(s * d);
        match self.mode {
            KernelMode::Naive => {
                for si in 0..s {
                    let mut o_row = vec![0.0f32; d];
                    self.attend(
                        &q[si * d..(si + 1) * d],
                        s,
                        |t, j| k[t * d + j],
                        |t, j| v[t * d + j],
                        |t| t <= si && mask[t] > 0.0,
                        &mut o_row,
                    );
                    o[si * d..(si + 1) * d].copy_from_slice(&o_row);
                }
            }
            KernelMode::Blocked => {
                let (q, k, v) = (&q[..], &k[..], &v[..]);
                let scale = 1.0 / (hd as f32).sqrt();
                // Each query row is an independent lane (disjoint o rows).
                par::par_rows(&mut o, s, 2 * s * d, |row0, chunk| {
                    let mut scores = vec![0.0f32; s];
                    for (ri, o_row) in chunk.chunks_mut(d).enumerate() {
                        let si = row0 + ri;
                        let q_row = &q[si * d..(si + 1) * d];
                        for head in 0..heads {
                            let base = head * hd;
                            let qh = &q_row[base..base + hd];
                            for (t, sc) in scores.iter_mut().enumerate() {
                                *sc = if t <= si && mask[t] > 0.0 {
                                    let kr = &k[t * d + base..t * d + base + hd];
                                    let mut dot = 0.0f32;
                                    for (&qv, &kv) in qh.iter().zip(kr) {
                                        dot += qv * kv;
                                    }
                                    dot * scale
                                } else {
                                    f32::NEG_INFINITY
                                };
                            }
                            softmax(&mut scores);
                            let oh = &mut o_row[base..base + hd];
                            for (t, &w) in scores.iter().enumerate() {
                                if w > 0.0 {
                                    let vr = &v[t * d + base..t * d + base + hd];
                                    for (ov, &vv) in oh.iter_mut().zip(vr) {
                                        *ov += w * vv;
                                    }
                                }
                            }
                        }
                    }
                });
            }
        }
        // y = x + o @ wo
        let mut proj = self.arena.take(s * d);
        self.mm(&o, s, d, &wo.data, d, &mut proj);
        let mut y = x.data.clone();
        for (a, b) in y.iter_mut().zip(proj.iter()) {
            *a += b;
        }
        Ok([
            Tensor::new(vec![s, d], y)?,
            Tensor::new(vec![s, d], k)?,
            Tensor::new(vec![s, d], v)?,
        ])
    }

    fn attn_decode(
        &self,
        layer: usize,
        bb: usize,
        x: &Tensor,
        kv: &dyn KvSource,
        pos_mask: &Tensor,
    ) -> Result<[Tensor; 3]> {
        let d = self.cfg.d_model;
        let (heads, hd) = (self.cfg.n_heads, self.cfg.head_dim);
        anyhow::ensure!(x.dims == vec![bb, d], "attn_decode x shape {:?}", x.dims);
        anyhow::ensure!(
            pos_mask.rank() == 2 && pos_mask.dims[0] == bb,
            "attn_decode pos_mask shape {:?}",
            pos_mask.dims
        );
        let s = pos_mask.dims[1];
        let n_real = kv.batch();
        anyhow::ensure!(n_real <= bb, "attn_decode: {n_real} sequences for bucket {bb}");
        for i in 0..n_real {
            let (kt, vt) = (kv.k(i), kv.v(i));
            anyhow::ensure!(
                kt.dims == [s, d] && vt.dims == [s, d],
                "attn_decode: seq {i} KV shape {:?}/{:?}, want [{s}, {d}]",
                kt.dims,
                vt.dims
            );
        }
        let ln1 = self.layer_tensor(layer, "ln1")?;
        let wq = self.layer_tensor(layer, "wq")?;
        let wk = self.layer_tensor(layer, "wk")?;
        let wv = self.layer_tensor(layer, "wv")?;
        let wo = self.layer_tensor(layer, "wo")?;

        let mut h = self.arena.take(bb * d);
        self.rms(&x.data, bb, &ln1.data, &mut h);
        let mut q = self.arena.take(bb * d);
        self.mm(&h, bb, d, &wq.data, d, &mut q);
        let mut k_new = vec![0.0f32; bb * d];
        self.mm(&h, bb, d, &wk.data, d, &mut k_new);
        let mut v_new = vec![0.0f32; bb * d];
        self.mm(&h, bb, d, &wv.data, d, &mut v_new);

        let mut o = self.arena.take(bb * d);
        match self.mode {
            KernelMode::Naive => {
                for b in 0..bb {
                    let (kc, vc) = lane_kv(kv, n_real, b);
                    let kn = &k_new[b * d..(b + 1) * d];
                    let vn = &v_new[b * d..(b + 1) * d];
                    let mask = &pos_mask.data[b * s..(b + 1) * s];
                    let mut o_row = vec![0.0f32; d];
                    // Window = S cached slots plus the current token appended
                    // at index S (always valid), exactly like
                    // attn_decode_stage.
                    self.attend(
                        &q[b * d..(b + 1) * d],
                        s + 1,
                        |t, j| if t < s { kc[t * d + j] } else { kn[j] },
                        |t, j| if t < s { vc[t * d + j] } else { vn[j] },
                        |t| t >= s || mask[t] > 0.0,
                        &mut o_row,
                    );
                    o[b * d..(b + 1) * d].copy_from_slice(&o_row);
                }
            }
            KernelMode::Blocked => {
                let (q, k_new_r, v_new_r) = (&q[..], &k_new[..], &v_new[..]);
                let scale = 1.0 / (hd as f32).sqrt();
                // Each batch lane is independent (disjoint o rows); the
                // window is the S cached slots plus the current token at
                // index S (always valid), like the naive closure form.
                par::par_rows(&mut o, bb, 2 * (s + 1) * d, |b0, chunk| {
                    let mut scores = vec![0.0f32; s + 1];
                    for (bi, o_row) in chunk.chunks_mut(d).enumerate() {
                        let b = b0 + bi;
                        let (kc, vc) = lane_kv(kv, n_real, b);
                        let kn = &k_new_r[b * d..(b + 1) * d];
                        let vn = &v_new_r[b * d..(b + 1) * d];
                        let mask = &pos_mask.data[b * s..(b + 1) * s];
                        let q_row = &q[b * d..(b + 1) * d];
                        for head in 0..heads {
                            let base = head * hd;
                            let qh = &q_row[base..base + hd];
                            for (t, sc) in scores[..s].iter_mut().enumerate() {
                                *sc = if mask[t] > 0.0 {
                                    let kr = &kc[t * d + base..t * d + base + hd];
                                    let mut dot = 0.0f32;
                                    for (&qv, &kv) in qh.iter().zip(kr) {
                                        dot += qv * kv;
                                    }
                                    dot * scale
                                } else {
                                    f32::NEG_INFINITY
                                };
                            }
                            {
                                let kr = &kn[base..base + hd];
                                let mut dot = 0.0f32;
                                for (&qv, &kv) in qh.iter().zip(kr) {
                                    dot += qv * kv;
                                }
                                scores[s] = dot * scale;
                            }
                            softmax(&mut scores);
                            let oh = &mut o_row[base..base + hd];
                            for t in 0..s {
                                let w = scores[t];
                                if w > 0.0 {
                                    let vr = &vc[t * d + base..t * d + base + hd];
                                    for (ov, &vv) in oh.iter_mut().zip(vr) {
                                        *ov += w * vv;
                                    }
                                }
                            }
                            let w_cur = scores[s];
                            if w_cur > 0.0 {
                                let vr = &vn[base..base + hd];
                                for (ov, &vv) in oh.iter_mut().zip(vr) {
                                    *ov += w_cur * vv;
                                }
                            }
                        }
                    }
                });
            }
        }
        let mut proj = self.arena.take(bb * d);
        self.mm(&o, bb, d, &wo.data, d, &mut proj);
        let mut y = x.data.clone();
        for (a, b) in y.iter_mut().zip(proj.iter()) {
            *a += b;
        }
        Ok([
            Tensor::new(vec![bb, d], y)?,
            Tensor::new(vec![bb, d], k_new)?,
            Tensor::new(vec![bb, d], v_new)?,
        ])
    }

    fn router(&self, layer: usize, y: &Tensor) -> Result<(Tensor, Tensor)> {
        let (t, d) = (y.dims[0], self.cfg.d_model);
        let e = self.cfg.n_experts;
        let ln2 = self.layer_tensor(layer, "ln2")?;
        let wg = self.layer_tensor(layer, "wg")?;
        let rbias = self.layer_tensor(layer, "rbias")?;
        // h and the probs are both returned: fresh allocations.
        let mut h = vec![0.0f32; t * d];
        self.rms(&y.data, t, &ln2.data, &mut h);
        let mut logits = vec![0.0f32; t * e];
        self.mm(&h, t, d, &wg.data, e, &mut logits);
        for r in 0..t {
            let row = &mut logits[r * e..(r + 1) * e];
            for (l, &b) in row.iter_mut().zip(&rbias.data) {
                *l += b;
            }
            softmax(row);
        }
        Ok((Tensor::new(vec![t, d], h)?, Tensor::new(vec![t, e], logits)?))
    }

    fn expert_resident(&self, _tb: usize, key: ExpertKey, h: &TensorView) -> Result<Tensor> {
        // Borrow the admitted Arc directly — no clone of any kind on the
        // per-invocation path.
        let w = self.resident.get(&key).with_context(|| {
            format!("expert L{}.E{} has no device buffers", key.layer, key.expert)
        })?;
        self.expert_ffn(h, w)
    }

    fn expert_transient(&self, _tb: usize, w: &ExpertWeights, h: &TensorView) -> Result<Tensor> {
        self.expert_ffn(h, w)
    }

    fn lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor> {
        let d = self.cfg.d_model;
        anyhow::ensure!(x.dims == vec![tb, d], "lm_head x shape {:?}", x.dims);
        let gain = self.store.tensor("final_gain")?;
        let emb = self.store.tensor("embed")?;
        let v = self.cfg.vocab_size;
        match self.mode {
            KernelMode::Naive => {
                let h = naive::rms_norm_rows(&x.data, tb, d, &gain.data, self.cfg.rms_eps as f32);
                // Tied embedding: logits = h @ embed^T, with embed stored
                // [V, D] — the transposed (row-dot) layout.
                let logits = naive::matmul_bt(&h, tb, d, &emb.data, v);
                Tensor::new(vec![tb, v], logits)
            }
            KernelMode::Blocked => {
                let mut h = self.arena.take(tb * d);
                self.rms(&x.data, tb, &gain.data, &mut h);
                let mut logits = vec![0.0f32; tb * v];
                kernels::matmul_bt_into(&h, tb, d, &emb.data, v, &mut logits);
                Tensor::new(vec![tb, v], logits)
            }
        }
    }

    fn admit_expert(&mut self, key: ExpertKey, w: &ExpertWeights) -> Result<()> {
        if key.layer >= self.cfg.n_layers || key.expert >= self.cfg.n_experts {
            bail!("admit_expert: key L{}.E{} out of range", key.layer, key.expert);
        }
        // Arc clone: a refcount bump, never a copy of the tensor bytes.
        self.resident.insert(key, w.clone());
        Ok(())
    }

    fn evict_expert(&mut self, key: ExpertKey) {
        self.resident.remove(&key);
    }

    /// All stage math is pure over `&self` (the arena is mutex-pooled), so
    /// the engine may fan expert groups out across threads.
    fn supports_parallel(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernels::naive::rms_norm_rows;
    use crate::runtime::KvSlices;

    fn stages() -> RefStages {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 7));
        RefStages::new(cfg, store)
    }

    #[test]
    fn router_probs_are_distributions() {
        let s = stages();
        let t = 3;
        let y = Tensor::new(
            vec![t, 16],
            (0..t * 16).map(|i| (i % 7) as f32 / 7.0 - 0.5).collect(),
        )
        .unwrap();
        let (h, probs) = s.router(0, &y).unwrap();
        assert_eq!(h.dims, vec![t, 16]);
        assert_eq!(probs.dims, vec![t, 8]);
        for r in 0..t {
            let sum: f32 = probs.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(probs.row(r).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn router_matches_host_router_probs() {
        // The PreGate predictor's host router math is an independent
        // implementation of the same stage; they must agree.
        let s = stages();
        let y = Tensor::new(vec![1, 16], (0..16).map(|i| i as f32 / 9.0 - 0.8).collect()).unwrap();
        let (_, probs) = s.router(1, &y).unwrap();
        let expect = crate::prefetch::host_router_probs(
            y.row(0),
            16,
            &s.store.tensor("L1.ln2").unwrap().data,
            s.store.tensor("L1.wg").unwrap(),
            &s.store.tensor("L1.rbias").unwrap().data,
            s.cfg.rms_eps as f32,
        );
        for (a, b) in probs.row(0).iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "router mismatch: {a} vs {b}");
        }
    }

    #[test]
    fn expert_requires_admission() {
        let mut s = stages();
        let key = ExpertKey::new(0, 3);
        let h = Tensor::zeros(vec![2, 16]);
        let hv = TensorView::from_tensor(&h);
        assert!(s.expert_resident(2, key, &hv).is_err());
        let w = s.store.expert(key).unwrap();
        s.admit_expert(key, &w).unwrap();
        let y = s.expert_resident(2, key, &hv).unwrap();
        assert_eq!(y.dims, vec![2, 16]);
        s.evict_expert(key);
        assert!(s.expert_resident(2, key, &hv).is_err());
    }

    #[test]
    fn admitted_weights_are_arc_shared() {
        let mut s = stages();
        let key = ExpertKey::new(1, 2);
        let w = s.store.expert(key).unwrap();
        s.admit_expert(key, &w).unwrap();
        let resident = s.resident_weights(key).expect("resident after admit");
        assert!(
            Arc::ptr_eq(resident, &w),
            "admit_expert must share the store's allocation, not copy it"
        );
    }

    #[test]
    fn expert_zero_input_zero_output() {
        let s = stages();
        let w = s.store.expert(ExpertKey::new(1, 1)).unwrap();
        let h = Tensor::zeros(vec![1, 16]);
        let y = s.expert_transient(1, &w, &TensorView::from_tensor(&h)).unwrap();
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn attn_decode_shapes_and_mask() {
        let s = stages();
        let (bb, d, sq) = (2, 16, 16);
        let x = Tensor::new(vec![bb, d], (0..bb * d).map(|i| (i % 5) as f32 - 2.0).collect())
            .unwrap();
        let kcs: Vec<Tensor> = (0..bb).map(|_| Tensor::zeros(vec![sq, d])).collect();
        let vcs: Vec<Tensor> = (0..bb).map(|_| Tensor::zeros(vec![sq, d])).collect();
        let kr: Vec<&Tensor> = kcs.iter().collect();
        let vr: Vec<&Tensor> = vcs.iter().collect();
        let kv = KvSlices { k: &kr, v: &vr };
        // No cached positions valid: attention sees only the current token.
        let pm = Tensor::zeros(vec![bb, sq]);
        let [y, kn, vn] = s.attn_decode(0, bb, &x, &kv, &pm).unwrap();
        assert_eq!(y.dims, vec![bb, d]);
        assert_eq!(kn.dims, vec![bb, d]);
        assert_eq!(vn.dims, vec![bb, d]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn attn_decode_padding_lanes_need_no_cache() {
        // A view narrower than the batch bucket: lanes >= kv.batch() have
        // no cache tensors at all and must still produce finite rows
        // (they attend only to their own current token).
        let s = stages();
        let (bb, d, sq) = (4, 16, 16);
        let x = Tensor::zeros(vec![bb, d]);
        let kc = Tensor::zeros(vec![sq, d]);
        let vc = Tensor::zeros(vec![sq, d]);
        let kr = [&kc];
        let vr = [&vc];
        let kv = KvSlices { k: &kr, v: &vr };
        let pm = Tensor::zeros(vec![bb, sq]);
        let [y, kn, vn] = s.attn_decode(0, bb, &x, &kv, &pm).unwrap();
        assert_eq!(y.dims, vec![bb, d]);
        assert_eq!(kn.dims, vec![bb, d]);
        assert_eq!(vn.dims, vec![bb, d]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prefill_is_causal() {
        // Changing a later token must not change an earlier row's output.
        let s = stages();
        let d = 16;
        let sq = 8;
        let mk = |last: f32| {
            let mut x = Tensor::zeros(vec![sq, d]);
            for t in 0..sq {
                for j in 0..d {
                    x.row_mut(t)[j] = ((t * d + j) % 11) as f32 / 11.0 - 0.5;
                }
            }
            x.row_mut(sq - 1)[0] = last;
            x
        };
        let mask = Tensor::new(vec![sq], vec![1.0; sq]).unwrap();
        let [y_a, _, _] = s.attn_prefill(0, &mk(0.3), &mask).unwrap();
        let [y_b, _, _] = s.attn_prefill(0, &mk(9.0), &mask).unwrap();
        for t in 0..sq - 1 {
            assert_eq!(y_a.row(t), y_b.row(t), "row {t} must not see the future");
        }
        assert_ne!(y_a.row(sq - 1), y_b.row(sq - 1));
    }

    #[test]
    fn lm_head_is_tied_embedding() {
        let s = stages();
        // With unit final_gain, logits of a row equal rms-normed dot with
        // each embedding row; check against a direct computation.
        let x = Tensor::new(vec![1, 16], (0..16).map(|i| i as f32 / 16.0).collect()).unwrap();
        let logits = s.lm_head(1, &x).unwrap();
        assert_eq!(logits.dims, vec![1, 64]);
        let emb = s.store.tensor("embed").unwrap();
        let h = rms_norm_rows(&x.data, 1, 16, &[1.0; 16], s.cfg.rms_eps as f32);
        let mut dot0 = 0.0f32;
        for j in 0..16 {
            dot0 += h[j] * emb.row(0)[j];
        }
        assert!((logits.row(0)[0] - dot0).abs() < 1e-5);
    }
}
