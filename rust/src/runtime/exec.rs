//! Literal <-> host-tensor plumbing and output handling.

use anyhow::{bail, Context, Result};

use crate::util::tensor::Tensor;

/// f32 tensor -> Literal with the tensor's shape.
pub fn lit_tensor(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .context("reshaping literal")
}

/// i32 vector -> rank-1 Literal.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Literal -> host tensor (f32).
pub fn tensor_from_lit(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal to_vec<f32>")?;
    Tensor::new(dims, data)
}

/// Stage outputs, decomposed when the artifact root is a tuple.
pub struct ExecOutputs {
    pub outputs: Vec<Tensor>,
}

impl ExecOutputs {
    /// From the raw PJRT result of one execute call.
    pub fn from_result(
        mut result: Vec<Vec<xla::PjRtBuffer>>,
        tuple_output: bool,
    ) -> Result<Self> {
        if result.is_empty() || result[0].is_empty() {
            bail!("empty execution result");
        }
        let buf = result.swap_remove(0).swap_remove(0);
        let lit = buf.to_literal_sync().context("to_literal_sync")?;
        let outputs = if tuple_output {
            lit.to_tuple()
                .context("decomposing tuple output")?
                .iter()
                .map(tensor_from_lit)
                .collect::<Result<Vec<_>>>()?
        } else {
            vec![tensor_from_lit(&lit)?]
        };
        Ok(Self { outputs })
    }

    pub fn single(mut self) -> Result<Tensor> {
        if self.outputs.len() != 1 {
            bail!("expected single output, got {}", self.outputs.len());
        }
        Ok(self.outputs.swap_remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_tensor_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = lit_tensor(&t).unwrap();
        let back = tensor_from_lit(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_literal() {
        let l = lit_i32(&[1, 2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
