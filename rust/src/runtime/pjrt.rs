//! The PJRT stage backend: AOT HLO artifacts compiled and executed through
//! the PJRT CPU client (`xla` crate). All PJRT interaction happens on the
//! thread that owns the engine; the transfer thread only touches host
//! state.
//!
//! Two execution paths per stage, selected by `weight_buffers`:
//! * **buffer path** (default) — non-expert weights live as device-resident
//!   buffers created once at startup (§Perf: saves one host->device weight
//!   copy per stage invocation on the hot path);
//! * **literal path** — weights shipped as literals on every call, retained
//!   for before/after measurement.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::runtime::artifacts::{ArtifactRegistry, Runtime};
use crate::runtime::exec::{lit_i32, lit_tensor};
use crate::runtime::{materialize_kv, KvSource, StageRunner};
use crate::util::tensor::{Tensor, TensorView};
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

struct LayerLits {
    ln1: xla::Literal,
    wq: xla::Literal,
    wk: xla::Literal,
    wv: xla::Literal,
    wo: xla::Literal,
    ln2: xla::Literal,
    wg: xla::Literal,
    rbias: xla::Literal,
}

/// Device-resident copies of per-layer non-expert weights (§Perf: created
/// once, reused every call).
struct LayerBufs {
    ln1: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    ln2: xla::PjRtBuffer,
    wg: xla::PjRtBuffer,
    rbias: xla::PjRtBuffer,
}

pub struct PjrtStages {
    rt: Runtime,
    reg: ArtifactRegistry,
    lit_embed: xla::Literal,
    lit_final_gain: xla::Literal,
    layer_lits: Vec<LayerLits>,
    buf_embed: Option<xla::PjRtBuffer>,
    buf_final_gain: Option<xla::PjRtBuffer>,
    layer_bufs: Vec<LayerBufs>,
}

// SAFETY: PJRT interaction is thread-confined by construction — this
// backend reports `StageRunner::supports_parallel() == false`, so the
// engine's only fan-out site (model/engine.rs::run_moe) executes its stage
// calls sequentially on the owning thread. These impls exist solely to
// satisfy the `StageRunner: Send + Sync` bound shared with the genuinely
// thread-safe reference backend; no PJRT handle is ever touched
// concurrently. Do not override supports_parallel here without making the
// xla handles actually synchronized.
unsafe impl Send for PjrtStages {}
unsafe impl Sync for PjrtStages {}

impl PjrtStages {
    pub fn new(cfg: &ModelConfig, store: &Arc<WeightStore>, weight_buffers: bool) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let reg = rt.load_artifacts(cfg)?;

        // Cache non-expert weights as literals once.
        let lit_embed = lit_tensor(store.tensor("embed")?)?;
        let lit_final_gain = lit_tensor(store.tensor("final_gain")?)?;
        let mut layer_lits = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g = |n: &str| -> Result<xla::Literal> {
                lit_tensor(store.tensor(&format!("L{l}.{n}"))?)
            };
            layer_lits.push(LayerLits {
                ln1: g("ln1")?,
                wq: g("wq")?,
                wk: g("wk")?,
                wv: g("wv")?,
                wo: g("wo")?,
                ln2: g("ln2")?,
                wg: g("wg")?,
                rbias: g("rbias")?,
            });
        }

        // §Perf: device-resident non-expert weights for the buffer path.
        let (buf_embed, buf_final_gain, layer_bufs) = if weight_buffers {
            let te = store.tensor("embed")?;
            let tg = store.tensor("final_gain")?;
            let mut bufs = Vec::with_capacity(cfg.n_layers);
            for l in 0..cfg.n_layers {
                let g = |n: &str| -> Result<xla::PjRtBuffer> {
                    let t = store.tensor(&format!("L{l}.{n}"))?;
                    rt.to_device(&t.data, &t.dims)
                };
                bufs.push(LayerBufs {
                    ln1: g("ln1")?,
                    wq: g("wq")?,
                    wk: g("wk")?,
                    wv: g("wv")?,
                    wo: g("wo")?,
                    ln2: g("ln2")?,
                    wg: g("wg")?,
                    rbias: g("rbias")?,
                });
            }
            (
                Some(rt.to_device(&te.data, &te.dims)?),
                Some(rt.to_device(&tg.data, &tg.dims)?),
                bufs,
            )
        } else {
            (None, None, Vec::new())
        };

        Ok(Self {
            rt,
            reg,
            lit_embed,
            lit_final_gain,
            layer_lits,
            buf_embed,
            buf_final_gain,
            layer_bufs,
        })
    }

    fn triple(out: Vec<Tensor>, stage: &str) -> Result<[Tensor; 3]> {
        out.try_into()
            .map_err(|_| anyhow::anyhow!("{stage} output arity"))
    }
}

impl StageRunner for PjrtStages {
    fn embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor> {
        let name = format!("embed_T{tb}");
        if let Some(be) = &self.buf_embed {
            let bt = self.rt.to_device_i32(toks, &[toks.len()])?;
            self.reg.run_buffers(&name, &[&bt, be])?.single()
        } else {
            let lt = lit_i32(toks);
            self.reg.run_lits(&name, &[&lt, &self.lit_embed])?.single()
        }
    }

    fn attn_prefill(&self, layer: usize, x: &Tensor, len_mask: &Tensor) -> Result<[Tensor; 3]> {
        let out = if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[layer];
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            let bm = self.rt.to_device(&len_mask.data, &len_mask.dims)?;
            self.reg
                .run_buffers(
                    "attn_prefill",
                    &[&bx, &bm, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &lb.wo],
                )?
                .outputs
        } else {
            let ll = &self.layer_lits[layer];
            let lx = lit_tensor(x)?;
            let lm = lit_tensor(len_mask)?;
            self.reg
                .run_lits(
                    "attn_prefill",
                    &[&lx, &lm, &ll.ln1, &ll.wq, &ll.wk, &ll.wv, &ll.wo],
                )?
                .outputs
        };
        Self::triple(out, "attn_prefill")
    }

    fn attn_decode(
        &self,
        layer: usize,
        bb: usize,
        x: &Tensor,
        kv: &dyn KvSource,
        pos_mask: &Tensor,
    ) -> Result<[Tensor; 3]> {
        let name = format!("attn_decode_B{bb}");
        // The AOT artifact wants contiguous [bb, s, d] device inputs:
        // materialize the borrowed view once at the trait boundary — the
        // one sanctioned KV copy (counted in `runtime::kv_copy_bytes`),
        // byte-identical to the seed's per-layer assembly.
        let d = x.dims[1];
        let s = pos_mask.dims[1];
        let (k_cache, v_cache) = materialize_kv(kv, bb, s, d)?;
        let out = if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[layer];
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            let bk = self.rt.to_device(&k_cache.data, &k_cache.dims)?;
            let bv = self.rt.to_device(&v_cache.data, &v_cache.dims)?;
            let bm = self.rt.to_device(&pos_mask.data, &pos_mask.dims)?;
            self.reg
                .run_buffers(
                    &name,
                    &[&bx, &bk, &bv, &bm, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &lb.wo],
                )?
                .outputs
        } else {
            let ll = &self.layer_lits[layer];
            let lx = lit_tensor(x)?;
            let lk = lit_tensor(&k_cache)?;
            let lv = lit_tensor(&v_cache)?;
            let lm = lit_tensor(pos_mask)?;
            self.reg
                .run_lits(
                    &name,
                    &[&lx, &lk, &lv, &lm, &ll.ln1, &ll.wq, &ll.wk, &ll.wv, &ll.wo],
                )?
                .outputs
        };
        Self::triple(out, "attn_decode")
    }

    fn router(&self, layer: usize, y: &Tensor) -> Result<(Tensor, Tensor)> {
        let t = y.dims[0];
        let name = format!("router_T{t}");
        let out = if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[layer];
            let by = self.rt.to_device(&y.data, &y.dims)?;
            self.reg.run_buffers(&name, &[&by, &lb.ln2, &lb.wg, &lb.rbias])?
        } else {
            let ll = &self.layer_lits[layer];
            let ly = lit_tensor(y)?;
            self.reg.run_lits(&name, &[&ly, &ll.ln2, &ll.wg, &ll.rbias])?
        };
        let mut it = out.outputs.into_iter();
        let h = it.next().context("router h")?;
        let probs = it.next().context("router probs")?;
        Ok((h, probs))
    }

    fn expert_resident(&self, tb: usize, key: ExpertKey, h: &TensorView) -> Result<Tensor> {
        let hbuf = self.rt.to_device(h.data, h.dims)?;
        let bufs = self.reg.expert_buffers(key)?;
        self.reg
            .run_buffers(&format!("expert_T{tb}"), &[&hbuf, &bufs[0], &bufs[1], &bufs[2]])?
            .single()
    }

    fn expert_transient(&self, tb: usize, w: &ExpertWeights, h: &TensorView) -> Result<Tensor> {
        let hbuf = self.rt.to_device(h.data, h.dims)?;
        let b1 = self.rt.to_device(&w.0.data, &w.0.dims)?;
        let b3 = self.rt.to_device(&w.1.data, &w.1.dims)?;
        let b2 = self.rt.to_device(&w.2.data, &w.2.dims)?;
        self.reg
            .run_buffers(&format!("expert_T{tb}"), &[&hbuf, &b1, &b3, &b2])?
            .single()
    }

    fn lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor> {
        let name = format!("lm_head_T{tb}");
        if let (Some(bg), Some(be)) = (&self.buf_final_gain, &self.buf_embed) {
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            self.reg.run_buffers(&name, &[&bx, bg, be])?.single()
        } else {
            let lx = lit_tensor(x)?;
            self.reg
                .run_lits(&name, &[&lx, &self.lit_final_gain, &self.lit_embed])?
                .single()
        }
    }

    fn admit_expert(&mut self, key: ExpertKey, w: &ExpertWeights) -> Result<()> {
        self.reg.admit_expert(&self.rt, key, w)
    }

    fn evict_expert(&mut self, key: ExpertKey) {
        self.reg.evict_expert(key);
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
