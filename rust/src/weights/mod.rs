//! Weight handling: the BMW bundle reader (binary contract with
//! `python/compile/bmw.py`) and the CPU-side weight store the offloading
//! system fetches experts from.

mod format;
mod store;

pub use format::{read_bmw, write_bmw};
pub use store::{ExpertKey, ExpertWeights, WeightStore};
