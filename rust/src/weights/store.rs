//! CPU-side weight store: the "host memory" tier of the offloading system.
//!
//! Non-expert weights (attention, router, embeddings) are always
//! GPU-resident in the paper's setting and are exposed directly. Expert
//! weights are fetched through [`WeightStore::expert`] by the transfer
//! engine when the cache loads them.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::format::read_bmw;
use crate::config::ModelConfig;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// (layer, expert) identifier used across the whole system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ExpertKey {
    pub layer: usize,
    pub expert: usize,
}

impl ExpertKey {
    pub fn new(layer: usize, expert: usize) -> Self {
        Self { layer, expert }
    }
}

/// One expert's three projection tensors, shared behind Arc so "transfers"
/// can hand them around without copying host memory twice.
pub type ExpertWeights = Arc<(Tensor, Tensor, Tensor)>;

#[derive(Debug)]
pub struct WeightStore {
    tensors: BTreeMap<String, Tensor>,
    experts: BTreeMap<ExpertKey, ExpertWeights>,
    pub expert_bytes: usize,
}

impl WeightStore {
    pub fn load(cfg: &ModelConfig) -> Result<Self> {
        let tensors = read_bmw(&cfg.weights_path())?;
        Self::from_tensors(cfg, tensors)
    }

    pub fn from_tensors(
        cfg: &ModelConfig,
        mut tensors: BTreeMap<String, Tensor>,
    ) -> Result<Self> {
        let mut experts = BTreeMap::new();
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let k = ExpertKey::new(l, e);
                let w1 = tensors
                    .remove(&format!("L{l}.E{e}.w1"))
                    .with_context(|| format!("missing L{l}.E{e}.w1"))?;
                let w3 = tensors
                    .remove(&format!("L{l}.E{e}.w3"))
                    .with_context(|| format!("missing L{l}.E{e}.w3"))?;
                let w2 = tensors
                    .remove(&format!("L{l}.E{e}.w2"))
                    .with_context(|| format!("missing L{l}.E{e}.w2"))?;
                experts.insert(k, Arc::new((w1, w3, w2)));
            }
        }
        Ok(Self { tensors, experts, expert_bytes: cfg.expert_bytes() })
    }

    /// Synthetic random weights for unit tests (no artifacts needed).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tensors = BTreeMap::new();
        let d = cfg.d_model;
        let (v, e, f) = (cfg.vocab_size, cfg.n_experts, cfg.d_ff);
        let mut randt = |dims: Vec<usize>, scale: f32| {
            let n: usize = dims.iter().product();
            let data = (0..n).map(|_| rng.normal() as f32 * scale).collect();
            Tensor::new(dims, data).unwrap()
        };
        tensors.insert("embed".into(), randt(vec![v, d], 1.0));
        tensors.insert("final_gain".into(), Tensor::new(vec![d], vec![1.0; d]).unwrap());
        let mut experts = BTreeMap::new();
        for l in 0..cfg.n_layers {
            let p = format!("L{l}.");
            tensors.insert(p.clone() + "ln1", Tensor::new(vec![d], vec![1.0; d]).unwrap());
            tensors.insert(p.clone() + "ln2", Tensor::new(vec![d], vec![1.0; d]).unwrap());
            for n in ["wq", "wk", "wv", "wo"] {
                tensors.insert(p.clone() + n, randt(vec![d, d], 1.0 / (d as f32).sqrt()));
            }
            tensors.insert(p.clone() + "wg", randt(vec![d, e], 1.0));
            tensors.insert(p.clone() + "rbias", randt(vec![e], 1.0));
            for ei in 0..e {
                let w1 = randt(vec![d, f], 1.0 / (d as f32).sqrt());
                let w3 = randt(vec![d, f], 1.0 / (d as f32).sqrt());
                let w2 = randt(vec![f, d], 1.0 / (f as f32).sqrt());
                experts.insert(ExpertKey::new(l, ei), Arc::new((w1, w3, w2)));
            }
        }
        Self { tensors, experts, expert_bytes: cfg.expert_bytes() }
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))
    }

    pub fn expert(&self, key: ExpertKey) -> Result<ExpertWeights> {
        self.experts
            .get(&key)
            .cloned()
            .with_context(|| format!("missing expert L{}.E{}", key.layer, key.expert))
    }

    pub fn expert_count(&self) -> usize {
        self.experts.len()
    }

    /// Flattened concatenation of one expert's parameters (similarity
    /// analysis, Fig 4).
    pub fn expert_flat(&self, key: ExpertKey) -> Result<Vec<f32>> {
        let w = self.expert(key)?;
        let mut flat = Vec::with_capacity(w.0.len() + w.1.len() + w.2.len());
        flat.extend_from_slice(&w.0.data);
        flat.extend_from_slice(&w.1.data);
        flat.extend_from_slice(&w.2.data);
        Ok(flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_store_complete() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 1);
        assert_eq!(s.expert_count(), cfg.total_experts());
        assert!(s.tensor("embed").is_ok());
        assert!(s.tensor("L0.wq").is_ok());
        assert!(s.tensor("nope").is_err());
        let e = s.expert(ExpertKey::new(0, 0)).unwrap();
        assert_eq!(e.0.dims, vec![cfg.d_model, cfg.d_ff]);
        assert_eq!(e.2.dims, vec![cfg.d_ff, cfg.d_model]);
    }

    #[test]
    fn expert_flat_length() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 2);
        let flat = s.expert_flat(ExpertKey::new(1, 3)).unwrap();
        assert_eq!(flat.len(), cfg.expert_param_count());
    }

    #[test]
    fn deterministic_synthetic() {
        let cfg = ModelConfig::test_tiny();
        let a = WeightStore::synthetic(&cfg, 5);
        let b = WeightStore::synthetic(&cfg, 5);
        assert_eq!(
            a.expert(ExpertKey::new(0, 1)).unwrap().0.data,
            b.expert(ExpertKey::new(0, 1)).unwrap().0.data
        );
    }

    #[test]
    fn missing_expert_errors() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 1);
        assert!(s.expert(ExpertKey::new(99, 0)).is_err());
    }
}
