//! CPU-side weight store: the "host memory" tier of the offloading system.
//!
//! Non-expert weights (attention, router, embeddings) are always
//! GPU-resident in the paper's setting and are exposed directly. Expert
//! weights are fetched through [`WeightStore::expert`] by the transfer
//! engine when the cache loads them.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::format::read_bmw;
use crate::config::ModelConfig;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// (layer, expert) identifier used across the whole system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ExpertKey {
    pub layer: usize,
    pub expert: usize,
}

impl ExpertKey {
    pub fn new(layer: usize, expert: usize) -> Self {
        Self { layer, expert }
    }
}

/// One expert's three projection tensors behind an `Arc` — the zero-copy
/// contract of the whole transfer/cache/backend path: store fetches,
/// transfer-engine arrivals, backend admission, and `expert_resident`
/// lookups all move this pointer, never the 3x(d x d_ff) f32 payload
/// (`Arc::ptr_eq`-asserted in `tests/kernel_equivalence.rs`).
pub type ExpertWeights = Arc<(Tensor, Tensor, Tensor)>;

#[derive(Debug)]
pub struct WeightStore {
    tensors: BTreeMap<String, Tensor>,
    experts: BTreeMap<ExpertKey, ExpertWeights>,
    pub expert_bytes: usize,
}

/// `n` normal samples scaled by `scale` (synthetic weight generation).
fn randv(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

impl WeightStore {
    pub fn load(cfg: &ModelConfig) -> Result<Self> {
        let tensors = read_bmw(&cfg.weights_path())?;
        Self::from_tensors(cfg, tensors)
    }

    pub fn from_tensors(
        cfg: &ModelConfig,
        mut tensors: BTreeMap<String, Tensor>,
    ) -> Result<Self> {
        let mut experts = BTreeMap::new();
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let k = ExpertKey::new(l, e);
                let w1 = tensors
                    .remove(&format!("L{l}.E{e}.w1"))
                    .with_context(|| format!("missing L{l}.E{e}.w1"))?;
                let w3 = tensors
                    .remove(&format!("L{l}.E{e}.w3"))
                    .with_context(|| format!("missing L{l}.E{e}.w3"))?;
                let w2 = tensors
                    .remove(&format!("L{l}.E{e}.w2"))
                    .with_context(|| format!("missing L{l}.E{e}.w2"))?;
                experts.insert(k, Arc::new((w1, w3, w2)));
            }
        }
        Ok(Self { tensors, experts, expert_bytes: cfg.expert_bytes() })
    }

    /// Non-expert scaffolding shared by both synthetic stores: embedding,
    /// final gain, and per-layer norms + attention projections.
    fn synthetic_base(cfg: &ModelConfig, rng: &mut Rng) -> BTreeMap<String, Tensor> {
        let d = cfg.d_model;
        let v = cfg.vocab_size;
        let wscale = 1.0 / (d as f32).sqrt();
        let mut tensors = BTreeMap::new();
        tensors.insert(
            "embed".into(),
            Tensor::new(vec![v, d], randv(rng, v * d, 1.0)).unwrap(),
        );
        tensors.insert("final_gain".into(), Tensor::new(vec![d], vec![1.0; d]).unwrap());
        for l in 0..cfg.n_layers {
            let p = format!("L{l}.");
            tensors.insert(p.clone() + "ln1", Tensor::new(vec![d], vec![1.0; d]).unwrap());
            tensors.insert(p.clone() + "ln2", Tensor::new(vec![d], vec![1.0; d]).unwrap());
            for n in ["wq", "wk", "wv", "wo"] {
                tensors.insert(
                    p.clone() + n,
                    Tensor::new(vec![d, d], randv(rng, d * d, wscale)).unwrap(),
                );
            }
        }
        tensors
    }

    /// Synthetic random weights for unit tests (no artifacts needed).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tensors = Self::synthetic_base(cfg, &mut rng);
        let d = cfg.d_model;
        let (e, f) = (cfg.n_experts, cfg.d_ff);
        let wscale = 1.0 / (d as f32).sqrt();
        let w2scale = 1.0 / (f as f32).sqrt();
        let mut experts = BTreeMap::new();
        for l in 0..cfg.n_layers {
            let p = format!("L{l}.");
            tensors.insert(
                p.clone() + "wg",
                Tensor::new(vec![d, e], randv(&mut rng, d * e, 1.0)).unwrap(),
            );
            tensors.insert(
                p.clone() + "rbias",
                Tensor::new(vec![e], randv(&mut rng, e, 1.0)).unwrap(),
            );
            for ei in 0..e {
                let w1 = Tensor::new(vec![d, f], randv(&mut rng, d * f, wscale)).unwrap();
                let w3 = Tensor::new(vec![d, f], randv(&mut rng, d * f, wscale)).unwrap();
                let w2 = Tensor::new(vec![f, d], randv(&mut rng, f * d, w2scale)).unwrap();
                experts.insert(ExpertKey::new(l, ei), Arc::new((w1, w3, w2)));
            }
        }
        Self { tensors, experts, expert_bytes: cfg.expert_bytes() }
    }

    /// Synthetic weights with *family structure*, mirroring what
    /// `python/compile/weightgen.py` builds for the real artifacts: experts
    /// within a family (of `cfg.family_size`) share a base weight matrix
    /// plus small per-member noise, and the router projection gives family
    /// members nearly identical logits. Consequences the integration tests
    /// rely on: family members co-activate (so CFT buddy lists are
    /// family-dominated) and substituting a missing expert with a resident
    /// family buddy perturbs the output only slightly — the paper's
    /// redundancy premise, reproduced without artifacts.
    pub fn synthetic_families(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tensors = Self::synthetic_base(cfg, &mut rng);
        let d = cfg.d_model;
        let (e, f) = (cfg.n_experts, cfg.d_ff);
        let fam = cfg.family_size.max(1);
        let mut experts = BTreeMap::new();
        let wscale = 1.0 / (d as f32).sqrt();
        let w2scale = 1.0 / (f as f32).sqrt();
        for l in 0..cfg.n_layers {
            let p = format!("L{l}.");
            // Router: family members get near-identical columns -> they
            // co-select; per-member noise keeps popularity distinguishable.
            let n_fam = e.div_ceil(fam);
            let fam_cols: Vec<Vec<f32>> =
                (0..n_fam).map(|_| randv(&mut rng, d, 1.0)).collect();
            let mut wg = vec![0.0f32; d * e];
            for ei in 0..e {
                let base = &fam_cols[ei / fam];
                let noise = randv(&mut rng, d, 0.15);
                for di in 0..d {
                    wg[di * e + ei] = base[di] + noise[di];
                }
            }
            tensors.insert(p.clone() + "wg", Tensor::new(vec![d, e], wg).unwrap());
            tensors.insert(
                p.clone() + "rbias",
                Tensor::new(vec![e], randv(&mut rng, e, 0.5)).unwrap(),
            );
            // Expert FFNs: shared family base + small member noise.
            for fi in 0..n_fam {
                let b1 = randv(&mut rng, d * f, wscale);
                let b3 = randv(&mut rng, d * f, wscale);
                let b2 = randv(&mut rng, f * d, w2scale);
                for m in 0..fam {
                    let ei = fi * fam + m;
                    if ei >= e {
                        break;
                    }
                    let perturb = |base: &[f32], scale: f32, rng: &mut Rng| -> Vec<f32> {
                        base.iter()
                            .map(|&x| x + rng.normal() as f32 * scale * 0.15)
                            .collect()
                    };
                    let w1 = Tensor::new(vec![d, f], perturb(&b1, wscale, &mut rng)).unwrap();
                    let w3 = Tensor::new(vec![d, f], perturb(&b3, wscale, &mut rng)).unwrap();
                    let w2 = Tensor::new(vec![f, d], perturb(&b2, w2scale, &mut rng)).unwrap();
                    experts.insert(ExpertKey::new(l, ei), Arc::new((w1, w3, w2)));
                }
            }
        }
        Self { tensors, experts, expert_bytes: cfg.expert_bytes() }
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))
    }

    pub fn expert(&self, key: ExpertKey) -> Result<ExpertWeights> {
        self.experts
            .get(&key)
            .cloned()
            .with_context(|| format!("missing expert L{}.E{}", key.layer, key.expert))
    }

    pub fn expert_count(&self) -> usize {
        self.experts.len()
    }

    /// Flattened concatenation of one expert's parameters (similarity
    /// analysis, Fig 4).
    pub fn expert_flat(&self, key: ExpertKey) -> Result<Vec<f32>> {
        let w = self.expert(key)?;
        let mut flat = Vec::with_capacity(w.0.len() + w.1.len() + w.2.len());
        flat.extend_from_slice(&w.0.data);
        flat.extend_from_slice(&w.1.data);
        flat.extend_from_slice(&w.2.data);
        Ok(flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_store_complete() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 1);
        assert_eq!(s.expert_count(), cfg.total_experts());
        assert!(s.tensor("embed").is_ok());
        assert!(s.tensor("L0.wq").is_ok());
        assert!(s.tensor("nope").is_err());
        let e = s.expert(ExpertKey::new(0, 0)).unwrap();
        assert_eq!(e.0.dims, vec![cfg.d_model, cfg.d_ff]);
        assert_eq!(e.2.dims, vec![cfg.d_ff, cfg.d_model]);
    }

    #[test]
    fn expert_flat_length() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 2);
        let flat = s.expert_flat(ExpertKey::new(1, 3)).unwrap();
        assert_eq!(flat.len(), cfg.expert_param_count());
    }

    #[test]
    fn deterministic_synthetic() {
        let cfg = ModelConfig::test_tiny();
        let a = WeightStore::synthetic(&cfg, 5);
        let b = WeightStore::synthetic(&cfg, 5);
        assert_eq!(
            a.expert(ExpertKey::new(0, 1)).unwrap().0.data,
            b.expert(ExpertKey::new(0, 1)).unwrap().0.data
        );
    }

    #[test]
    fn family_store_complete_and_family_structured() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic_families(&cfg, 3);
        assert_eq!(s.expert_count(), cfg.total_experts());
        assert!(s.tensor("L0.wg").is_ok());
        // Same-family experts are closer in weight space than cross-family.
        let flat = |e: usize| s.expert_flat(ExpertKey::new(0, e)).unwrap();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let (e0, e1, ex) = (flat(0), flat(1), flat(cfg.family_size));
        assert!(
            dist(&e0, &e1) < dist(&e0, &ex),
            "family members must be nearer than strangers"
        );
    }

    #[test]
    fn missing_expert_errors() {
        let cfg = ModelConfig::test_tiny();
        let s = WeightStore::synthetic(&cfg, 1);
        assert!(s.expert(ExpertKey::new(99, 0)).is_err());
    }
}
