//! BMW weight-bundle reader/writer. Layout (little-endian):
//!
//! ```text
//! magic  4B  b"BMW1"
//! count  u32
//! per tensor: name_len u16, name utf8, ndim u8, dims u32*ndim, data f32*n
//! ```

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tensor::Tensor;

const MAGIC: &[u8; 4] = b"BMW1";

pub fn read_bmw(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad BMW magic {:?}", magic);
    }
    let count = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let ndim = read_u8(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::new(dims, data)?);
    }
    Ok(out)
}

pub fn write_bmw(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u16).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[t.dims.len() as u8])?;
        for &d in &t.dims {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bmw_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bmw");
        let mut m = BTreeMap::new();
        m.insert(
            "a.b".to_string(),
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        m.insert("c".to_string(), Tensor::new(vec![4], vec![0.5; 4]).unwrap());
        write_bmw(&path, &m).unwrap();
        let back = read_bmw(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["a.b"], m["a.b"]);
        assert_eq!(back["c"], m["c"]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bmw_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bmw");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(read_bmw(&path).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(read_bmw(Path::new("/nonexistent/x.bmw")).is_err());
    }
}
