//! The transfer engine: serializes CPU->GPU expert movement over each
//! device's simulated host link, in either of the two [`SimClock`] modes.
//!
//! Since the multi-device topology PR the engine models an expert-parallel
//! fleet: every simulated GPU owns its own [`ExpertCache`] and its own
//! serialized host link ([`PcieSim`]), and a [`Placement`] routes each
//! expert's transfers to its home device. Links are independent — two
//! devices fetch concurrently — while transfers on one link serialize
//! exactly as before. A shared peer-interconnect cost model
//! (`EngineState::peer`) charges cross-device activation hops (the ψ/κ
//! story, see [`crate::topology`]). With one device the behavior is
//! byte-identical to the original single-cache engine.
//!
//! Two priority classes share each link: **demand** loads (synchronous
//! misses — the pipeline is stalled on them) always preempt **prefetch**
//! loads (speculative). Completed transfers flip the cache slot to `Gpu`
//! and stage the host weights in an arrivals list the engine layer drains
//! to create device buffers.
//!
//! * **Virtual clock** — transfers are discrete events. A request enqueues
//!   with its (virtual) arrival time; each link starts its next transfer
//!   the moment it frees (demand first among requests that have arrived by
//!   then), and completion advances nothing by itself — completions become
//!   visible when the clock reaches their ready time. A synchronous
//!   `wait_gpu` *advances the clock* to the stalled transfer's completion.
//!   No thread is spawned and nothing sleeps, so a full table sweep runs in
//!   milliseconds and is bit-for-bit deterministic, while the
//!   link-serialization and preemption semantics match the threaded
//!   engine's exactly.
//! * **Real-time clock** — one background thread per device pops requests
//!   and sleeps for each simulated duration, so downstream latency numbers
//!   are genuine elapsed-time measurements.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::memory::cache::{ExpertCache, LoadDecision, SlotState};
use crate::memory::pcie::{PcieSim, PcieStats};
use crate::topology::Placement;
use crate::util::clock::SimClock;
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferPriority {
    Demand,
    Prefetch,
}

/// A queued (not yet started) transfer request.
#[derive(Debug, Clone, Copy)]
struct Queued {
    key: ExpertKey,
    /// Virtual time the request was made; a transfer can never start
    /// before it was requested.
    enqueued_at: Duration,
}

/// A transfer occupying a link. Its PCIe traffic is recorded at start;
/// completion only flips cache state and stages the arrival. (Real-time
/// mode uses this as an in-progress marker with `ready_at` unused.)
#[derive(Debug, Clone, Copy)]
struct InFlight {
    key: ExpertKey,
    ready_at: Duration,
}

/// One simulated GPU: its expert cache plus its own serialized host link.
pub struct DeviceState {
    pub cache: ExpertCache,
    pub pcie: PcieSim,
    demand_q: VecDeque<Queued>,
    prefetch_q: VecDeque<Queued>,
    in_flight: Vec<InFlight>,
    /// Virtual time at which this link finishes its current work.
    link_free_at: Duration,
}

impl DeviceState {
    fn new(cache: ExpertCache, pcie: PcieSim) -> Self {
        Self {
            cache,
            pcie,
            demand_q: VecDeque::new(),
            prefetch_q: VecDeque::new(),
            in_flight: Vec::new(),
            link_free_at: Duration::ZERO,
        }
    }

    fn has_transfer(&self, key: ExpertKey) -> bool {
        self.demand_q.iter().any(|q| q.key == key)
            || self.prefetch_q.iter().any(|q| q.key == key)
            || self.in_flight.iter().any(|t| t.key == key)
    }
}

/// Per-device caches + links, the expert→device map, the shared peer
/// interconnect, and arrival/eviction mailboxes, all behind one mutex.
/// Arrivals carry [`ExpertWeights`] by `Arc` — staging a completed
/// transfer is a pointer move, not a weight copy (the simulated link
/// already charged the PCIe time for the bytes).
pub struct EngineState {
    pub devices: Vec<DeviceState>,
    pub placement: Placement,
    /// Peer (GPU↔GPU) interconnect cost model + traffic stats. Only
    /// touched by cross-device dispatches, so it stays all-zero in the
    /// single-device configuration.
    pub peer: PcieSim,
    pub arrivals: Vec<(ExpertKey, ExpertWeights)>,
    pub evictions: Vec<ExpertKey>,
    shutdown: bool,
}

impl EngineState {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Home device of an expert (where it is cached and executed).
    pub fn home(&self, key: ExpertKey) -> usize {
        self.placement.device_of(key)
    }

    /// The cache responsible for `key`.
    pub fn cache(&self, key: ExpertKey) -> &ExpertCache {
        &self.devices[self.home(key)].cache
    }

    pub fn cache_mut(&mut self, key: ExpertKey) -> &mut ExpertCache {
        let d = self.home(key);
        &mut self.devices[d].cache
    }

    /// Resident on its home device (= resident on *some* device, since an
    /// expert is only ever admitted at home).
    pub fn is_gpu(&self, key: ExpertKey) -> bool {
        self.cache(key).is_gpu(key)
    }

    pub fn mark_use(&mut self, key: ExpertKey) {
        self.cache_mut(key).mark_use(key);
    }

    pub fn pin(&mut self, key: ExpertKey) {
        self.cache_mut(key).pin(key);
    }

    pub fn unpin(&mut self, key: ExpertKey) {
        self.cache_mut(key).unpin(key);
    }

    pub fn admit(&mut self, key: ExpertKey) -> anyhow::Result<()> {
        self.cache_mut(key).admit(key)
    }

    pub fn demote(&mut self, key: ExpertKey) -> bool {
        self.cache_mut(key).demote(key)
    }

    /// Residency mask for one layer across the whole fleet (Algorithm 1's
    /// M): expert `e` is resident iff it is GPU-resident on its home
    /// device.
    pub fn residency_mask(&self, layer: usize) -> Vec<bool> {
        (0..self.placement.n_experts())
            .map(|e| self.is_gpu(ExpertKey::new(layer, e)))
            .collect()
    }

    /// Host-link traffic summed over every device (the fleet-wide view the
    /// reports consume; identical to the single link's stats when
    /// `n_devices == 1`).
    pub fn pcie_stats(&self) -> PcieStats {
        let mut total = PcieStats::default();
        for d in &self.devices {
            total.accumulate(&d.pcie.stats);
        }
        total
    }

    fn has_transfer(&self, key: ExpertKey) -> bool {
        self.devices[self.home(key)].has_transfer(key)
    }
}

pub struct Inner {
    state: Mutex<EngineState>,
    cv: Condvar,
}

pub type SharedCache = Arc<Inner>;

pub struct TransferEngine;

/// Handle owned by the serving engine; cloneable for the prefetcher.
#[derive(Clone)]
pub struct TransferHandle {
    inner: SharedCache,
    clock: SimClock,
    store: Arc<WeightStore>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// When will this link start its next queued transfer, and is it a demand?
///
/// The link frees at `link_free_at`; the next transfer starts at
/// `max(link_free_at, earliest enqueue among queue fronts)`. At that
/// instant a demand wins if it has arrived by then — exactly the threaded
/// engine's "pop demand first" rule at the moment the thread frees.
fn next_start(dev: &DeviceState) -> Option<(Duration, bool)> {
    let d = dev.demand_q.front().map(|q| q.enqueued_at);
    let p = dev.prefetch_q.front().map(|q| q.enqueued_at);
    let earliest = match (d, p) {
        (None, None) => return None,
        (Some(a), None) => a,
        (None, Some(b)) => b,
        (Some(a), Some(b)) => a.min(b),
    };
    let start = dev.link_free_at.max(earliest);
    let demand_first = d.map(|t| t <= start).unwrap_or(false);
    Some((start, demand_first))
}

/// Advance one device's virtual link state to `now`: start every transfer
/// whose start time has been reached (recording its PCIe traffic — the
/// link is committed the moment a transfer starts, and recording at start
/// keeps virtual and real-time stats in agreement even for transfers still
/// in flight when a run ends), and complete every transfer whose ready
/// time has passed (flipping the cache slot and staging arrivals).
fn settle_device(
    dev: &mut DeviceState,
    store: &WeightStore,
    now: Duration,
    arrivals: &mut Vec<(ExpertKey, ExpertWeights)>,
) {
    loop {
        let Some((start, demand_first)) = next_start(dev) else { break };
        if start > now {
            break;
        }
        let key = if demand_first {
            dev.demand_q.pop_front().unwrap().key
        } else {
            dev.prefetch_q.pop_front().unwrap().key
        };
        let dur = dev.pcie.transfer_duration(store.expert_bytes);
        let ready = start + dur;
        dev.link_free_at = ready;
        dev.pcie.record(store.expert_bytes, !demand_first);
        dev.in_flight.push(InFlight { key, ready_at: ready });
    }
    let mut i = 0;
    while i < dev.in_flight.len() {
        if dev.in_flight[i].ready_at <= now {
            let t = dev.in_flight.remove(i);
            dev.cache.complete_load(t.key);
            let w = store.expert(t.key).expect("transfer for unknown expert");
            arrivals.push((t.key, w));
        } else {
            i += 1;
        }
    }
}

/// Settle every device's link to `now`. Links are independent: each one
/// serializes its own transfers but never blocks another's.
fn settle(st: &mut EngineState, store: &WeightStore, now: Duration) {
    let EngineState { devices, arrivals, .. } = st;
    for dev in devices.iter_mut() {
        settle_device(dev, store, now, arrivals);
    }
}

/// The next virtual instant at which a transfer completes on this link
/// (in-flight first; otherwise the next queued transfer's start +
/// duration).
fn next_event(dev: &DeviceState, expert_bytes: usize) -> Option<Duration> {
    if let Some(t) = dev.in_flight.iter().map(|t| t.ready_at).min() {
        return Some(t);
    }
    next_start(dev).map(|(start, _)| start + dev.pcie.transfer_duration(expert_bytes))
}

/// The satellite fix for the request/wait race: the awaited expert's
/// transfer can vanish between `request` and `wait_gpu` (e.g. the prefetch
/// verification step cancelled it, which also aborted the `Loading` slot).
/// Re-issue the load at demand priority instead of panicking.
fn reissue_demand(st: &mut EngineState, key: ExpertKey, now: Duration) {
    if st.cache(key).state(key) == SlotState::Loading {
        // Orphaned Loading slot with no backing transfer: reset it so
        // request_load can restart the state machine.
        st.cache_mut(key).abort_load(key);
    }
    match st.cache_mut(key).request_load(key) {
        LoadDecision::StartLoad { evicted } => {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            let dev = st.home(key);
            st.devices[dev].demand_q.push_back(Queued { key, enqueued_at: now });
        }
        LoadDecision::AlreadyGpu => {}
        LoadDecision::AlreadyLoading => unreachable!("orphaned Loading slot was just reset"),
        LoadDecision::NoRoom => panic!(
            "wait_gpu({key:?}): transfer lost and every slot in the layer is pinned"
        ),
    }
}

impl TransferEngine {
    /// Single-device convenience: the degenerate one-GPU fleet (all
    /// experts homed on device 0). Byte-identical to the pre-topology
    /// engine.
    pub fn spawn(
        cache: ExpertCache,
        pcie: PcieSim,
        store: Arc<WeightStore>,
        clock: SimClock,
    ) -> TransferHandle {
        let placement = Placement::single(cache.n_layers(), cache.n_experts());
        // The peer link of a one-GPU fleet carries no traffic; use the
        // serving-config default cost model rather than duplicating its
        // constants here.
        let dflt = crate::config::ServingConfig::default();
        let peer = PcieSim::new(dflt.peer_bandwidth, dflt.peer_base_latency, 1.0);
        Self::spawn_multi(vec![(cache, pcie)], peer, placement, store, clock)
    }

    /// Build the engine for an expert-parallel fleet: one (cache, host
    /// link) pair per device, a peer-interconnect cost model, and the
    /// expert→device placement. With a virtual clock this spawns no
    /// thread — transfers are simulated events; with a real-time clock one
    /// background thread per device sleeps for each simulated transfer
    /// duration.
    pub fn spawn_multi(
        devices: Vec<(ExpertCache, PcieSim)>,
        peer: PcieSim,
        placement: Placement,
        store: Arc<WeightStore>,
        clock: SimClock,
    ) -> TransferHandle {
        assert!(!devices.is_empty(), "need at least one device");
        assert_eq!(
            devices.len(),
            placement.n_devices(),
            "placement device count must match the fleet"
        );
        let n_devices = devices.len();
        let inner = Arc::new(Inner {
            state: Mutex::new(EngineState {
                devices: devices
                    .into_iter()
                    .map(|(cache, pcie)| DeviceState::new(cache, pcie))
                    .collect(),
                placement,
                peer,
                arrivals: Vec::new(),
                evictions: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let threads = if clock.is_virtual() {
            Vec::new()
        } else {
            (0..n_devices)
                .map(|dev| {
                    let inner2 = inner.clone();
                    let store2 = store.clone();
                    std::thread::Builder::new()
                        .name(format!("pcie-transfer-{dev}"))
                        .spawn(move || Self::run(inner2, store2, dev))
                        .expect("spawn transfer engine")
                })
                .collect()
        };
        TransferHandle { inner, clock, store, threads: Arc::new(Mutex::new(threads)) }
    }

    /// Real-time worker loop for one device: pop (demand first), sleep the
    /// simulated duration, complete. The in-flight marker keeps
    /// `wait_gpu`'s lost-transfer detection honest while the thread
    /// sleeps outside the lock.
    fn run(inner: SharedCache, store: Arc<WeightStore>, dev: usize) {
        loop {
            let (key, duration) = {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    let d = &mut st.devices[dev];
                    if let Some(q) = d.demand_q.pop_front() {
                        let dur = d.pcie.transfer_duration(store.expert_bytes);
                        // Record at transfer start (matches virtual mode).
                        d.pcie.record(store.expert_bytes, false);
                        d.in_flight.push(InFlight { key: q.key, ready_at: Duration::ZERO });
                        break (q.key, dur);
                    }
                    if let Some(q) = d.prefetch_q.pop_front() {
                        let dur = d.pcie.transfer_duration(store.expert_bytes);
                        d.pcie.record(store.expert_bytes, true);
                        d.in_flight.push(InFlight { key: q.key, ready_at: Duration::ZERO });
                        break (q.key, dur);
                    }
                    st = inner.cv.wait(st).unwrap();
                }
            };
            // Occupy the link in real time (lock released).
            std::thread::sleep(duration);
            let weights = store.expert(key).expect("transfer for unknown expert");
            let mut st = inner.state.lock().unwrap();
            let d = &mut st.devices[dev];
            if let Some(pos) = d.in_flight.iter().position(|t| t.key == key) {
                d.in_flight.remove(pos);
            }
            d.cache.complete_load(key);
            st.arrivals.push((key, weights));
            inner.cv.notify_all();
        }
    }
}

impl TransferHandle {
    /// Lock the shared state, first settling the virtual event queues up
    /// to the current virtual time so callers always observe a consistent
    /// "present".
    fn lock_settled(&self) -> MutexGuard<'_, EngineState> {
        let mut st = self.inner.state.lock().unwrap();
        if self.clock.is_virtual() {
            settle(&mut st, &self.store, self.clock.now());
        }
        st
    }

    /// The clock this engine runs on.
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Run a closure with exclusive access to the fleet state.
    pub fn with_state<R>(&self, f: impl FnOnce(&mut EngineState) -> R) -> R {
        let mut st = self.lock_settled();
        f(&mut st)
    }

    /// Request that `key` be brought onto its home device. Returns the
    /// cache decision; enqueues a transfer on the home link (and records
    /// any eviction) when a load starts.
    pub fn request(&self, key: ExpertKey, prio: TransferPriority) -> LoadDecision {
        let mut st = self.lock_settled();
        let decision = st.cache_mut(key).request_load(key);
        if let LoadDecision::StartLoad { evicted } = decision {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            let dev = st.home(key);
            let q = Queued { key, enqueued_at: self.clock.now() };
            match prio {
                TransferPriority::Demand => st.devices[dev].demand_q.push_back(q),
                TransferPriority::Prefetch => st.devices[dev].prefetch_q.push_back(q),
            }
            if self.clock.is_virtual() {
                // The link may be idle: the transfer starts this instant.
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
        decision
    }

    /// Escalate a still-queued prefetch to demand priority (the
    /// verification step of the prefetch pipeline, Fig 3). Transfers that
    /// already started keep their class.
    pub fn escalate(&self, key: ExpertKey) {
        let mut st = self.lock_settled();
        let dev = st.home(key);
        if let Some(pos) = st.devices[dev].prefetch_q.iter().position(|q| q.key == key) {
            let q = st.devices[dev].prefetch_q.remove(pos).unwrap();
            st.devices[dev].demand_q.push_back(q);
            if self.clock.is_virtual() {
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
    }

    /// Cancel a still-queued (not yet started) prefetch: the verification
    /// step discovered it is not needed. Returns true if it was dequeued.
    /// Saves PCIe occupancy that would otherwise serve speculative waste.
    pub fn cancel_prefetch(&self, key: ExpertKey) -> bool {
        let mut st = self.lock_settled();
        let dev = st.home(key);
        if let Some(pos) = st.devices[dev].prefetch_q.iter().position(|q| q.key == key) {
            st.devices[dev].prefetch_q.remove(pos);
            st.cache_mut(key).abort_load(key);
            true
        } else {
            false
        }
    }

    /// Block until `key` is resident on its home device (the synchronous
    /// miss stall). Under a virtual clock this advances the clock to the
    /// transfer's completion instant — the stall costs virtual, not real,
    /// time. If the awaited transfer vanished (request/wait race with a
    /// cancellation), the load is re-issued at demand priority.
    pub fn wait_gpu(&self, key: ExpertKey) {
        if self.clock.is_virtual() {
            let mut st = self.inner.state.lock().unwrap();
            loop {
                settle(&mut st, &self.store, self.clock.now());
                if st.is_gpu(key) {
                    return;
                }
                if !st.has_transfer(key) {
                    reissue_demand(&mut st, key, self.clock.now());
                    continue;
                }
                let dev = st.home(key);
                let t = next_event(&st.devices[dev], self.store.expert_bytes)
                    .expect("pending transfer implies a next link event");
                self.clock.advance_to(t);
            }
        } else {
            let mut st = self.inner.state.lock().unwrap();
            while !st.is_gpu(key) {
                if !st.has_transfer(key) {
                    reissue_demand(&mut st, key, self.clock.now());
                    self.inner.cv.notify_all();
                }
                st = self.inner.cv.wait(st).unwrap();
            }
        }
    }

    /// A transient (uncached) fetch on `key`'s home link: pays the PCIe
    /// time — virtual advance or real sleep — and records demand traffic,
    /// without touching the cache. Returns the simulated duration.
    pub fn transient_fetch_for(&self, key: ExpertKey, bytes: usize) -> Duration {
        let (dev, dur) = {
            let st = self.lock_settled();
            let dev = st.home(key);
            (dev, st.devices[dev].pcie.transfer_duration(bytes))
        };
        self.clock.sleep(dur);
        let mut st = self.lock_settled();
        st.devices[dev].pcie.record(bytes, false);
        dur
    }

    /// Transient fetch on device 0 (single-device call sites).
    pub fn transient_fetch(&self, bytes: usize) -> Duration {
        self.transient_fetch_for(ExpertKey::new(0, 0), bytes)
    }

    /// Charge `hops` peer-link crossings of `bytes` each (the activation
    /// round trip of dispatching a token to a cross-device substitute):
    /// advances the clock by the peer time and records the traffic on the
    /// shared peer interconnect. Returns the total simulated duration.
    pub fn peer_dispatch(&self, bytes: usize, hops: usize) -> Duration {
        if hops == 0 {
            return Duration::ZERO;
        }
        let dur = {
            let st = self.lock_settled();
            st.peer.transfer_duration(bytes) * hops as u32
        };
        self.clock.sleep(dur);
        let mut st = self.lock_settled();
        st.peer.record(bytes.saturating_mul(hops), false);
        dur
    }

    /// Drain completed transfers (engine layer creates device buffers).
    pub fn drain_arrivals(&self) -> Vec<(ExpertKey, ExpertWeights)> {
        std::mem::take(&mut self.lock_settled().arrivals)
    }

    /// Drain evicted experts (engine layer drops device buffers).
    pub fn drain_evictions(&self) -> Vec<ExpertKey> {
        std::mem::take(&mut self.lock_settled().evictions)
    }

    /// Number of queued (not yet started) transfers across every link.
    pub fn queue_depth(&self) -> (usize, usize) {
        let st = self.lock_settled();
        st.devices
            .iter()
            .fold((0, 0), |(d, p), dev| (d + dev.demand_q.len(), p + dev.prefetch_q.len()))
    }

    pub fn shutdown(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.cv.notify_all();
        }
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::cache::EvictPolicy;
    use crate::topology::PlacementKind;

    fn setup(cap: usize) -> (TransferHandle, SimClock) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, cap, EvictPolicy::Lru);
        let pcie = PcieSim::new(16e9, 1e-6, 1.0);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        (h, clock)
    }

    #[test]
    fn demand_load_completes() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(0, 2);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        h.wait_gpu(k);
        assert!(h.with_state(|st| st.is_gpu(k)));
        let arr = h.drain_arrivals();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].0, k);
        h.shutdown();
    }

    #[test]
    fn stats_recorded_per_class() {
        let (h, _) = setup(4);
        h.request(ExpertKey::new(0, 0), TransferPriority::Demand);
        h.request(ExpertKey::new(0, 1), TransferPriority::Prefetch);
        h.wait_gpu(ExpertKey::new(0, 0));
        h.wait_gpu(ExpertKey::new(0, 1));
        let (d, p) = h.with_state(|st| {
            let s = st.pcie_stats();
            (s.demand_transfers, s.prefetch_transfers)
        });
        assert_eq!((d, p), (1, 1));
        h.shutdown();
    }

    #[test]
    fn eviction_reported() {
        let (h, _) = setup(1);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.wait_gpu(a);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(b);
        let ev = h.drain_evictions();
        assert_eq!(ev, vec![a]);
        h.shutdown();
    }

    #[test]
    fn duplicate_requests_coalesce() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(1, 3);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        // Second request while loading (or already loaded) never double-queues.
        let d2 = h.request(k, TransferPriority::Demand);
        assert!(matches!(
            d2,
            LoadDecision::AlreadyLoading | LoadDecision::AlreadyGpu
        ));
        h.wait_gpu(k);
        assert_eq!(h.drain_arrivals().len(), 1);
        h.shutdown();
    }

    #[test]
    fn escalate_moves_queue() {
        let (h, _) = setup(8);
        // Saturate with prefetches, then escalate the last one.
        for e in 0..4 {
            h.request(ExpertKey::new(2, e), TransferPriority::Prefetch);
        }
        h.escalate(ExpertKey::new(2, 3));
        h.wait_gpu(ExpertKey::new(2, 3));
        h.shutdown();
    }

    #[test]
    fn shutdown_idempotent() {
        let (h, _) = setup(2);
        h.shutdown();
        h.shutdown();
    }

    #[test]
    fn virtual_stall_advances_clock_not_wall_time() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 6144 bytes/expert * 1e6 scale / 1e9 B/s ~= 6.1ms per transfer.
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let k = ExpertKey::new(0, 0);
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        h.wait_gpu(k);
        assert!(
            clock.now().as_secs_f64() > 0.006,
            "virtual clock must advance by the transfer duration"
        );
        assert!(
            t0.elapsed().as_secs_f64() < 0.005,
            "virtual stall must not consume wall time"
        );
        h.shutdown();
    }

    #[test]
    fn virtual_link_serializes_transfers() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(a);
        assert_eq!(clock.now(), dur, "first transfer completes after one duration");
        h.wait_gpu(b);
        assert_eq!(clock.now(), dur * 2, "second transfer waits for the link");
        h.shutdown();
    }

    #[test]
    fn virtual_demand_preempts_queued_prefetches() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 8, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        // First prefetch occupies the link immediately; two more queue up.
        for e in 0..3 {
            h.request(ExpertKey::new(0, e), TransferPriority::Prefetch);
        }
        let d = ExpertKey::new(0, 7);
        h.request(d, TransferPriority::Demand);
        h.wait_gpu(d);
        // The demand ran right after the in-flight prefetch, jumping the
        // two still-queued prefetches: 2 transfers total. By the demand's
        // completion instant the link has picked up the next prefetch, so
        // exactly one remains queued.
        assert_eq!(clock.now(), dur * 2);
        let (dq, pq) = h.queue_depth();
        assert_eq!((dq, pq), (0, 1), "one prefetch in flight, one still queued");
        h.shutdown();
    }

    #[test]
    fn real_time_mode_still_sleeps() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 2 ms base latency dominates: measurable but far under the
        // test-suite real-sleep budget.
        let pcie = PcieSim::new(1e9, 2e-3, 1.0);
        let h = TransferEngine::spawn(cache, pcie, store, SimClock::real_time());
        let k = ExpertKey::new(0, 0);
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        h.wait_gpu(k);
        assert!(t0.elapsed().as_secs_f64() > 0.0015, "stall must be real");
        h.shutdown();
    }

    #[test]
    fn transient_fetch_costs_virtual_time() {
        let (h, clock) = setup(2);
        let t0 = clock.now();
        let dur = h.transient_fetch(1 << 20);
        assert!(dur > Duration::ZERO);
        assert_eq!(clock.now() - t0, dur);
        assert_eq!(h.with_state(|st| st.pcie_stats().demand_transfers), 1);
        h.shutdown();
    }

    #[test]
    fn wait_gpu_reissues_lost_transfer() {
        // Regression: wait_gpu used to panic when the awaited expert had
        // no queued or in-flight transfer (request/wait racing a
        // cancellation). It must re-issue at demand priority instead.
        let (h, _) = setup(4);
        let busy = ExpertKey::new(0, 0);
        let k = ExpertKey::new(0, 2);
        // Occupy the link so the prefetch for `k` stays queued...
        h.request(busy, TransferPriority::Demand);
        h.request(k, TransferPriority::Prefetch);
        // ...then cancel it: the transfer vanishes, the slot returns to Cpu.
        assert!(h.cancel_prefetch(k));
        h.wait_gpu(k); // panicked before the fix
        assert!(h.with_state(|st| st.is_gpu(k)));
        h.shutdown();
    }

    fn multi_setup(n_devices: usize) -> (TransferHandle, SimClock, Duration) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let devices: Vec<(ExpertCache, PcieSim)> = (0..n_devices)
            .map(|_| {
                (
                    ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru),
                    pcie.clone(),
                )
            })
            .collect();
        let placement = Placement::build(
            PlacementKind::LayerStriped,
            cfg.n_layers,
            cfg.n_experts,
            n_devices,
            None,
        );
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn_multi(
            devices,
            PcieSim::new(64e9, 3e-6, 1.0),
            placement,
            store,
            clock.clone(),
        );
        (h, clock, dur)
    }

    #[test]
    fn per_device_links_transfer_in_parallel() {
        // Layer 0, experts 0 and 1 live on different striped devices: both
        // demand loads run concurrently on their own host links, so both
        // complete after ONE transfer duration (a single shared link would
        // serialize them to 2x — see virtual_link_serializes_transfers).
        let (h, clock, dur) = multi_setup(2);
        let a = ExpertKey::new(0, 0); // device 0
        let b = ExpertKey::new(0, 1); // device 1
        assert_eq!(h.with_state(|st| (st.home(a), st.home(b))), (0, 1));
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(a);
        h.wait_gpu(b);
        assert_eq!(clock.now(), dur, "independent links must not serialize");
        assert!(h.with_state(|st| st.is_gpu(a) && st.is_gpu(b)));
        // Fleet-wide stats aggregate both links.
        assert_eq!(h.with_state(|st| st.pcie_stats().demand_transfers), 2);
        h.shutdown();
    }

    #[test]
    fn same_device_transfers_still_serialize() {
        // Experts 0 and 2 both live on device 0 under 2-way striping.
        let (h, clock, dur) = multi_setup(2);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 2);
        assert_eq!(h.with_state(|st| (st.home(a), st.home(b))), (0, 0));
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(b);
        assert_eq!(clock.now(), dur * 2, "one link still serializes");
        h.shutdown();
    }

    #[test]
    fn peer_dispatch_costs_time_and_records_traffic() {
        let (h, clock, _) = multi_setup(2);
        let t0 = clock.now();
        let d0 = h.peer_dispatch(4096, 0);
        assert_eq!(d0, Duration::ZERO, "zero hops are free");
        let d2 = h.peer_dispatch(4096, 2);
        assert!(d2 > Duration::ZERO);
        assert_eq!(clock.now() - t0, d2);
        let (bytes, transfers) =
            h.with_state(|st| (st.peer.stats.demand_bytes, st.peer.stats.demand_transfers));
        assert_eq!(bytes, 8192, "two hops carry the bytes twice");
        assert_eq!(transfers, 1);
        h.shutdown();
    }
}
