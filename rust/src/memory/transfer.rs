//! The transfer engine: serializes CPU->GPU expert movement over the
//! simulated PCIe link, in either of the two [`SimClock`] modes.
//!
//! Two priority classes share the link: **demand** loads (synchronous
//! misses — the pipeline is stalled on them) always preempt **prefetch**
//! loads (speculative). Completed transfers flip the cache slot to `Gpu`
//! and stage the host weights in an arrivals list the engine layer drains
//! to create device buffers.
//!
//! * **Virtual clock** — transfers are discrete events. A request enqueues
//!   with its (virtual) arrival time; the link starts the next transfer the
//!   moment it frees (demand first among requests that have arrived by
//!   then), and completion advances nothing by itself — completions become
//!   visible when the clock reaches their ready time. A synchronous
//!   `wait_gpu` *advances the clock* to the stalled transfer's completion.
//!   No thread is spawned and nothing sleeps, so a full table sweep runs in
//!   milliseconds and is bit-for-bit deterministic, while the
//!   link-serialization and preemption semantics match the threaded
//!   engine's exactly.
//! * **Real-time clock** — a background thread pops requests and sleeps for
//!   each simulated duration, so downstream latency numbers are genuine
//!   elapsed-time measurements.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::memory::cache::{ExpertCache, LoadDecision};
use crate::memory::pcie::PcieSim;
use crate::util::clock::SimClock;
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferPriority {
    Demand,
    Prefetch,
}

/// A queued (not yet started) transfer request.
#[derive(Debug, Clone, Copy)]
struct Queued {
    key: ExpertKey,
    /// Virtual time the request was made; a transfer can never start
    /// before it was requested.
    enqueued_at: Duration,
}

/// A transfer occupying the link (virtual mode only). Its PCIe traffic is
/// recorded at start; completion only flips cache state and stages the
/// arrival.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    key: ExpertKey,
    ready_at: Duration,
}

/// Cache + link + arrival/eviction mailboxes, all behind one mutex.
/// Arrivals carry [`ExpertWeights`] by `Arc` — staging a completed
/// transfer is a pointer move, not a weight copy (the simulated link
/// already charged the PCIe time for the bytes).
pub struct EngineState {
    pub cache: ExpertCache,
    pub pcie: PcieSim,
    pub arrivals: Vec<(ExpertKey, ExpertWeights)>,
    pub evictions: Vec<ExpertKey>,
    demand_q: VecDeque<Queued>,
    prefetch_q: VecDeque<Queued>,
    in_flight: Vec<InFlight>,
    /// Virtual time at which the link finishes its current work.
    link_free_at: Duration,
    shutdown: bool,
}

pub struct Inner {
    state: Mutex<EngineState>,
    cv: Condvar,
}

pub type SharedCache = Arc<Inner>;

pub struct TransferEngine;

/// Handle owned by the serving engine; cloneable for the prefetcher.
#[derive(Clone)]
pub struct TransferHandle {
    inner: SharedCache,
    clock: SimClock,
    store: Arc<WeightStore>,
    thread: Arc<Mutex<Option<JoinHandle<()>>>>,
}

/// When will the link start its next queued transfer, and is it a demand?
///
/// The link frees at `link_free_at`; the next transfer starts at
/// `max(link_free_at, earliest enqueue among queue fronts)`. At that
/// instant a demand wins if it has arrived by then — exactly the threaded
/// engine's "pop demand first" rule at the moment the thread frees.
fn next_start(st: &EngineState) -> Option<(Duration, bool)> {
    let d = st.demand_q.front().map(|q| q.enqueued_at);
    let p = st.prefetch_q.front().map(|q| q.enqueued_at);
    let earliest = match (d, p) {
        (None, None) => return None,
        (Some(a), None) => a,
        (None, Some(b)) => b,
        (Some(a), Some(b)) => a.min(b),
    };
    let start = st.link_free_at.max(earliest);
    let demand_first = d.map(|t| t <= start).unwrap_or(false);
    Some((start, demand_first))
}

/// Advance the virtual link state to `now`: start every transfer whose
/// start time has been reached (recording its PCIe traffic — the link is
/// committed the moment a transfer starts, and recording at start keeps
/// virtual and real-time stats in agreement even for transfers still in
/// flight when a run ends), and complete every transfer whose ready time
/// has passed (flipping the cache slot and staging arrivals).
fn settle(st: &mut EngineState, store: &WeightStore, now: Duration) {
    loop {
        let Some((start, demand_first)) = next_start(st) else { break };
        if start > now {
            break;
        }
        let key = if demand_first {
            st.demand_q.pop_front().unwrap().key
        } else {
            st.prefetch_q.pop_front().unwrap().key
        };
        let dur = st.pcie.transfer_duration(store.expert_bytes);
        let ready = start + dur;
        st.link_free_at = ready;
        st.pcie.record(store.expert_bytes, !demand_first);
        st.in_flight.push(InFlight { key, ready_at: ready });
    }
    let mut i = 0;
    while i < st.in_flight.len() {
        if st.in_flight[i].ready_at <= now {
            let t = st.in_flight.remove(i);
            st.cache.complete_load(t.key);
            let w = store.expert(t.key).expect("transfer for unknown expert");
            st.arrivals.push((t.key, w));
        } else {
            i += 1;
        }
    }
}

/// The next virtual instant at which a transfer completes (in-flight
/// first; otherwise the next queued transfer's start + duration).
fn next_event(st: &EngineState, expert_bytes: usize) -> Option<Duration> {
    if let Some(t) = st.in_flight.iter().map(|t| t.ready_at).min() {
        return Some(t);
    }
    next_start(st).map(|(start, _)| start + st.pcie.transfer_duration(expert_bytes))
}

impl TransferEngine {
    /// Build the engine on `clock`. With a virtual clock this spawns no
    /// thread — transfers are simulated events; with a real-time clock a
    /// background thread sleeps for each simulated transfer duration.
    pub fn spawn(
        cache: ExpertCache,
        pcie: PcieSim,
        store: Arc<WeightStore>,
        clock: SimClock,
    ) -> TransferHandle {
        let inner = Arc::new(Inner {
            state: Mutex::new(EngineState {
                cache,
                pcie,
                arrivals: Vec::new(),
                evictions: Vec::new(),
                demand_q: VecDeque::new(),
                prefetch_q: VecDeque::new(),
                in_flight: Vec::new(),
                link_free_at: Duration::ZERO,
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let thread = if clock.is_virtual() {
            None
        } else {
            let inner2 = inner.clone();
            let store2 = store.clone();
            Some(
                std::thread::Builder::new()
                    .name("pcie-transfer".into())
                    .spawn(move || Self::run(inner2, store2))
                    .expect("spawn transfer engine"),
            )
        };
        TransferHandle { inner, clock, store, thread: Arc::new(Mutex::new(thread)) }
    }

    /// Real-time worker loop: pop (demand first), sleep the simulated
    /// duration, complete.
    fn run(inner: SharedCache, store: Arc<WeightStore>) {
        loop {
            let (key, duration) = {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if let Some(q) = st.demand_q.pop_front() {
                        let d = st.pcie.transfer_duration(store.expert_bytes);
                        // Record at transfer start (matches virtual mode).
                        st.pcie.record(store.expert_bytes, false);
                        break (q.key, d);
                    }
                    if let Some(q) = st.prefetch_q.pop_front() {
                        let d = st.pcie.transfer_duration(store.expert_bytes);
                        st.pcie.record(store.expert_bytes, true);
                        break (q.key, d);
                    }
                    st = inner.cv.wait(st).unwrap();
                }
            };
            // Occupy the link in real time (lock released).
            std::thread::sleep(duration);
            let weights = store.expert(key).expect("transfer for unknown expert");
            let mut st = inner.state.lock().unwrap();
            st.cache.complete_load(key);
            st.arrivals.push((key, weights));
            inner.cv.notify_all();
        }
    }
}

impl TransferHandle {
    /// Lock the shared state, first settling the virtual event queue up to
    /// the current virtual time so callers always observe a consistent
    /// "present".
    fn lock_settled(&self) -> MutexGuard<'_, EngineState> {
        let mut st = self.inner.state.lock().unwrap();
        if self.clock.is_virtual() {
            settle(&mut st, &self.store, self.clock.now());
        }
        st
    }

    /// The clock this engine runs on.
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Run a closure with exclusive access to cache + link state.
    pub fn with_state<R>(&self, f: impl FnOnce(&mut EngineState) -> R) -> R {
        let mut st = self.lock_settled();
        f(&mut st)
    }

    /// Request that `key` be brought to GPU. Returns the cache decision;
    /// enqueues a transfer (and records any eviction) when a load starts.
    pub fn request(&self, key: ExpertKey, prio: TransferPriority) -> LoadDecision {
        let mut st = self.lock_settled();
        let decision = st.cache.request_load(key);
        if let LoadDecision::StartLoad { evicted } = decision {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            let q = Queued { key, enqueued_at: self.clock.now() };
            match prio {
                TransferPriority::Demand => st.demand_q.push_back(q),
                TransferPriority::Prefetch => st.prefetch_q.push_back(q),
            }
            if self.clock.is_virtual() {
                // The link may be idle: the transfer starts this instant.
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
        decision
    }

    /// Escalate a still-queued prefetch to demand priority (the
    /// verification step of the prefetch pipeline, Fig 3). Transfers that
    /// already started keep their class.
    pub fn escalate(&self, key: ExpertKey) {
        let mut st = self.lock_settled();
        if let Some(pos) = st.prefetch_q.iter().position(|q| q.key == key) {
            let q = st.prefetch_q.remove(pos).unwrap();
            st.demand_q.push_back(q);
            if self.clock.is_virtual() {
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
    }

    /// Cancel a still-queued (not yet started) prefetch: the verification
    /// step discovered it is not needed. Returns true if it was dequeued.
    /// Saves PCIe occupancy that would otherwise serve speculative waste.
    pub fn cancel_prefetch(&self, key: ExpertKey) -> bool {
        let mut st = self.lock_settled();
        if let Some(pos) = st.prefetch_q.iter().position(|q| q.key == key) {
            st.prefetch_q.remove(pos);
            st.cache.abort_load(key);
            true
        } else {
            false
        }
    }

    /// Block until `key` is GPU-resident (the synchronous miss stall).
    /// Under a virtual clock this advances the clock to the transfer's
    /// completion instant — the stall costs virtual, not real, time.
    pub fn wait_gpu(&self, key: ExpertKey) {
        if self.clock.is_virtual() {
            let mut st = self.inner.state.lock().unwrap();
            loop {
                settle(&mut st, &self.store, self.clock.now());
                if st.cache.is_gpu(key) {
                    return;
                }
                let Some(t) = next_event(&st, self.store.expert_bytes) else {
                    panic!("wait_gpu({key:?}) with no queued or in-flight transfer");
                };
                self.clock.advance_to(t);
            }
        } else {
            let mut st = self.inner.state.lock().unwrap();
            while !st.cache.is_gpu(key) {
                st = self.inner.cv.wait(st).unwrap();
            }
        }
    }

    /// A transient (uncached) fetch: pays the PCIe time — virtual advance
    /// or real sleep — and records demand traffic, without touching the
    /// cache. Returns the simulated duration.
    pub fn transient_fetch(&self, bytes: usize) -> Duration {
        let dur = {
            let st = self.lock_settled();
            st.pcie.transfer_duration(bytes)
        };
        self.clock.sleep(dur);
        let mut st = self.lock_settled();
        st.pcie.record(bytes, false);
        dur
    }

    /// Drain completed transfers (engine layer creates device buffers).
    pub fn drain_arrivals(&self) -> Vec<(ExpertKey, ExpertWeights)> {
        std::mem::take(&mut self.lock_settled().arrivals)
    }

    /// Drain evicted experts (engine layer drops device buffers).
    pub fn drain_evictions(&self) -> Vec<ExpertKey> {
        std::mem::take(&mut self.lock_settled().evictions)
    }

    /// Number of queued (not yet started) transfers.
    pub fn queue_depth(&self) -> (usize, usize) {
        let st = self.lock_settled();
        (st.demand_q.len(), st.prefetch_q.len())
    }

    pub fn shutdown(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.cv.notify_all();
        }
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::cache::EvictPolicy;

    fn setup(cap: usize) -> (TransferHandle, SimClock) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, cap, EvictPolicy::Lru);
        let pcie = PcieSim::new(16e9, 1e-6, 1.0);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        (h, clock)
    }

    #[test]
    fn demand_load_completes() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(0, 2);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        h.wait_gpu(k);
        assert!(h.with_state(|st| st.cache.is_gpu(k)));
        let arr = h.drain_arrivals();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].0, k);
        h.shutdown();
    }

    #[test]
    fn stats_recorded_per_class() {
        let (h, _) = setup(4);
        h.request(ExpertKey::new(0, 0), TransferPriority::Demand);
        h.request(ExpertKey::new(0, 1), TransferPriority::Prefetch);
        h.wait_gpu(ExpertKey::new(0, 0));
        h.wait_gpu(ExpertKey::new(0, 1));
        let (d, p) = h.with_state(|st| {
            (st.pcie.stats.demand_transfers, st.pcie.stats.prefetch_transfers)
        });
        assert_eq!((d, p), (1, 1));
        h.shutdown();
    }

    #[test]
    fn eviction_reported() {
        let (h, _) = setup(1);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.wait_gpu(a);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(b);
        let ev = h.drain_evictions();
        assert_eq!(ev, vec![a]);
        h.shutdown();
    }

    #[test]
    fn duplicate_requests_coalesce() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(1, 3);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        // Second request while loading (or already loaded) never double-queues.
        let d2 = h.request(k, TransferPriority::Demand);
        assert!(matches!(
            d2,
            LoadDecision::AlreadyLoading | LoadDecision::AlreadyGpu
        ));
        h.wait_gpu(k);
        assert_eq!(h.drain_arrivals().len(), 1);
        h.shutdown();
    }

    #[test]
    fn escalate_moves_queue() {
        let (h, _) = setup(8);
        // Saturate with prefetches, then escalate the last one.
        for e in 0..4 {
            h.request(ExpertKey::new(2, e), TransferPriority::Prefetch);
        }
        h.escalate(ExpertKey::new(2, 3));
        h.wait_gpu(ExpertKey::new(2, 3));
        h.shutdown();
    }

    #[test]
    fn shutdown_idempotent() {
        let (h, _) = setup(2);
        h.shutdown();
        h.shutdown();
    }

    #[test]
    fn virtual_stall_advances_clock_not_wall_time() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 6144 bytes/expert * 1e6 scale / 1e9 B/s ~= 6.1ms per transfer.
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let k = ExpertKey::new(0, 0);
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        h.wait_gpu(k);
        assert!(
            clock.now().as_secs_f64() > 0.006,
            "virtual clock must advance by the transfer duration"
        );
        assert!(
            t0.elapsed().as_secs_f64() < 0.005,
            "virtual stall must not consume wall time"
        );
        h.shutdown();
    }

    #[test]
    fn virtual_link_serializes_transfers() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(a);
        assert_eq!(clock.now(), dur, "first transfer completes after one duration");
        h.wait_gpu(b);
        assert_eq!(clock.now(), dur * 2, "second transfer waits for the link");
        h.shutdown();
    }

    #[test]
    fn virtual_demand_preempts_queued_prefetches() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 8, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        // First prefetch occupies the link immediately; two more queue up.
        for e in 0..3 {
            h.request(ExpertKey::new(0, e), TransferPriority::Prefetch);
        }
        let d = ExpertKey::new(0, 7);
        h.request(d, TransferPriority::Demand);
        h.wait_gpu(d);
        // The demand ran right after the in-flight prefetch, jumping the
        // two still-queued prefetches: 2 transfers total. By the demand's
        // completion instant the link has picked up the next prefetch, so
        // exactly one remains queued.
        assert_eq!(clock.now(), dur * 2);
        let (dq, pq) = h.queue_depth();
        assert_eq!((dq, pq), (0, 1), "one prefetch in flight, one still queued");
        h.shutdown();
    }

    #[test]
    fn real_time_mode_still_sleeps() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 2 ms base latency dominates: measurable but far under the
        // test-suite real-sleep budget.
        let pcie = PcieSim::new(1e9, 2e-3, 1.0);
        let h = TransferEngine::spawn(cache, pcie, store, SimClock::real_time());
        let k = ExpertKey::new(0, 0);
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        h.wait_gpu(k);
        assert!(t0.elapsed().as_secs_f64() > 0.0015, "stall must be real");
        h.shutdown();
    }

    #[test]
    fn transient_fetch_costs_virtual_time() {
        let (h, clock) = setup(2);
        let t0 = clock.now();
        let dur = h.transient_fetch(1 << 20);
        assert!(dur > Duration::ZERO);
        assert_eq!(clock.now() - t0, dur);
        assert_eq!(h.with_state(|st| st.pcie.stats.demand_transfers), 1);
        h.shutdown();
    }
}
