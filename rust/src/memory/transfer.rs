//! The transfer engine: serializes CPU->GPU expert movement over each
//! device's simulated host link, in either of the two [`SimClock`] modes.
//!
//! Since the multi-device topology PR the engine models an expert-parallel
//! fleet: every simulated GPU owns its own [`ExpertCache`] and its own
//! serialized host link ([`PcieSim`]), and a [`Placement`] routes each
//! expert's transfers to its *primary home* device. Links are independent
//! — two devices fetch concurrently — while transfers on one link
//! serialize exactly as before. With one device the behavior is
//! byte-identical to the original single-cache engine.
//!
//! ## Peer-link contention model
//!
//! The peer (GPU↔GPU) interconnect is a set of serialized links with the
//! same FIFO busy-until semantics as the host links: the fully connected
//! fabric is one shared [`PeerLink`], a ring is one link per edge, and
//! [`Topology::peer_path`] maps a device pair to the links a dispatch
//! crosses in order. Charging a dispatch reserves each link on its path
//! starting at `max(cursor, link.busy_until)` — concurrent cross-device
//! dispatches and replica copies *queue behind each other* on the virtual
//! clock instead of overlapping for free, and every link traversal is
//! recorded as its own transfer so [`PcieSim`] busy-time accounting equals
//! the charged duration (one base latency per hop).
//!
//! ## Expert replication
//!
//! A [`Placement`] may give hot experts several homes. The engine keeps
//! replicas resident on their whole home set (the replication-intent mask
//! shields them from eviction; see
//! [`ExpertCache::request_load_protected`]), and online re-placement
//! promotes/demotes replicas over the peer links as real asynchronous
//! transfers ([`TransferHandle::replica_promote`] /
//! [`TransferHandle::replica_demote`]).
//!
//! Two priority classes share each link: **demand** loads (synchronous
//! misses — the pipeline is stalled on them) always preempt **prefetch**
//! loads (speculative). Completed transfers flip the cache slot to `Gpu`
//! and stage the host weights in an arrivals list the engine layer drains
//! to create device buffers.
//!
//! * **Virtual clock** — transfers are discrete events. A request enqueues
//!   with its (virtual) arrival time; each link starts its next transfer
//!   the moment it frees (demand first among requests that have arrived by
//!   then), and completion advances nothing by itself — completions become
//!   visible when the clock reaches their ready time. A synchronous
//!   `wait_gpu` *advances the clock* to the stalled transfer's completion.
//!   No thread is spawned and nothing sleeps, so a full table sweep runs in
//!   milliseconds and is bit-for-bit deterministic, while the
//!   link-serialization and preemption semantics match the threaded
//!   engine's exactly.
//! * **Real-time clock** — one background thread per device pops requests
//!   and sleeps for each simulated duration, so downstream latency numbers
//!   are genuine elapsed-time measurements.
//!
//! ## Fault injection & recovery
//!
//! The engine replays a [`FaultTimeline`] (see `crate::fault`) inside
//! `settle()`: before the fleet settles past a fault's virtual timestamp,
//! every link is first settled to exactly that instant, then the fault
//! mutates state as one discrete event — so faults are totally ordered
//! against transfer starts/completions and runs stay per-seed
//! byte-identical. A downed device loses its queued and in-flight
//! transfers, its unpinned cache contents, and accepts no new work until it
//! comes back up (empty — recovery re-admits lazily on demand). `wait_gpu`
//! is correspondingly bounded: a lost transfer is re-issued up to
//! [`TransferTuning::max_retries`] times (the first re-issue immediately —
//! the pre-fault behavior — later ones after seeded-jitter exponential
//! backoff), an optional per-transfer deadline caps the stall, and the
//! caller gets a [`TransferOutcome`] instead of an unbounded block.
//!
//! ## Panic policy (unwrap audit)
//!
//! Fallible lock/state paths on the engine API surface return contextful
//! `anyhow` errors where a caller can recover (`drain_arrivals`,
//! `drain_evictions`). The remaining panics are named invariant
//! violations: a poisoned state mutex (a holder panicked mid-update, so
//! fleet state is unrecoverable by construction) and a `WeightStore`
//! missing an expert the cache accepted.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context as _;

use crate::fault::{FaultAction, FaultTick, FaultTimeline};
use crate::memory::cache::{ExpertCache, LoadDecision, SlotState};
use crate::memory::pcie::{PcieSim, PcieStats};
use crate::topology::{Placement, Topology};
use crate::trace::{StallKind, Tracer, Track};
use crate::util::clock::SimClock;
use crate::util::rng::Rng;
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferPriority {
    Demand,
    Prefetch,
}

/// How a synchronous `wait_gpu` stall resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferOutcome {
    /// Resident without incident.
    Ok,
    /// Resident, but the transfer was lost and re-issued `n` times along
    /// the way (cancellation race, in-flight loss, device flap).
    Retried(u32),
    /// Gave up: deadline exceeded, retry budget exhausted, home device
    /// down, or no evictable slot for a re-issue. The expert is *not*
    /// resident; the caller runs its degradation waterfall.
    TimedOut,
}

/// Retry/deadline knobs for synchronous transfers. The defaults (no
/// deadline; first re-issue immediate) make healthy runs byte-identical to
/// the pre-fault engine: the backoff RNG is only consulted from the second
/// re-issue of the same wait on, which a fault-free run never reaches.
#[derive(Debug, Clone, Copy)]
pub struct TransferTuning {
    /// Per-`wait_gpu` stall budget (virtual time). `None` disables the
    /// deadline. Ignored in real-time mode.
    pub deadline: Option<Duration>,
    /// Re-issues of a lost transfer before giving up.
    pub max_retries: u32,
    /// Base of the exponential backoff applied from the second re-issue of
    /// one wait on (`base * 2^(n-1) * (1 + jitter)`, jitter uniform in
    /// `[0, 1)` from the seeded stream).
    pub backoff_base: Duration,
    /// Seed for the backoff-jitter RNG (deterministic per seed).
    pub seed: u64,
}

impl Default for TransferTuning {
    fn default() -> Self {
        Self {
            deadline: None,
            max_retries: 4,
            backoff_base: Duration::from_micros(2000),
            seed: 0x00dd_f00d,
        }
    }
}

/// A queued (not yet started) transfer request.
#[derive(Debug, Clone, Copy)]
struct Queued {
    key: ExpertKey,
    /// Virtual time the request was made; a transfer can never start
    /// before it was requested.
    enqueued_at: Duration,
}

/// A transfer occupying a link. Its PCIe traffic is recorded at start;
/// completion only flips cache state and stages the arrival. (Real-time
/// mode uses this as an in-progress marker with `ready_at` unused.)
#[derive(Debug, Clone, Copy)]
struct InFlight {
    key: ExpertKey,
    ready_at: Duration,
}

/// One simulated GPU: its expert cache plus its own serialized host link.
pub struct DeviceState {
    pub cache: ExpertCache,
    pub pcie: PcieSim,
    /// Out of service (fault injection). A down device starts no transfers,
    /// counts no residency, and accepts no new requests.
    pub down: bool,
    /// Host-link bandwidth at spawn; degrade faults scale relative to this
    /// so overlapping degrades do not compound.
    nominal_bw: f64,
    demand_q: VecDeque<Queued>,
    prefetch_q: VecDeque<Queued>,
    in_flight: Vec<InFlight>,
    /// Virtual time at which this link finishes its current work.
    link_free_at: Duration,
}

impl DeviceState {
    fn new(cache: ExpertCache, pcie: PcieSim) -> Self {
        let nominal_bw = pcie.bandwidth_bytes_per_s;
        Self {
            cache,
            pcie,
            down: false,
            nominal_bw,
            demand_q: VecDeque::new(),
            prefetch_q: VecDeque::new(),
            in_flight: Vec::new(),
            link_free_at: Duration::ZERO,
        }
    }

    fn has_transfer(&self, key: ExpertKey) -> bool {
        self.demand_q.iter().any(|q| q.key == key)
            || self.prefetch_q.iter().any(|q| q.key == key)
            || self.in_flight.iter().any(|t| t.key == key)
    }
}

/// One serialized peer link: the cost model + traffic stats of a shared
/// fabric (fully connected) or a single ring edge, with the same FIFO
/// busy-until semantics as a device's host link.
pub struct PeerLink {
    pub sim: PcieSim,
    /// Virtual time at which this link finishes its queued traversals.
    pub busy_until: Duration,
}

/// An expert copy in flight device→device over the peer links (an online
/// re-placement promotion).
#[derive(Debug, Clone, Copy)]
struct PeerInFlight {
    key: ExpertKey,
    device: usize,
    ready_at: Duration,
}

/// Per-device caches + links, the expert→device-set map, the contended
/// peer links, and arrival/eviction mailboxes, all behind one mutex.
/// Arrivals carry [`ExpertWeights`] by `Arc` — staging a completed
/// transfer is a pointer move, not a weight copy (the simulated link
/// already charged the PCIe time for the bytes).
pub struct EngineState {
    pub devices: Vec<DeviceState>,
    pub placement: Placement,
    pub topology: Topology,
    /// Serialized peer (GPU↔GPU) links ([`Topology::n_peer_links`] of
    /// them). Only touched by cross-device dispatches and replica copies,
    /// so they stay all-zero in the single-device configuration.
    pub peer_links: Vec<PeerLink>,
    /// Replica copies in flight over the peer links.
    peer_in_flight: Vec<PeerInFlight>,
    pub arrivals: Vec<(ExpertKey, ExpertWeights)>,
    pub evictions: Vec<ExpertKey>,
    /// Expanded fault schedule replayed by `settle` (inert when empty).
    faults: FaultTimeline,
    /// Bumped once per applied fault tick; the engine layer polls it to
    /// detect device up/down transitions without re-scanning the fleet.
    fault_epoch: u64,
    /// Seeded jitter stream for retry backoff (only drawn from on the
    /// second re-issue of a wait — never in fault-free runs).
    retry_rng: Rng,
    /// Trace sink for transfer-lifecycle events (`Tracer::off()` unless
    /// the serving engine installs an enabled recorder post-spawn). Every
    /// emission site goes through an inlined no-op when disabled.
    pub tracer: Tracer,
    shutdown: bool,
}

impl EngineState {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Applied-fault counter (one increment per primitive fault tick).
    pub fn fault_epoch(&self) -> u64 {
        self.fault_epoch
    }

    /// Which devices are currently out of service.
    pub fn down_mask(&self) -> Vec<bool> {
        self.devices.iter().map(|d| d.down).collect()
    }

    pub fn is_down(&self, dev: usize) -> bool {
        self.devices[dev].down
    }

    /// Primary home device of an expert (demand fetches land here).
    pub fn home(&self, key: ExpertKey) -> usize {
        self.placement.device_of(key)
    }

    /// The primary-home cache responsible for `key`'s demand transfers.
    pub fn cache(&self, key: ExpertKey) -> &ExpertCache {
        &self.devices[self.home(key)].cache
    }

    pub fn cache_mut(&mut self, key: ExpertKey) -> &mut ExpertCache {
        let d = self.home(key);
        &mut self.devices[d].cache
    }

    /// Resident on any of its *live* home devices (an expert is only ever
    /// admitted at a home, so this is fleet-wide residency). A copy on a
    /// downed device does not count — its weights are unreachable until
    /// the device recovers.
    pub fn is_gpu(&self, key: ExpertKey) -> bool {
        for i in 0..self.placement.replication_of(key) {
            let d = self.placement.homes(key)[i];
            if !self.devices[d].down && self.devices[d].cache.is_gpu(key) {
                return true;
            }
        }
        false
    }

    /// Record a routing hit on every home replica (so each home's
    /// recency/frequency bookkeeping — and the re-placement telemetry —
    /// sees the full traffic).
    pub fn mark_use(&mut self, key: ExpertKey) {
        for i in 0..self.placement.replication_of(key) {
            let d = self.placement.homes(key)[i];
            self.devices[d].cache.mark_use(key);
        }
    }

    pub fn pin(&mut self, key: ExpertKey) {
        for i in 0..self.placement.replication_of(key) {
            let d = self.placement.homes(key)[i];
            self.devices[d].cache.pin(key);
        }
    }

    pub fn unpin(&mut self, key: ExpertKey) {
        for i in 0..self.placement.replication_of(key) {
            let d = self.placement.homes(key)[i];
            self.devices[d].cache.unpin(key);
        }
    }

    pub fn admit(&mut self, key: ExpertKey) -> anyhow::Result<()> {
        self.cache_mut(key).admit(key)
    }

    pub fn demote(&mut self, key: ExpertKey) -> bool {
        self.cache_mut(key).demote(key)
    }

    /// Per-expert eviction shield for one layer: replicated experts'
    /// copies must not be evicted out from under their placement intent
    /// (only the re-placement demotion path removes them). Empty — and
    /// allocation-free — when nothing is replicated.
    fn protected_mask(&self, layer: usize) -> Vec<bool> {
        if !self.placement.is_replicated() {
            return Vec::new();
        }
        (0..self.placement.n_experts())
            .map(|e| self.placement.replication_of(ExpertKey::new(layer, e)) > 1)
            .collect()
    }

    /// `request_load` on the primary home with the layer's replication
    /// shield applied to victim selection.
    fn request_load_routed(&mut self, key: ExpertKey) -> LoadDecision {
        let protected = self.protected_mask(key.layer);
        let d = self.home(key);
        self.devices[d].cache.request_load_protected(key, &protected)
    }

    /// Residency mask for one layer across the whole fleet (Algorithm 1's
    /// M): expert `e` is resident iff it is GPU-resident on one of its
    /// home devices.
    pub fn residency_mask(&self, layer: usize) -> Vec<bool> {
        (0..self.placement.n_experts())
            .map(|e| self.is_gpu(ExpertKey::new(layer, e)))
            .collect()
    }

    /// Host-link traffic summed over every device (the fleet-wide view the
    /// reports consume; identical to the single link's stats when
    /// `n_devices == 1`).
    pub fn pcie_stats(&self) -> PcieStats {
        let mut total = PcieStats::default();
        for d in &self.devices {
            total.accumulate(&d.pcie.stats);
        }
        total
    }

    /// Peer-interconnect traffic summed over every serialized link.
    pub fn peer_stats(&self) -> PcieStats {
        let mut total = PcieStats::default();
        for l in &self.peer_links {
            total.accumulate(&l.sim.stats);
        }
        total
    }

    fn has_transfer(&self, key: ExpertKey) -> bool {
        self.devices[self.home(key)].has_transfer(key)
            || self.peer_in_flight.iter().any(|t| t.key == key)
    }
}

/// Reserve a dispatch of `bytes` across `edges` (in traversal order) with
/// FIFO busy-until semantics: each link starts at `max(cursor,
/// busy_until)`, and every traversal is recorded as its own transfer so
/// the link's recomputed busy time matches the charged duration (one base
/// latency per hop — the multi-hop accounting fix). Returns the instant
/// the last traversal completes (`start_at` for an empty path).
fn reserve_peer_path(
    st: &mut EngineState,
    edges: &[usize],
    bytes: usize,
    start_at: Duration,
) -> Duration {
    let mut cursor = start_at;
    for &e in edges {
        let link = &mut st.peer_links[e];
        let start = cursor.max(link.busy_until);
        let dur = link.sim.transfer_duration(bytes);
        let end = start + dur;
        link.busy_until = end;
        link.sim.record(bytes, false);
        st.tracer.span(start, end, Track::PeerLink(e), "peer_xfer", &[("bytes", bytes as i64)]);
        cursor = end;
    }
    cursor
}

pub struct Inner {
    state: Mutex<EngineState>,
    cv: Condvar,
}

impl Inner {
    /// Invariant: the state mutex is never poisoned — a holder that
    /// panicked mid-update leaves the fleet bookkeeping unrecoverable, so
    /// infallible API paths stop here with the invariant named.
    fn lock(&self) -> MutexGuard<'_, EngineState> {
        self.state.lock().unwrap_or_else(|_| {
            panic!(
                "invariant violated: transfer-engine state mutex poisoned \
                 (a state holder panicked mid-update; fleet bookkeeping is unrecoverable)"
            )
        })
    }

    /// Fallible flavor for API surfaces where the caller can recover.
    fn try_lock(&self) -> anyhow::Result<MutexGuard<'_, EngineState>> {
        self.state.lock().map_err(|_| {
            anyhow::anyhow!(
                "transfer-engine state mutex poisoned: a state holder panicked mid-update"
            )
        })
    }
}

pub type SharedCache = Arc<Inner>;

pub struct TransferEngine;

/// Handle owned by the serving engine; cloneable for the prefetcher.
#[derive(Clone)]
pub struct TransferHandle {
    inner: SharedCache,
    clock: SimClock,
    store: Arc<WeightStore>,
    tuning: TransferTuning,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// When will this link start its next queued transfer, and is it a demand?
///
/// The link frees at `link_free_at`; the next transfer starts at
/// `max(link_free_at, earliest enqueue among queue fronts)`. At that
/// instant a demand wins if it has arrived by then — exactly the threaded
/// engine's "pop demand first" rule at the moment the thread frees.
fn next_start(dev: &DeviceState) -> Option<(Duration, bool)> {
    let d = dev.demand_q.front().map(|q| q.enqueued_at);
    let p = dev.prefetch_q.front().map(|q| q.enqueued_at);
    let earliest = match (d, p) {
        (None, None) => return None,
        (Some(a), None) => a,
        (None, Some(b)) => b,
        (Some(a), Some(b)) => a.min(b),
    };
    let start = dev.link_free_at.max(earliest);
    let demand_first = d.map(|t| t <= start).unwrap_or(false);
    Some((start, demand_first))
}

/// Advance one device's virtual link state to `now`: start every transfer
/// whose start time has been reached (recording its PCIe traffic — the
/// link is committed the moment a transfer starts, and recording at start
/// keeps virtual and real-time stats in agreement even for transfers still
/// in flight when a run ends), and complete every transfer whose ready
/// time has passed (flipping the cache slot and staging arrivals).
fn settle_device(
    dev_idx: usize,
    dev: &mut DeviceState,
    store: &WeightStore,
    now: Duration,
    arrivals: &mut Vec<(ExpertKey, ExpertWeights)>,
    tracer: &Tracer,
) {
    // A down device starts no transfers (its queues were drained when it
    // went down, but new enqueues are also refused at the request layer).
    while !dev.down {
        let Some((start, demand_first)) = next_start(dev) else { break };
        if start > now {
            break;
        }
        let key = if demand_first {
            dev.demand_q
                .pop_front()
                .expect("invariant violated: next_start reported a queued demand")
                .key
        } else {
            dev.prefetch_q
                .pop_front()
                .expect("invariant violated: next_start reported a queued prefetch")
                .key
        };
        let dur = dev.pcie.transfer_duration(store.expert_bytes);
        let ready = start + dur;
        dev.link_free_at = ready;
        dev.pcie.record(store.expert_bytes, !demand_first);
        dev.in_flight.push(InFlight { key, ready_at: ready });
        tracer.span(
            start,
            ready,
            Track::HostLink(dev_idx),
            "transfer",
            &[
                ("layer", key.layer as i64),
                ("expert", key.expert as i64),
                ("prefetch", (!demand_first) as i64),
            ],
        );
    }
    let mut i = 0;
    while i < dev.in_flight.len() {
        if dev.in_flight[i].ready_at <= now {
            let t = dev.in_flight.remove(i);
            dev.cache.complete_load(t.key);
            let w = store.expert(t.key).expect(
                "invariant violated: WeightStore must hold every expert the cache accepted",
            );
            arrivals.push((t.key, w));
            tracer.instant(
                t.ready_at,
                Track::HostLink(dev_idx),
                "land",
                &[("layer", t.key.layer as i64), ("expert", t.key.expert as i64)],
            );
        } else {
            i += 1;
        }
    }
}

/// Settle every device's link to `now`, replaying due fault ticks in
/// timestamp order: the fleet is settled up to each tick's instant before
/// the tick mutates state, so faults interleave with transfer events
/// deterministically. Links are independent: each one serializes its own
/// transfers but never blocks another's. Replica copies that finished
/// crossing the peer links land on their target device's cache and stage
/// their weights like any host arrival.
fn settle(st: &mut EngineState, store: &WeightStore, now: Duration) {
    while let Some(tick) = st.faults.peek_due(now) {
        settle_links(st, store, tick.at);
        apply_fault(st, tick);
        st.faults.pop();
        st.fault_epoch += 1;
    }
    settle_links(st, store, now);
}

fn settle_links(st: &mut EngineState, store: &WeightStore, now: Duration) {
    let EngineState { devices, arrivals, peer_in_flight, tracer, .. } = st;
    for (i, dev) in devices.iter_mut().enumerate() {
        settle_device(i, dev, store, now, arrivals, tracer);
    }
    let mut i = 0;
    while i < peer_in_flight.len() {
        if peer_in_flight[i].ready_at <= now {
            let t = peer_in_flight.remove(i);
            devices[t.device].cache.complete_load(t.key);
            let w = store.expert(t.key).expect(
                "invariant violated: WeightStore must hold every expert the cache accepted",
            );
            arrivals.push((t.key, w));
            tracer.instant(
                t.ready_at,
                Track::Device(t.device),
                "replica_land",
                &[("layer", t.key.layer as i64), ("expert", t.key.expert as i64)],
            );
        } else {
            i += 1;
        }
    }
}

/// Apply one primitive fault tick to the fleet. Only engine-owned state is
/// touched (see `crate::fault` module docs for the full mutation contract).
fn apply_fault(st: &mut EngineState, tick: FaultTick) {
    let (fault_name, target) = match &tick.action {
        FaultAction::DeviceDown { device } => ("device_down", *device as i64),
        FaultAction::DeviceUp { device } => ("device_up", *device as i64),
        FaultAction::HostBandwidth { device, .. } => ("host_bandwidth", *device as i64),
        FaultAction::HostStall { device, .. } => ("host_stall", *device as i64),
        FaultAction::PeerStall { link, .. } => ("peer_stall", *link as i64),
        FaultAction::LoseInFlight { device } => ("lose_inflight", *device as i64),
    };
    st.tracer.instant(tick.at, Track::Fault, fault_name, &[("target", target)]);
    match tick.action {
        FaultAction::DeviceDown { device } => {
            let live = st.devices.iter().filter(|d| !d.down).count();
            if st.devices[device].down || live <= 1 {
                // Never down the last live device (the fleet would deadlock
                // with no recovery target); repeated downs are no-ops.
                log::warn!("fault: ignoring device-down({device}) — last live device or already down");
                return;
            }
            // Cancel replica copies heading to the device first (their
            // Loading slots live in its cache).
            let mut i = 0;
            while i < st.peer_in_flight.len() {
                if st.peer_in_flight[i].device == device {
                    let t = st.peer_in_flight.remove(i);
                    st.devices[device].cache.abort_load(t.key);
                } else {
                    i += 1;
                }
            }
            let dev = &mut st.devices[device];
            dev.down = true;
            // Queued and in-flight host transfers are lost with the link.
            for q in dev.demand_q.drain(..) {
                dev.cache.abort_load(q.key);
            }
            for q in dev.prefetch_q.drain(..) {
                dev.cache.abort_load(q.key);
            }
            for t in dev.in_flight.drain(..) {
                dev.cache.abort_load(t.key);
            }
            dev.link_free_at = tick.at;
            // Unpinned residency is invalidated; the engine layer drops the
            // matching device buffers via the eviction mailbox.
            let dropped = dev.cache.invalidate_unpinned();
            st.evictions.extend(dropped);
        }
        FaultAction::DeviceUp { device } => {
            let dev = &mut st.devices[device];
            if dev.down {
                dev.down = false;
                dev.link_free_at = dev.link_free_at.max(tick.at);
            }
        }
        FaultAction::HostBandwidth { device, multiplier } => {
            let dev = &mut st.devices[device];
            dev.pcie.bandwidth_bytes_per_s = dev.nominal_bw * multiplier;
        }
        FaultAction::HostStall { device, until } => {
            let dev = &mut st.devices[device];
            dev.link_free_at = dev.link_free_at.max(until);
        }
        FaultAction::PeerStall { link, until } => {
            if let Some(l) = st.peer_links.get_mut(link) {
                l.busy_until = l.busy_until.max(until);
            }
        }
        FaultAction::LoseInFlight { device } => {
            let dev = &mut st.devices[device];
            for t in dev.in_flight.drain(..) {
                dev.cache.abort_load(t.key);
            }
            // The discarded work frees the link at the loss instant.
            dev.link_free_at = dev.link_free_at.min(tick.at);
        }
    }
}

/// The next virtual instant at which a transfer completes on this link
/// (in-flight first; otherwise the next queued transfer's start +
/// duration). A down device produces no events.
fn next_event(dev: &DeviceState, expert_bytes: usize) -> Option<Duration> {
    if dev.down {
        return None;
    }
    if let Some(t) = dev.in_flight.iter().map(|t| t.ready_at).min() {
        return Some(t);
    }
    next_start(dev).map(|(start, _)| start + dev.pcie.transfer_duration(expert_bytes))
}

/// The fix for the request/wait race: the awaited expert's transfer can
/// vanish between `request` and `wait_gpu` (the prefetch verification step
/// cancelled it, or a fault dropped it). Re-issue the load at demand
/// priority. Returns false when the load cannot be re-issued (every slot
/// in the layer is pinned) — the caller surfaces `TimedOut` instead of the
/// old panic.
fn reissue_demand(st: &mut EngineState, key: ExpertKey, now: Duration) -> bool {
    if st.cache(key).state(key) == SlotState::Loading {
        // Orphaned Loading slot with no backing transfer: reset it so
        // request_load can restart the state machine.
        st.cache_mut(key).abort_load(key);
    }
    match st.request_load_routed(key) {
        LoadDecision::StartLoad { evicted } => {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            let dev = st.home(key);
            st.devices[dev].demand_q.push_back(Queued { key, enqueued_at: now });
            true
        }
        LoadDecision::AlreadyGpu => true,
        LoadDecision::AlreadyLoading => {
            unreachable!("invariant violated: orphaned Loading slot was just reset")
        }
        LoadDecision::NoRoom => false,
    }
}

/// Give up on a wait: dequeue the expert's still-queued transfer (freeing
/// its `Loading` slot) so the abandoned request stops holding cache
/// capacity. A transfer already *in flight* is left to land — the link
/// time is committed and the late arrival is harmless (the expert simply
/// becomes resident after the caller has moved on).
fn abandon_wait(st: &mut EngineState, key: ExpertKey) {
    let dev = st.home(key);
    let d = &mut st.devices[dev];
    let mut dequeued = false;
    if let Some(pos) = d.demand_q.iter().position(|q| q.key == key) {
        d.demand_q.remove(pos);
        dequeued = true;
    }
    if let Some(pos) = d.prefetch_q.iter().position(|q| q.key == key) {
        d.prefetch_q.remove(pos);
        dequeued = true;
    }
    if dequeued {
        d.cache.abort_load(key);
    }
}

impl TransferEngine {
    /// Single-device convenience: the degenerate one-GPU fleet (all
    /// experts homed on device 0). Byte-identical to the pre-topology
    /// engine.
    pub fn spawn(
        cache: ExpertCache,
        pcie: PcieSim,
        store: Arc<WeightStore>,
        clock: SimClock,
    ) -> TransferHandle {
        let placement = Placement::single(cache.n_layers(), cache.n_experts());
        // The peer link of a one-GPU fleet carries no traffic; use the
        // serving-config default cost model rather than duplicating its
        // constants here.
        let dflt = crate::config::ServingConfig::default();
        let peer = PcieSim::new(dflt.peer_bandwidth, dflt.peer_base_latency, 1.0);
        let topology = Topology::new(1, crate::topology::TopologyKind::FullyConnected);
        Self::spawn_multi(vec![(cache, pcie)], peer, topology, placement, store, clock)
    }

    /// Build the engine for an expert-parallel fleet: one (cache, host
    /// link) pair per device, a peer-link cost model (instantiated once
    /// per serialized link of `topology`), and the expert→device-set
    /// placement. With a virtual clock this spawns no thread — transfers
    /// are simulated events; with a real-time clock one background thread
    /// per device sleeps for each simulated transfer duration.
    pub fn spawn_multi(
        devices: Vec<(ExpertCache, PcieSim)>,
        peer: PcieSim,
        topology: Topology,
        placement: Placement,
        store: Arc<WeightStore>,
        clock: SimClock,
    ) -> TransferHandle {
        Self::spawn_multi_with(
            devices,
            peer,
            topology,
            placement,
            store,
            clock,
            FaultTimeline::default(),
            TransferTuning::default(),
        )
    }

    /// [`Self::spawn_multi`] with a fault schedule and transfer tuning.
    /// Fault injection requires a virtual clock (the timeline is replayed
    /// against virtual timestamps); a non-empty timeline under a real-time
    /// clock is refused.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_multi_with(
        devices: Vec<(ExpertCache, PcieSim)>,
        peer: PcieSim,
        topology: Topology,
        placement: Placement,
        store: Arc<WeightStore>,
        clock: SimClock,
        faults: FaultTimeline,
        tuning: TransferTuning,
    ) -> TransferHandle {
        assert!(!devices.is_empty(), "need at least one device");
        assert!(
            clock.is_virtual() || !faults.is_active(),
            "fault injection is only supported under a virtual clock"
        );
        assert_eq!(
            devices.len(),
            placement.n_devices(),
            "placement device count must match the fleet"
        );
        assert_eq!(
            devices.len(),
            topology.n_devices(),
            "topology device count must match the fleet"
        );
        let n_devices = devices.len();
        let peer_links = (0..topology.n_peer_links())
            .map(|_| PeerLink { sim: peer.clone(), busy_until: Duration::ZERO })
            .collect();
        let inner = Arc::new(Inner {
            state: Mutex::new(EngineState {
                devices: devices
                    .into_iter()
                    .map(|(cache, pcie)| DeviceState::new(cache, pcie))
                    .collect(),
                placement,
                topology,
                peer_links,
                peer_in_flight: Vec::new(),
                arrivals: Vec::new(),
                evictions: Vec::new(),
                faults,
                fault_epoch: 0,
                retry_rng: Rng::new(tuning.seed ^ 0xfa17_0b0f),
                tracer: Tracer::off(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let threads = if clock.is_virtual() {
            Vec::new()
        } else {
            (0..n_devices)
                .map(|dev| {
                    let inner2 = inner.clone();
                    let store2 = store.clone();
                    std::thread::Builder::new()
                        .name(format!("pcie-transfer-{dev}"))
                        .spawn(move || Self::run(inner2, store2, dev))
                        .expect("spawn transfer engine")
                })
                .collect()
        };
        TransferHandle { inner, clock, store, tuning, threads: Arc::new(Mutex::new(threads)) }
    }

    /// Real-time worker loop for one device: pop (demand first), sleep the
    /// simulated duration, complete. The in-flight marker keeps
    /// `wait_gpu`'s lost-transfer detection honest while the thread
    /// sleeps outside the lock.
    fn run(inner: SharedCache, store: Arc<WeightStore>, dev: usize) {
        loop {
            let (key, duration) = {
                // A poisoned mutex means another holder panicked; this
                // worker can recover by exiting cleanly instead of
                // double-panicking during unwind.
                let Ok(mut st) = inner.state.lock() else { return };
                loop {
                    if st.shutdown {
                        return;
                    }
                    let d = &mut st.devices[dev];
                    if let Some(q) = d.demand_q.pop_front() {
                        let dur = d.pcie.transfer_duration(store.expert_bytes);
                        // Record at transfer start (matches virtual mode).
                        d.pcie.record(store.expert_bytes, false);
                        d.in_flight.push(InFlight { key: q.key, ready_at: Duration::ZERO });
                        break (q.key, dur);
                    }
                    if let Some(q) = d.prefetch_q.pop_front() {
                        let dur = d.pcie.transfer_duration(store.expert_bytes);
                        d.pcie.record(store.expert_bytes, true);
                        d.in_flight.push(InFlight { key: q.key, ready_at: Duration::ZERO });
                        break (q.key, dur);
                    }
                    st = match inner.cv.wait(st) {
                        Ok(g) => g,
                        Err(_) => return,
                    };
                }
            };
            // Occupy the link in real time (lock released).
            std::thread::sleep(duration);
            let weights = store.expert(key).expect(
                "invariant violated: WeightStore must hold every expert the cache accepted",
            );
            let Ok(mut st) = inner.state.lock() else { return };
            let d = &mut st.devices[dev];
            if let Some(pos) = d.in_flight.iter().position(|t| t.key == key) {
                d.in_flight.remove(pos);
            }
            d.cache.complete_load(key);
            st.arrivals.push((key, weights));
            inner.cv.notify_all();
        }
    }
}

impl TransferHandle {
    /// Lock the shared state, first settling the virtual event queues up
    /// to the current virtual time so callers always observe a consistent
    /// "present".
    fn lock_settled(&self) -> MutexGuard<'_, EngineState> {
        let mut st = self.inner.lock();
        if self.clock.is_virtual() {
            settle(&mut st, &self.store, self.clock.now());
        }
        st
    }

    /// Fallible flavor of [`Self::lock_settled`] for API surfaces where
    /// the caller can recover from a poisoned state mutex.
    fn try_lock_settled(&self) -> anyhow::Result<MutexGuard<'_, EngineState>> {
        let mut st = self.inner.try_lock()?;
        if self.clock.is_virtual() {
            settle(&mut st, &self.store, self.clock.now());
        }
        Ok(st)
    }

    /// The retry/deadline knobs this engine was spawned with.
    pub fn tuning(&self) -> TransferTuning {
        self.tuning
    }

    /// Override the per-awaited-transfer deadline on *this* handle
    /// (`None` disables it). Tuning is per-handle `Copy` state: clones
    /// held elsewhere (e.g. the prefetcher, which never waits on
    /// transfers) are unaffected. The brownout controller uses this to
    /// tighten the deadline while browned out and restore it on exit.
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.tuning.deadline = deadline;
    }

    /// The clock this engine runs on.
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Run a closure with exclusive access to the fleet state.
    pub fn with_state<R>(&self, f: impl FnOnce(&mut EngineState) -> R) -> R {
        let mut st = self.lock_settled();
        f(&mut st)
    }

    /// Request that `key` be brought onto its primary home device (a
    /// replica already resident on *any* home returns `AlreadyGpu`).
    /// Returns the cache decision; enqueues a transfer on the home link
    /// (and records any eviction) when a load starts.
    pub fn request(&self, key: ExpertKey, prio: TransferPriority) -> LoadDecision {
        let mut st = self.lock_settled();
        if st.is_gpu(key) {
            return LoadDecision::AlreadyGpu;
        }
        if st.devices[st.home(key)].down {
            // A down home cannot accept a transfer; NoRoom tells the
            // caller to degrade (transient fetch / waterfall) without
            // queueing work that could never start.
            return LoadDecision::NoRoom;
        }
        let decision = st.request_load_routed(key);
        if let LoadDecision::StartLoad { evicted } = decision {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            let dev = st.home(key);
            let q = Queued { key, enqueued_at: self.clock.now() };
            match prio {
                TransferPriority::Demand => st.devices[dev].demand_q.push_back(q),
                TransferPriority::Prefetch => st.devices[dev].prefetch_q.push_back(q),
            }
            st.tracer.instant(
                q.enqueued_at,
                Track::HostLink(dev),
                "enqueue",
                &[
                    ("layer", key.layer as i64),
                    ("expert", key.expert as i64),
                    ("prefetch", matches!(prio, TransferPriority::Prefetch) as i64),
                ],
            );
            if self.clock.is_virtual() {
                // The link may be idle: the transfer starts this instant.
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
        decision
    }

    /// Escalate a still-queued prefetch to demand priority (the
    /// verification step of the prefetch pipeline, Fig 3). Transfers that
    /// already started keep their class.
    pub fn escalate(&self, key: ExpertKey) {
        let mut st = self.lock_settled();
        let dev = st.home(key);
        if let Some(pos) = st.devices[dev].prefetch_q.iter().position(|q| q.key == key) {
            let q = st.devices[dev]
                .prefetch_q
                .remove(pos)
                .expect("invariant violated: position() just located this queue index");
            st.devices[dev].demand_q.push_back(q);
            if self.clock.is_virtual() {
                settle(&mut st, &self.store, self.clock.now());
            } else {
                self.inner.cv.notify_all();
            }
        }
    }

    /// Cancel a still-queued (not yet started) prefetch: the verification
    /// step discovered it is not needed. Returns true if it was dequeued.
    /// Saves PCIe occupancy that would otherwise serve speculative waste.
    pub fn cancel_prefetch(&self, key: ExpertKey) -> bool {
        let mut st = self.lock_settled();
        let dev = st.home(key);
        if let Some(pos) = st.devices[dev].prefetch_q.iter().position(|q| q.key == key) {
            st.devices[dev].prefetch_q.remove(pos);
            st.cache_mut(key).abort_load(key);
            true
        } else {
            false
        }
    }

    /// Block until `key` is resident on a live home device (the
    /// synchronous miss stall). Under a virtual clock this advances the
    /// clock to the transfer's completion instant — the stall costs
    /// virtual, not real, time. A lost transfer (cancellation race,
    /// fault-injected loss) is re-issued at demand priority up to
    /// `tuning.max_retries` times: the first re-issue is immediate (the
    /// pre-fault behavior, so fault-free runs are byte-identical), later
    /// ones wait out a seeded-jitter exponential backoff first. The wait
    /// resolves `TimedOut` — leaving the expert non-resident — when the
    /// optional deadline expires, the retry budget runs out, the home
    /// device is down, or a re-issue finds every slot pinned.
    #[must_use = "a TimedOut expert is not resident; run the degradation waterfall"]
    pub fn wait_gpu(&self, key: ExpertKey) -> TransferOutcome {
        let deadline = self.tuning.deadline.map(|d| self.clock.now() + d);
        let mut retries: u32 = 0;
        let done = |retries: u32| {
            if retries == 0 {
                TransferOutcome::Ok
            } else {
                TransferOutcome::Retried(retries)
            }
        };
        if self.clock.is_virtual() {
            let mut st = self.inner.lock();
            loop {
                settle(&mut st, &self.store, self.clock.now());
                if st.is_gpu(key) {
                    return done(retries);
                }
                let home = st.home(key);
                let key_args = |reason: i64| {
                    [("layer", key.layer as i64), ("expert", key.expert as i64), ("reason", reason)]
                };
                if let Some(dl) = deadline {
                    if self.clock.now() >= dl {
                        st.tracer.instant(
                            self.clock.now(),
                            Track::HostLink(home),
                            "timeout",
                            &key_args(0),
                        );
                        abandon_wait(&mut st, key);
                        return TransferOutcome::TimedOut;
                    }
                }
                if !st.has_transfer(key) {
                    if st.devices[home].down {
                        // Nothing to clean up: the device-down fault
                        // already drained its queues. The caller reroutes.
                        st.tracer.instant(
                            self.clock.now(),
                            Track::HostLink(home),
                            "timeout",
                            &key_args(1),
                        );
                        return TransferOutcome::TimedOut;
                    }
                    if retries >= self.tuning.max_retries {
                        st.tracer.instant(
                            self.clock.now(),
                            Track::HostLink(home),
                            "timeout",
                            &key_args(2),
                        );
                        abandon_wait(&mut st, key);
                        return TransferOutcome::TimedOut;
                    }
                    if retries >= 1 {
                        // Exponential backoff with seeded jitter from the
                        // second re-issue on; burns virtual time, so fault
                        // windows can pass while we back off.
                        let base = self.tuning.backoff_base.as_secs_f64();
                        let jitter = st.retry_rng.f64();
                        let factor = (1u64 << (retries - 1).min(20)) as f64;
                        let t_before = self.clock.now();
                        let mut until =
                            t_before + Duration::from_secs_f64(base * factor * (1.0 + jitter));
                        if let Some(dl) = deadline {
                            until = until.min(dl);
                        }
                        self.clock.advance_to(until);
                        st.tracer.stall(
                            StallKind::RetryBackoff,
                            t_before,
                            self.clock.now(),
                            Track::HostLink(home),
                            &[
                                ("layer", key.layer as i64),
                                ("expert", key.expert as i64),
                                ("retry", retries as i64),
                            ],
                        );
                        settle(&mut st, &self.store, self.clock.now());
                        if st.is_gpu(key) {
                            return done(retries);
                        }
                        if st.devices[home].down {
                            st.tracer.instant(
                                self.clock.now(),
                                Track::HostLink(home),
                                "timeout",
                                &key_args(1),
                            );
                            return TransferOutcome::TimedOut;
                        }
                        if deadline.is_some_and(|dl| self.clock.now() >= dl) {
                            st.tracer.instant(
                                self.clock.now(),
                                Track::HostLink(home),
                                "timeout",
                                &key_args(0),
                            );
                            abandon_wait(&mut st, key);
                            return TransferOutcome::TimedOut;
                        }
                    }
                    retries += 1;
                    if !reissue_demand(&mut st, key, self.clock.now()) {
                        st.tracer.instant(
                            self.clock.now(),
                            Track::HostLink(home),
                            "timeout",
                            &key_args(3),
                        );
                        return TransferOutcome::TimedOut;
                    }
                    st.tracer.instant(
                        self.clock.now(),
                        Track::HostLink(home),
                        "retry",
                        &[
                            ("layer", key.layer as i64),
                            ("expert", key.expert as i64),
                            ("attempt", retries as i64),
                        ],
                    );
                    continue;
                }
                let dev = st.home(key);
                let host = next_event(&st.devices[dev], self.store.expert_bytes);
                let peer = st
                    .peer_in_flight
                    .iter()
                    .filter(|t| t.key == key)
                    .map(|t| t.ready_at)
                    .min();
                let mut t = match (host, peer) {
                    (Some(a), Some(b)) => a.min(b),
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (None, None) => unreachable!(
                        "invariant violated: pending transfer implies a next link event"
                    ),
                };
                // Never advance past the next scheduled fault (it may kill
                // the very transfer we are waiting on) or the deadline.
                if let Some(f) = st.faults.next_at() {
                    t = t.min(f);
                }
                if let Some(dl) = deadline {
                    t = t.min(dl);
                }
                self.clock.advance_to(t);
            }
        } else {
            // Real-time mode: no fault timeline and no virtual deadline —
            // the bounded retry budget still applies.
            let mut st = self.inner.lock();
            loop {
                if st.is_gpu(key) {
                    return done(retries);
                }
                if !st.has_transfer(key) {
                    if retries >= self.tuning.max_retries {
                        return TransferOutcome::TimedOut;
                    }
                    retries += 1;
                    if !reissue_demand(&mut st, key, self.clock.now()) {
                        return TransferOutcome::TimedOut;
                    }
                    self.inner.cv.notify_all();
                }
                st = match self.inner.cv.wait(st) {
                    Ok(g) => g,
                    Err(_) => panic!(
                        "invariant violated: transfer-engine state mutex poisoned \
                         while waiting on a transfer"
                    ),
                };
            }
        }
    }

    /// A transient (uncached) fetch on `key`'s home link: pays the PCIe
    /// time — virtual advance or real sleep — and records demand traffic,
    /// without touching the cache. Returns the simulated duration.
    pub fn transient_fetch_for(&self, key: ExpertKey, bytes: usize) -> Duration {
        let (dev, dur) = {
            let st = self.lock_settled();
            let mut dev = st.home(key);
            if st.devices[dev].down {
                // The home link is gone; stream through the first live
                // device's link instead (deterministic fallback).
                dev = (0..st.devices.len()).find(|&i| !st.devices[i].down).unwrap_or(dev);
            }
            (dev, st.devices[dev].pcie.transfer_duration(bytes))
        };
        self.clock.sleep(dur);
        let mut st = self.lock_settled();
        st.devices[dev].pcie.record(bytes, false);
        let now = self.clock.now();
        st.tracer.stall(
            StallKind::Waterfall,
            now.saturating_sub(dur),
            now,
            Track::HostLink(dev),
            &[("layer", key.layer as i64), ("expert", key.expert as i64), ("bytes", bytes as i64)],
        );
        dur
    }

    /// Transient fetch on device 0 (single-device call sites).
    pub fn transient_fetch(&self, bytes: usize) -> Duration {
        self.transient_fetch_for(ExpertKey::new(0, 0), bytes)
    }

    /// Charge `hops` crossings of `bytes` each on peer link 0 (the
    /// activation round trip of dispatching a token to a cross-device
    /// substitute): reserves the serialized link hop by hop — queuing
    /// behind whatever already occupies it — advances the clock to the
    /// last traversal's completion, and records one transfer per hop.
    /// Returns the simulated wait (queueing + transfer time).
    pub fn peer_dispatch(&self, bytes: usize, hops: usize) -> Duration {
        if hops == 0 {
            return Duration::ZERO;
        }
        let now = self.clock.now();
        let done = {
            let mut st = self.lock_settled();
            let edges = vec![0usize; hops];
            reserve_peer_path(&mut st, &edges, bytes, now)
        };
        let dur = done.saturating_sub(now);
        self.clock.sleep(dur);
        dur
    }

    /// Charge one peer dispatch of `bytes` per `(from, to)` route,
    /// each crossing the serialized links of its topology path with FIFO
    /// busy-until queuing (routes contending for the same link serialize;
    /// routes on disjoint ring edges overlap). Advances the clock to the
    /// latest completion and returns that simulated wait.
    pub fn peer_dispatch_routes(&self, bytes: usize, routes: &[(usize, usize)]) -> Duration {
        let now = self.clock.now();
        let mut latest = now;
        {
            let mut st = self.lock_settled();
            for &(a, b) in routes {
                let edges = st.topology.peer_path(a, b);
                let done = reserve_peer_path(&mut st, &edges, bytes, now);
                latest = latest.max(done);
            }
        }
        let dur = latest.saturating_sub(now);
        if dur > Duration::ZERO {
            self.clock.sleep(dur);
        }
        dur
    }

    /// Online re-placement: bring a replica of `key` up on device `to` by
    /// copying it from the resident home `from` over the peer links. The
    /// copy reserves a cache slot (`Loading`) on `to` immediately, charges
    /// the peer path as a real queued transfer, and completes
    /// asynchronously at its ready instant — the caller does not stall.
    /// Returns false (and changes nothing) if the copy cannot start:
    /// source not resident, target already holds or is receiving a copy,
    /// or no evictable slot on the target.
    pub fn replica_promote(&self, key: ExpertKey, from: usize, to: usize) -> bool {
        let now = self.clock.now();
        let mut st = self.lock_settled();
        if st.devices[from].down || st.devices[to].down {
            return false;
        }
        if !st.devices[from].cache.is_gpu(key) {
            return false;
        }
        match st.devices[to].cache.state(key) {
            SlotState::Gpu | SlotState::Loading => return false,
            SlotState::Cpu => {}
        }
        let protected = st.protected_mask(key.layer);
        match st.devices[to].cache.request_load_protected(key, &protected) {
            LoadDecision::StartLoad { evicted } => {
                if let Some(v) = evicted {
                    st.evictions.push(v);
                }
                let edges = st.topology.peer_path(from, to);
                let ready = reserve_peer_path(&mut st, &edges, self.store.expert_bytes, now);
                st.peer_in_flight.push(PeerInFlight { key, device: to, ready_at: ready });
                true
            }
            _ => false,
        }
    }

    /// Online re-placement: drop the replica of `key` on `dev` (its
    /// placement no longer lists that home). Cancels an in-flight
    /// promotion copy, or demotes a resident unpinned copy and reports
    /// the eviction. Returns true when no copy remains on `dev` (also
    /// when there was none); false when the copy is pinned or loading on
    /// the host link — the caller should keep the home and retry later.
    pub fn replica_demote(&self, key: ExpertKey, dev: usize) -> bool {
        let mut st = self.lock_settled();
        if let Some(pos) =
            st.peer_in_flight.iter().position(|t| t.key == key && t.device == dev)
        {
            st.peer_in_flight.remove(pos);
            st.devices[dev].cache.abort_load(key);
            return true;
        }
        match st.devices[dev].cache.state(key) {
            SlotState::Cpu => true,
            SlotState::Loading => false,
            SlotState::Gpu => {
                if st.devices[dev].cache.demote(key) {
                    st.evictions.push(key);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Drain completed transfers (engine layer creates device buffers).
    /// Errs with context when the state mutex is poisoned — the caller can
    /// surface the failure instead of cascading the panic.
    pub fn drain_arrivals(&self) -> anyhow::Result<Vec<(ExpertKey, ExpertWeights)>> {
        let mut st = self
            .try_lock_settled()
            .context("drain_arrivals: cannot stage completed transfers")?;
        Ok(std::mem::take(&mut st.arrivals))
    }

    /// Drain evicted experts (engine layer drops device buffers). Errs
    /// with context when the state mutex is poisoned.
    pub fn drain_evictions(&self) -> anyhow::Result<Vec<ExpertKey>> {
        let mut st = self
            .try_lock_settled()
            .context("drain_evictions: cannot collect evicted experts")?;
        Ok(std::mem::take(&mut st.evictions))
    }

    /// Number of queued (not yet started) transfers across every link.
    pub fn queue_depth(&self) -> (usize, usize) {
        let st = self.lock_settled();
        st.devices
            .iter()
            .fold((0, 0), |(d, p), dev| (d + dev.demand_q.len(), p + dev.prefetch_q.len()))
    }

    pub fn shutdown(&self) {
        {
            // Best-effort during teardown: a poisoned mutex means the
            // workers are already unwinding, so there is nothing to flag.
            if let Ok(mut st) = self.inner.state.lock() {
                st.shutdown = true;
            }
            self.inner.cv.notify_all();
        }
        if let Ok(mut threads) = self.threads.lock() {
            for t in threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::cache::EvictPolicy;
    use crate::topology::PlacementKind;

    fn setup(cap: usize) -> (TransferHandle, SimClock) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, cap, EvictPolicy::Lru);
        let pcie = PcieSim::new(16e9, 1e-6, 1.0);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        (h, clock)
    }

    #[test]
    fn demand_load_completes() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(0, 2);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        let _ = h.wait_gpu(k);
        assert!(h.with_state(|st| st.is_gpu(k)));
        let arr = h.drain_arrivals().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].0, k);
        h.shutdown();
    }

    #[test]
    fn stats_recorded_per_class() {
        let (h, _) = setup(4);
        h.request(ExpertKey::new(0, 0), TransferPriority::Demand);
        h.request(ExpertKey::new(0, 1), TransferPriority::Prefetch);
        let _ = h.wait_gpu(ExpertKey::new(0, 0));
        let _ = h.wait_gpu(ExpertKey::new(0, 1));
        let (d, p) = h.with_state(|st| {
            let s = st.pcie_stats();
            (s.demand_transfers, s.prefetch_transfers)
        });
        assert_eq!((d, p), (1, 1));
        h.shutdown();
    }

    #[test]
    fn eviction_reported() {
        let (h, _) = setup(1);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        let _ = h.wait_gpu(a);
        h.request(b, TransferPriority::Demand);
        let _ = h.wait_gpu(b);
        let ev = h.drain_evictions().unwrap();
        assert_eq!(ev, vec![a]);
        h.shutdown();
    }

    #[test]
    fn duplicate_requests_coalesce() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(1, 3);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        // Second request while loading (or already loaded) never double-queues.
        let d2 = h.request(k, TransferPriority::Demand);
        assert!(matches!(
            d2,
            LoadDecision::AlreadyLoading | LoadDecision::AlreadyGpu
        ));
        let _ = h.wait_gpu(k);
        assert_eq!(h.drain_arrivals().unwrap().len(), 1);
        h.shutdown();
    }

    #[test]
    fn escalate_moves_queue() {
        let (h, _) = setup(8);
        // Saturate with prefetches, then escalate the last one.
        for e in 0..4 {
            h.request(ExpertKey::new(2, e), TransferPriority::Prefetch);
        }
        h.escalate(ExpertKey::new(2, 3));
        let _ = h.wait_gpu(ExpertKey::new(2, 3));
        h.shutdown();
    }

    #[test]
    fn shutdown_idempotent() {
        let (h, _) = setup(2);
        h.shutdown();
        h.shutdown();
    }

    #[test]
    #[allow(clippy::disallowed_methods)]
    fn virtual_stall_advances_clock_not_wall_time() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 6144 bytes/expert * 1e6 scale / 1e9 B/s ~= 6.1ms per transfer.
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let k = ExpertKey::new(0, 0);
        // pallas-lint: allow(wall-clock, reason = "test asserts the virtual stall consumes no wall time")
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        let _ = h.wait_gpu(k);
        // pallas-lint: allow(wall-clock, reason = "the wall-clock bound is the assertion under test")
        let wall_s = t0.elapsed().as_secs_f64();
        assert!(
            clock.now().as_secs_f64() > 0.006,
            "virtual clock must advance by the transfer duration"
        );
        assert!(wall_s < 0.005, "virtual stall must not consume wall time");
        h.shutdown();
    }

    #[test]
    fn virtual_link_serializes_transfers() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        let _ = h.wait_gpu(a);
        assert_eq!(clock.now(), dur, "first transfer completes after one duration");
        let _ = h.wait_gpu(b);
        assert_eq!(clock.now(), dur * 2, "second transfer waits for the link");
        h.shutdown();
    }

    #[test]
    fn virtual_demand_preempts_queued_prefetches() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 8, EvictPolicy::Lru);
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let dur = pcie.transfer_duration(store.expert_bytes);
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn(cache, pcie, store, clock.clone());
        // First prefetch occupies the link immediately; two more queue up.
        for e in 0..3 {
            h.request(ExpertKey::new(0, e), TransferPriority::Prefetch);
        }
        let d = ExpertKey::new(0, 7);
        h.request(d, TransferPriority::Demand);
        let _ = h.wait_gpu(d);
        // The demand ran right after the in-flight prefetch, jumping the
        // two still-queued prefetches: 2 transfers total. By the demand's
        // completion instant the link has picked up the next prefetch, so
        // exactly one remains queued.
        assert_eq!(clock.now(), dur * 2);
        let (dq, pq) = h.queue_depth();
        assert_eq!((dq, pq), (0, 1), "one prefetch in flight, one still queued");
        h.shutdown();
    }

    #[test]
    #[allow(clippy::disallowed_methods)]
    fn real_time_mode_still_sleeps() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 2 ms base latency dominates: measurable but far under the
        // test-suite real-sleep budget.
        let pcie = PcieSim::new(1e9, 2e-3, 1.0);
        let h = TransferEngine::spawn(cache, pcie, store, SimClock::real_time());
        let k = ExpertKey::new(0, 0);
        // pallas-lint: allow(wall-clock, reason = "test asserts real-time mode genuinely sleeps")
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        let _ = h.wait_gpu(k);
        // pallas-lint: allow(wall-clock, reason = "the wall-clock bound is the assertion under test")
        let wall_s = t0.elapsed().as_secs_f64();
        assert!(wall_s > 0.0015, "stall must be real");
        h.shutdown();
    }

    #[test]
    fn transient_fetch_costs_virtual_time() {
        let (h, clock) = setup(2);
        let t0 = clock.now();
        let dur = h.transient_fetch(1 << 20);
        assert!(dur > Duration::ZERO);
        assert_eq!(clock.now() - t0, dur);
        assert_eq!(h.with_state(|st| st.pcie_stats().demand_transfers), 1);
        h.shutdown();
    }

    #[test]
    fn wait_gpu_reissues_lost_transfer() {
        // Regression: wait_gpu used to panic when the awaited expert had
        // no queued or in-flight transfer (request/wait racing a
        // cancellation). It must re-issue at demand priority instead.
        let (h, _) = setup(4);
        let busy = ExpertKey::new(0, 0);
        let k = ExpertKey::new(0, 2);
        // Occupy the link so the prefetch for `k` stays queued...
        h.request(busy, TransferPriority::Demand);
        h.request(k, TransferPriority::Prefetch);
        // ...then cancel it: the transfer vanishes, the slot returns to Cpu.
        assert!(h.cancel_prefetch(k));
        // Panicked before the fix; now surfaces exactly one re-issue.
        assert_eq!(h.wait_gpu(k), TransferOutcome::Retried(1));
        assert!(h.with_state(|st| st.is_gpu(k)));
        h.shutdown();
    }

    fn multi_setup(n_devices: usize) -> (TransferHandle, SimClock, Duration) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let devices: Vec<(ExpertCache, PcieSim)> = (0..n_devices)
            .map(|_| {
                (
                    ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru),
                    pcie.clone(),
                )
            })
            .collect();
        let placement = Placement::build(
            PlacementKind::LayerStriped,
            cfg.n_layers,
            cfg.n_experts,
            n_devices,
            None,
            1,
        );
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn_multi(
            devices,
            PcieSim::new(64e9, 3e-6, 1.0),
            Topology::new(n_devices, crate::topology::TopologyKind::FullyConnected),
            placement,
            store,
            clock.clone(),
        );
        (h, clock, dur)
    }

    #[test]
    fn per_device_links_transfer_in_parallel() {
        // Layer 0, experts 0 and 1 live on different striped devices: both
        // demand loads run concurrently on their own host links, so both
        // complete after ONE transfer duration (a single shared link would
        // serialize them to 2x — see virtual_link_serializes_transfers).
        let (h, clock, dur) = multi_setup(2);
        let a = ExpertKey::new(0, 0); // device 0
        let b = ExpertKey::new(0, 1); // device 1
        assert_eq!(h.with_state(|st| (st.home(a), st.home(b))), (0, 1));
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        let _ = h.wait_gpu(a);
        let _ = h.wait_gpu(b);
        assert_eq!(clock.now(), dur, "independent links must not serialize");
        assert!(h.with_state(|st| st.is_gpu(a) && st.is_gpu(b)));
        // Fleet-wide stats aggregate both links.
        assert_eq!(h.with_state(|st| st.pcie_stats().demand_transfers), 2);
        h.shutdown();
    }

    #[test]
    fn same_device_transfers_still_serialize() {
        // Experts 0 and 2 both live on device 0 under 2-way striping.
        let (h, clock, dur) = multi_setup(2);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 2);
        assert_eq!(h.with_state(|st| (st.home(a), st.home(b))), (0, 0));
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Demand);
        let _ = h.wait_gpu(b);
        assert_eq!(clock.now(), dur * 2, "one link still serializes");
        h.shutdown();
    }

    #[test]
    fn peer_dispatch_costs_time_and_records_traffic() {
        let (h, clock, _) = multi_setup(2);
        let t0 = clock.now();
        let d0 = h.peer_dispatch(4096, 0);
        assert_eq!(d0, Duration::ZERO, "zero hops are free");
        let d2 = h.peer_dispatch(4096, 2);
        assert!(d2 > Duration::ZERO);
        assert_eq!(clock.now() - t0, d2);
        let (bytes, transfers) = h.with_state(|st| {
            let s = st.peer_stats();
            (s.demand_bytes, s.demand_transfers)
        });
        assert_eq!(bytes, 8192, "two hops carry the bytes twice");
        assert_eq!(transfers, 2, "each hop is its own recorded transfer");
        h.shutdown();
    }

    #[test]
    fn peer_busy_seconds_match_charged_duration() {
        // Regression for the multi-hop accounting bug: a 2-hop dispatch
        // used to be recorded as ONE transfer of bytes*2, so the link's
        // recomputed busy time (one base latency) undercounted the charged
        // duration (two base latencies). Per-hop recording makes the two
        // agree exactly.
        let (h, _, _) = multi_setup(2);
        let d = h.peer_dispatch(4096, 3);
        let busy = h.with_state(|st| st.peer_stats().busy_seconds);
        assert!(
            (busy - d.as_secs_f64()).abs() < 1e-12,
            "busy {busy}s must equal charged {}s",
            d.as_secs_f64()
        );
        h.shutdown();
    }

    #[test]
    fn peer_link_is_contended() {
        // Two back-to-back dispatches on the shared fabric queue FIFO: the
        // second starts where the first ended, so the total virtual time is
        // the sum, not the max.
        let (h, clock, _) = multi_setup(2);
        let one = h.with_state(|st| st.peer_links[0].sim.transfer_duration(4096));
        let d1 = h.peer_dispatch(4096, 1);
        assert_eq!(d1, one);
        let d2 = h.peer_dispatch_routes(4096, &[(0, 1), (1, 0)]);
        // Both routes traverse the single shared link: serialized.
        assert_eq!(d2, one * 2, "same-link routes must queue behind each other");
        assert_eq!(clock.now(), one * 3);
        // A reservation made without advancing the clock (replica copy)
        // pushes later dispatches behind it.
        h.with_state(|st| {
            let now = clock.now();
            let edges = st.topology.peer_path(0, 1);
            super::reserve_peer_path(st, &edges, 4096, now);
        });
        let d3 = h.peer_dispatch(4096, 1);
        assert_eq!(d3, one * 2, "dispatch waits out the queued reservation");
        h.shutdown();
    }

    #[test]
    fn replica_promote_copies_over_peer_and_lands() {
        let (h, clock, _) = multi_setup(2);
        let k = ExpertKey::new(0, 0); // primary home: device 0
        h.request(k, TransferPriority::Demand);
        let _ = h.wait_gpu(k);
        assert!(h.replica_promote(k, 0, 1), "copy must start");
        assert!(
            !h.replica_promote(k, 0, 1),
            "target already receiving a copy"
        );
        // The copy is asynchronous: device 1 not resident yet, and the
        // peer link is reserved without the clock having moved.
        let (gpu1, busy) =
            h.with_state(|st| (st.devices[1].cache.is_gpu(k), st.peer_links[0].busy_until));
        assert!(!gpu1);
        assert!(busy > clock.now());
        clock.advance_to(busy);
        h.with_state(|st| {
            assert!(st.devices[1].cache.is_gpu(k), "copy lands at its ready instant");
            assert!(st.peer_stats().demand_transfers >= 1, "charged as real transfer");
        });
        // The staged weights arrive like any host transfer.
        assert!(h.drain_arrivals().unwrap().iter().any(|(key, _)| *key == k));
        h.shutdown();
    }

    #[test]
    fn replica_demote_cancels_or_drops() {
        let (h, clock, _) = multi_setup(2);
        let k = ExpertKey::new(0, 0);
        h.request(k, TransferPriority::Demand);
        let _ = h.wait_gpu(k);
        // Cancel an in-flight copy before it lands.
        assert!(h.replica_promote(k, 0, 1));
        assert!(h.replica_demote(k, 1), "in-flight copy must cancel");
        h.with_state(|st| {
            assert_eq!(st.devices[1].cache.state(k), SlotState::Cpu);
        });
        // Promote again, let it land, then drop the resident copy.
        assert!(h.replica_promote(k, 0, 1));
        let busy = h.with_state(|st| st.peer_links[0].busy_until);
        clock.advance_to(busy);
        h.drain_arrivals().unwrap();
        assert!(h.replica_demote(k, 1), "resident copy must demote");
        h.with_state(|st| assert!(!st.devices[1].cache.is_gpu(k)));
        assert!(h.drain_evictions().unwrap().contains(&k), "engine must drop buffers");
        // Demoting where no copy exists is a no-op success.
        assert!(h.replica_demote(k, 1));
        h.shutdown();
    }

    // ---- fault injection & bounded retry ----

    use crate::fault::{FaultEvent, FaultKind, FaultPlan};

    fn multi_setup_faulty(
        n_devices: usize,
        plan: &FaultPlan,
        tuning: TransferTuning,
    ) -> (TransferHandle, SimClock, Duration) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let pcie = PcieSim::new(1e9, 0.0, 1e6); // ~6.144 ms per transfer
        let dur = pcie.transfer_duration(store.expert_bytes);
        let devices: Vec<(ExpertCache, PcieSim)> = (0..n_devices)
            .map(|_| {
                (
                    ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru),
                    pcie.clone(),
                )
            })
            .collect();
        let placement = Placement::build(
            PlacementKind::LayerStriped,
            cfg.n_layers,
            cfg.n_experts,
            n_devices,
            None,
            1,
        );
        let clock = SimClock::virtual_clock();
        let h = TransferEngine::spawn_multi_with(
            devices,
            PcieSim::new(64e9, 3e-6, 1.0),
            Topology::new(n_devices, crate::topology::TopologyKind::FullyConnected),
            placement,
            store,
            clock.clone(),
            plan.timeline(),
            tuning,
        );
        (h, clock, dur)
    }

    fn at(at_s: f64, kind: FaultKind) -> FaultEvent {
        FaultEvent { at_s, kind }
    }

    #[test]
    fn cancel_prefetch_cannot_cancel_escalated_transfer() {
        // Regression: escalation moves the queue entry to the demand class;
        // a later cancel_prefetch for the same key must find nothing (it
        // only scans the prefetch queue), so an escalated transfer can
        // never be cancelled out from under a waiter.
        let (h, _) = setup(8);
        let busy = ExpertKey::new(0, 0);
        let k = ExpertKey::new(0, 2);
        h.request(busy, TransferPriority::Demand); // occupy the link
        h.request(k, TransferPriority::Prefetch); // stays queued
        h.escalate(k);
        assert!(!h.cancel_prefetch(k), "escalated transfer must be uncancellable");
        assert_eq!(h.wait_gpu(k), TransferOutcome::Ok, "the escalated demand still lands");
        assert!(h.with_state(|st| st.is_gpu(k)));
        h.shutdown();
    }

    #[test]
    fn lost_in_flight_transfer_is_retried() {
        // Kill the in-flight transfer mid-flight; the waiter re-issues it
        // (first retry immediate) and the load completes late.
        let plan = FaultPlan::from_events(vec![at(
            0.003,
            FaultKind::LoseInFlight { device: 0 },
        )]);
        let (h, clock, dur) = multi_setup_faulty(1, &plan, TransferTuning::default());
        let k = ExpertKey::new(0, 0);
        h.request(k, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(k), TransferOutcome::Retried(1));
        assert!(h.with_state(|st| st.is_gpu(k)));
        // Lost at 3 ms, re-issued there, full transfer again on top.
        assert_eq!(clock.now(), Duration::from_secs_f64(0.003) + dur);
        h.shutdown();
    }

    #[test]
    fn repeated_losses_back_off_with_seeded_jitter() {
        let plan = FaultPlan::from_events(vec![
            at(0.001, FaultKind::LoseInFlight { device: 0 }),
            at(0.002, FaultKind::LoseInFlight { device: 0 }),
        ]);
        let run = || {
            let (h, clock, dur) = multi_setup_faulty(1, &plan, TransferTuning::default());
            let k = ExpertKey::new(0, 0);
            h.request(k, TransferPriority::Demand);
            let out = h.wait_gpu(k);
            let t = clock.now();
            h.shutdown();
            (out, t, dur)
        };
        let (out1, t1, dur) = run();
        let (out2, t2, _) = run();
        assert_eq!(out1, TransferOutcome::Retried(2));
        assert_eq!((out1, t1), (out2, t2), "seeded backoff must be deterministic");
        // The second re-issue waits out a jittered backoff >= backoff_base
        // before a full transfer lands on top.
        let floor = Duration::from_secs_f64(0.002) + TransferTuning::default().backoff_base + dur;
        assert!(t1 >= floor, "backoff must burn virtual time ({t1:?} < {floor:?})");
    }

    #[test]
    fn deadline_expires_into_timeout_and_releases_the_slot() {
        // A 1-second host stall pins the link; a 10 ms deadline gives up
        // long before the transfer could start.
        let plan =
            FaultPlan::from_events(vec![at(0.0, FaultKind::HostStall { device: 0, duration_s: 1.0 })]);
        let tuning = TransferTuning {
            deadline: Some(Duration::from_millis(10)),
            ..TransferTuning::default()
        };
        let (h, clock, _) = multi_setup_faulty(1, &plan, tuning);
        let k = ExpertKey::new(0, 0);
        assert!(matches!(h.request(k, TransferPriority::Demand), LoadDecision::StartLoad { .. }));
        assert_eq!(h.wait_gpu(k), TransferOutcome::TimedOut);
        assert_eq!(clock.now(), Duration::from_millis(10), "gave up exactly at the deadline");
        h.with_state(|st| {
            assert_eq!(
                st.devices[0].cache.state(k),
                SlotState::Cpu,
                "the abandoned queued transfer must release its Loading slot"
            );
        });
        h.shutdown();
    }

    #[test]
    fn device_down_invalidates_and_refuses_work_until_up() {
        let plan = FaultPlan::from_events(vec![at(
            0.010,
            FaultKind::DeviceDown { device: 0, down_s: Some(0.020) },
        )]);
        let (h, clock, _) = multi_setup_faulty(2, &plan, TransferTuning::default());
        let a = ExpertKey::new(0, 0); // homed on device 0
        h.request(a, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(a), TransferOutcome::Ok);
        assert!(h.with_state(|st| st.is_gpu(a)));
        h.drain_arrivals().unwrap();
        // Cross the fault instant: residency is invalidated and the engine
        // is told to drop buffers.
        clock.advance_to(Duration::from_millis(15));
        assert!(!h.with_state(|st| st.is_gpu(a)), "down device counts no residency");
        assert!(h.drain_evictions().unwrap().contains(&a));
        // New work on the downed home is refused...
        assert_eq!(h.request(a, TransferPriority::Demand), LoadDecision::NoRoom);
        // ...a waiter on a vanished transfer times out instead of hanging...
        assert_eq!(h.wait_gpu(a), TransferOutcome::TimedOut);
        // ...and after recovery the expert is lazily re-admittable.
        clock.advance_to(Duration::from_millis(31));
        assert!(matches!(h.request(a, TransferPriority::Demand), LoadDecision::StartLoad { .. }));
        assert_eq!(h.wait_gpu(a), TransferOutcome::Ok);
        assert!(h.with_state(|st| st.is_gpu(a)));
        h.shutdown();
    }

    #[test]
    fn device_down_kills_queued_and_inflight_transfers() {
        let plan = FaultPlan::from_events(vec![at(
            0.002,
            FaultKind::DeviceDown { device: 0, down_s: None },
        )]);
        let (h, clock, _) = multi_setup_faulty(2, &plan, TransferTuning::default());
        let a = ExpertKey::new(0, 0); // device 0: goes in flight
        let b = ExpertKey::new(0, 2); // device 0: stays queued
        h.request(a, TransferPriority::Demand);
        h.request(b, TransferPriority::Prefetch);
        clock.advance_to(Duration::from_millis(30));
        h.with_state(|st| {
            assert_eq!(st.devices[0].cache.state(a), SlotState::Cpu, "in-flight load aborted");
            assert_eq!(st.devices[0].cache.state(b), SlotState::Cpu, "queued load aborted");
            assert!(st.is_down(0));
            assert_eq!(st.fault_epoch(), 1);
        });
        // Device 1 is unaffected.
        let c = ExpertKey::new(0, 1);
        h.request(c, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(c), TransferOutcome::Ok);
        h.shutdown();
    }

    #[test]
    fn last_live_device_cannot_go_down() {
        let plan = FaultPlan::from_events(vec![at(
            0.001,
            FaultKind::DeviceDown { device: 0, down_s: None },
        )]);
        let (h, clock, _) = multi_setup_faulty(1, &plan, TransferTuning::default());
        clock.advance_to(Duration::from_millis(10));
        h.with_state(|st| {
            assert!(!st.is_down(0), "the last live device must refuse to go down");
        });
        // The fleet still serves.
        let k = ExpertKey::new(0, 0);
        h.request(k, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(k), TransferOutcome::Ok);
        h.shutdown();
    }

    #[test]
    fn host_degrade_scales_bandwidth_and_restores_nominal() {
        let plan = FaultPlan::from_events(vec![at(
            0.0,
            FaultKind::HostDegrade { device: 0, multiplier: 0.5, duration_s: 0.050 },
        )]);
        let (h, clock, dur) = multi_setup_faulty(1, &plan, TransferTuning::default());
        let k = ExpertKey::new(0, 0);
        h.request(k, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(k), TransferOutcome::Ok);
        assert_eq!(clock.now(), dur * 2, "half bandwidth doubles the transfer time");
        clock.advance_to(Duration::from_millis(60));
        let k2 = ExpertKey::new(0, 1);
        let t0 = clock.now();
        h.request(k2, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(k2), TransferOutcome::Ok);
        assert_eq!(clock.now() - t0, dur, "bandwidth restored to nominal after the window");
        h.shutdown();
    }

    #[test]
    fn peer_flap_delays_replica_copies() {
        let plan = FaultPlan::from_events(vec![at(
            0.0,
            FaultKind::PeerFlap { link: 0, duration_s: 0.100 },
        )]);
        let (h, clock, _) = multi_setup_faulty(2, &plan, TransferTuning::default());
        let k = ExpertKey::new(0, 0);
        h.request(k, TransferPriority::Demand);
        assert_eq!(h.wait_gpu(k), TransferOutcome::Ok);
        assert!(h.replica_promote(k, 0, 1));
        let busy = h.with_state(|st| st.peer_links[0].busy_until);
        assert!(
            busy >= Duration::from_millis(100),
            "the copy must queue behind the flapped link ({busy:?})"
        );
        clock.advance_to(busy);
        assert!(h.with_state(|st| st.devices[1].cache.is_gpu(k)));
        h.shutdown();
    }
}
