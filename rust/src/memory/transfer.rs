//! The transfer engine: a background thread that serializes CPU->GPU
//! expert movement over the simulated PCIe link.
//!
//! Two priority classes share the link: **demand** loads (synchronous
//! misses — the pipeline is stalled on them) always preempt **prefetch**
//! loads (speculative). Completed transfers flip the cache slot to `Gpu`
//! and stage the host weights in an arrivals list the engine layer drains
//! to create device buffers.
//!
//! Transfers take *real wall-clock time* (the thread sleeps for the
//! simulated duration), so every latency/throughput number downstream is a
//! genuine elapsed-time measurement.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::memory::cache::{ExpertCache, LoadDecision};
use crate::memory::pcie::PcieSim;
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferPriority {
    Demand,
    Prefetch,
}

/// Cache + link + arrival/eviction mailboxes, all behind one mutex.
pub struct EngineState {
    pub cache: ExpertCache,
    pub pcie: PcieSim,
    pub arrivals: Vec<(ExpertKey, ExpertWeights)>,
    pub evictions: Vec<ExpertKey>,
    demand_q: VecDeque<ExpertKey>,
    prefetch_q: VecDeque<ExpertKey>,
    shutdown: bool,
}

pub struct Inner {
    state: Mutex<EngineState>,
    cv: Condvar,
}

pub type SharedCache = Arc<Inner>;

pub struct TransferEngine;

/// Handle owned by the serving engine; cloneable for the prefetcher.
#[derive(Clone)]
pub struct TransferHandle {
    inner: SharedCache,
    thread: Arc<Mutex<Option<JoinHandle<()>>>>,
}

impl TransferEngine {
    /// Spawn the engine thread. `time_scale` scales simulated sleeps
    /// (1.0 = real simulated durations; 0.0 = instant, for unit tests).
    pub fn spawn(
        cache: ExpertCache,
        pcie: PcieSim,
        store: Arc<WeightStore>,
        time_scale: f64,
    ) -> TransferHandle {
        let inner = Arc::new(Inner {
            state: Mutex::new(EngineState {
                cache,
                pcie,
                arrivals: Vec::new(),
                evictions: Vec::new(),
                demand_q: VecDeque::new(),
                prefetch_q: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let inner2 = inner.clone();
        let thread = std::thread::Builder::new()
            .name("pcie-transfer".into())
            .spawn(move || Self::run(inner2, store, time_scale))
            .expect("spawn transfer engine");
        TransferHandle { inner, thread: Arc::new(Mutex::new(Some(thread))) }
    }

    fn run(inner: SharedCache, store: Arc<WeightStore>, time_scale: f64) {
        loop {
            // Pop the next request (demand first), or wait.
            let (key, prefetch, duration) = {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if let Some(k) = st.demand_q.pop_front() {
                        let d = st.pcie.transfer_duration(store.expert_bytes);
                        break (k, false, d);
                    }
                    if let Some(k) = st.prefetch_q.pop_front() {
                        let d = st.pcie.transfer_duration(store.expert_bytes);
                        break (k, true, d);
                    }
                    st = inner.cv.wait(st).unwrap();
                }
            };
            // Simulate the PCIe occupancy in real time (lock released).
            if time_scale > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(
                    duration.as_secs_f64() * time_scale,
                ));
            }
            let weights = store
                .expert(key)
                .expect("transfer for unknown expert");
            let mut st = inner.state.lock().unwrap();
            st.pcie.record(store.expert_bytes, prefetch);
            st.cache.complete_load(key);
            st.arrivals.push((key, weights));
            inner.cv.notify_all();
        }
    }
}

impl TransferHandle {
    /// Run a closure with exclusive access to cache + link state.
    pub fn with_state<R>(&self, f: impl FnOnce(&mut EngineState) -> R) -> R {
        let mut st = self.inner.state.lock().unwrap();
        f(&mut st)
    }

    /// Request that `key` be brought to GPU. Returns the cache decision;
    /// enqueues a transfer (and records any eviction) when a load starts.
    pub fn request(&self, key: ExpertKey, prio: TransferPriority) -> LoadDecision {
        let mut st = self.inner.state.lock().unwrap();
        let decision = st.cache.request_load(key);
        if let LoadDecision::StartLoad { evicted } = decision {
            if let Some(v) = evicted {
                st.evictions.push(v);
            }
            match prio {
                TransferPriority::Demand => st.demand_q.push_back(key),
                TransferPriority::Prefetch => st.prefetch_q.push_back(key),
            }
            self.inner.cv.notify_all();
        }
        decision
    }

    /// Escalate an already-queued prefetch to demand priority (the
    /// verification step of the prefetch pipeline, Fig 3).
    pub fn escalate(&self, key: ExpertKey) {
        let mut st = self.inner.state.lock().unwrap();
        if let Some(pos) = st.prefetch_q.iter().position(|&k| k == key) {
            st.prefetch_q.remove(pos);
            st.demand_q.push_back(key);
            self.inner.cv.notify_all();
        }
    }

    /// Cancel a still-queued (not yet started) prefetch: the verification
    /// step discovered it is not needed. Returns true if it was dequeued.
    /// Saves PCIe occupancy that would otherwise serve speculative waste.
    pub fn cancel_prefetch(&self, key: ExpertKey) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        if let Some(pos) = st.prefetch_q.iter().position(|&k| k == key) {
            st.prefetch_q.remove(pos);
            st.cache.abort_load(key);
            true
        } else {
            false
        }
    }

    /// Block until `key` is GPU-resident (the synchronous miss stall).
    pub fn wait_gpu(&self, key: ExpertKey) {
        let mut st = self.inner.state.lock().unwrap();
        while !st.cache.is_gpu(key) {
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Drain completed transfers (engine layer creates device buffers).
    pub fn drain_arrivals(&self) -> Vec<(ExpertKey, ExpertWeights)> {
        std::mem::take(&mut self.inner.state.lock().unwrap().arrivals)
    }

    /// Drain evicted experts (engine layer drops device buffers).
    pub fn drain_evictions(&self) -> Vec<ExpertKey> {
        std::mem::take(&mut self.inner.state.lock().unwrap().evictions)
    }

    /// Number of queued (not yet started) transfers.
    pub fn queue_depth(&self) -> (usize, usize) {
        let st = self.inner.state.lock().unwrap();
        (st.demand_q.len(), st.prefetch_q.len())
    }

    pub fn shutdown(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.cv.notify_all();
        }
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::cache::EvictPolicy;

    fn setup(cap: usize) -> (TransferHandle, Arc<WeightStore>) {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, cap, EvictPolicy::Lru);
        let pcie = PcieSim::new(16e9, 1e-6, 1.0);
        let h = TransferEngine::spawn(cache, pcie, store.clone(), 0.0);
        (h, store)
    }

    #[test]
    fn demand_load_completes() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(0, 2);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        h.wait_gpu(k);
        assert!(h.with_state(|st| st.cache.is_gpu(k)));
        let arr = h.drain_arrivals();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].0, k);
        h.shutdown();
    }

    #[test]
    fn stats_recorded_per_class() {
        let (h, _) = setup(4);
        h.request(ExpertKey::new(0, 0), TransferPriority::Demand);
        h.request(ExpertKey::new(0, 1), TransferPriority::Prefetch);
        h.wait_gpu(ExpertKey::new(0, 0));
        h.wait_gpu(ExpertKey::new(0, 1));
        let (d, p) = h.with_state(|st| {
            (st.pcie.stats.demand_transfers, st.pcie.stats.prefetch_transfers)
        });
        assert_eq!((d, p), (1, 1));
        h.shutdown();
    }

    #[test]
    fn eviction_reported() {
        let (h, _) = setup(1);
        let a = ExpertKey::new(0, 0);
        let b = ExpertKey::new(0, 1);
        h.request(a, TransferPriority::Demand);
        h.wait_gpu(a);
        h.request(b, TransferPriority::Demand);
        h.wait_gpu(b);
        let ev = h.drain_evictions();
        assert_eq!(ev, vec![a]);
        h.shutdown();
    }

    #[test]
    fn duplicate_requests_coalesce() {
        let (h, _) = setup(4);
        let k = ExpertKey::new(1, 3);
        assert!(matches!(
            h.request(k, TransferPriority::Demand),
            LoadDecision::StartLoad { .. }
        ));
        // Second request while loading (or already loaded) never double-queues.
        let d2 = h.request(k, TransferPriority::Demand);
        assert!(matches!(
            d2,
            LoadDecision::AlreadyLoading | LoadDecision::AlreadyGpu
        ));
        h.wait_gpu(k);
        assert_eq!(h.drain_arrivals().len(), 1);
        h.shutdown();
    }

    #[test]
    fn escalate_moves_queue() {
        let (h, _) = setup(8);
        // Saturate with prefetches, then escalate the last one.
        for e in 0..4 {
            h.request(ExpertKey::new(2, e), TransferPriority::Prefetch);
        }
        h.escalate(ExpertKey::new(2, 3));
        h.wait_gpu(ExpertKey::new(2, 3));
        h.shutdown();
    }

    #[test]
    fn shutdown_idempotent() {
        let (h, _) = setup(2);
        h.shutdown();
        h.shutdown();
    }

    #[test]
    fn real_sleep_takes_time() {
        let cfg = ModelConfig::test_tiny();
        let store = Arc::new(WeightStore::synthetic(&cfg, 1));
        let cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, 4, EvictPolicy::Lru);
        // 6144 bytes/expert * 1e6 scale / 1e9 B/s ~= 6.1ms per transfer.
        let pcie = PcieSim::new(1e9, 0.0, 1e6);
        let h = TransferEngine::spawn(cache, pcie, store, 1.0);
        let k = ExpertKey::new(0, 0);
        let t0 = std::time::Instant::now();
        h.request(k, TransferPriority::Demand);
        h.wait_gpu(k);
        assert!(t0.elapsed().as_secs_f64() > 0.004, "stall must be real");
        h.shutdown();
    }
}
