//! PCIe link simulator.
//!
//! The paper's phenomena are scheduling phenomena: a CPU-resident expert
//! costs ~10 ms to fetch over a 16–32 GB/s link while its GPU compute costs
//! ~ms (paper §2.2, Table 1). This model reproduces exactly that structure:
//! a serialized link with `base_latency + bytes/bandwidth` per transfer,
//! with per-direction byte counters for the Fig 8 bandwidth analysis.
//!
//! Durations are *simulated*; how they are enforced depends on the
//! [`crate::util::clock::SimClock`] mode the transfer engine runs on.
//! Under a virtual clock (the default) each transfer advances the shared
//! virtual timeline — deterministic and instant in wall time — while under
//! a real-time clock the engine thread sleeps for the duration, so
//! measurements are genuine elapsed time. Either way the serialization and
//! priority semantics are identical.

use std::time::Duration;

/// Byte/transfer counters, split by cause (Fig 8 + speculative waste).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PcieStats {
    /// CPU->GPU bytes moved by on-demand (miss) loads.
    pub demand_bytes: u64,
    /// CPU->GPU bytes moved by prefetches.
    pub prefetch_bytes: u64,
    pub demand_transfers: u64,
    pub prefetch_transfers: u64,
    /// Total simulated seconds the link was busy.
    pub busy_seconds: f64,
}

impl PcieStats {
    pub fn total_bytes(&self) -> u64 {
        self.demand_bytes + self.prefetch_bytes
    }

    pub fn total_transfers(&self) -> u64 {
        self.demand_transfers + self.prefetch_transfers
    }

    /// Fold another link's counters into this one (aggregating per-device
    /// host links into one fleet-wide view).
    pub fn accumulate(&mut self, other: &PcieStats) {
        self.demand_bytes += other.demand_bytes;
        self.prefetch_bytes += other.prefetch_bytes;
        self.demand_transfers += other.demand_transfers;
        self.prefetch_transfers += other.prefetch_transfers;
        self.busy_seconds += other.busy_seconds;
    }
}

/// The link model. Cheap and `Send`; the transfer engine holds it behind a
/// mutex together with the cache.
#[derive(Debug, Clone)]
pub struct PcieSim {
    pub bandwidth_bytes_per_s: f64,
    pub base_latency_s: f64,
    /// Bytes scaling factor mapping mini-model experts onto the paper's
    /// expert sizes (see ServingConfig::transfer_bytes_scale).
    pub bytes_scale: f64,
    pub stats: PcieStats,
}

impl PcieSim {
    pub fn new(bandwidth_bytes_per_s: f64, base_latency_s: f64, bytes_scale: f64) -> Self {
        Self {
            bandwidth_bytes_per_s,
            base_latency_s,
            bytes_scale,
            stats: PcieStats::default(),
        }
    }

    /// Simulated duration of one transfer of `bytes` real bytes.
    pub fn transfer_duration(&self, bytes: usize) -> Duration {
        let s = self.base_latency_s
            + (bytes as f64 * self.bytes_scale) / self.bandwidth_bytes_per_s;
        Duration::from_secs_f64(s)
    }

    /// Record a completed transfer.
    pub fn record(&mut self, bytes: usize, prefetch: bool) {
        let d = self.transfer_duration(bytes).as_secs_f64();
        self.stats.busy_seconds += d;
        if prefetch {
            self.stats.prefetch_bytes += bytes as u64;
            self.stats.prefetch_transfers += 1;
        } else {
            self.stats.demand_bytes += bytes as u64;
            self.stats.demand_transfers += 1;
        }
    }

    /// Average read bandwidth over an observation window (bytes/s of
    /// *scaled* traffic) — the Fig 8 series.
    pub fn read_bandwidth_over(&self, window_s: f64) -> f64 {
        if window_s <= 0.0 {
            return 0.0;
        }
        self.stats.total_bytes() as f64 * self.bytes_scale / window_s
    }

    pub fn reset_stats(&mut self) {
        self.stats = PcieStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_model() {
        let p = PcieSim::new(16e9, 10e-6, 400.0);
        // dsv2-mini expert: 98304 bytes * 400 / 16e9 + 10us ~= 2.468 ms
        let d = p.transfer_duration(98304).as_secs_f64();
        assert!((d - (10e-6 + 98304.0 * 400.0 / 16e9)).abs() < 1e-9);
    }

    #[test]
    fn counters_split_by_cause() {
        let mut p = PcieSim::new(1e9, 0.0, 1.0);
        p.record(100, false);
        p.record(50, true);
        p.record(50, true);
        assert_eq!(p.stats.demand_bytes, 100);
        assert_eq!(p.stats.prefetch_bytes, 100);
        assert_eq!(p.stats.demand_transfers, 1);
        assert_eq!(p.stats.prefetch_transfers, 2);
        assert_eq!(p.stats.total_bytes(), 200);
        assert!(p.stats.busy_seconds > 0.0);
    }

    #[test]
    fn bandwidth_window() {
        let mut p = PcieSim::new(1e9, 0.0, 2.0);
        p.record(500, false);
        assert!((p.read_bandwidth_over(1.0) - 1000.0).abs() < 1e-9);
        assert_eq!(p.read_bandwidth_over(0.0), 0.0);
    }

    #[test]
    fn reset() {
        let mut p = PcieSim::new(1e9, 0.0, 1.0);
        p.record(10, false);
        p.reset_stats();
        assert_eq!(p.stats, PcieStats::default());
    }
}
