//! The offloading substrate: GPU residency accounting, per-device expert
//! caches with eviction policies, the PCIe link simulator, and the
//! background transfer engine that moves experts CPU -> GPU over each
//! device's own serialized host link (see `crate::topology` for the
//! device graph and the expert→device placement).
//!
//! Everything here is xla-free: "GPU residency" is an accounting state; the
//! engine layer (`model::engine`) owns the corresponding device buffers and
//! keeps them in sync with cache events.

mod cache;
mod pcie;
mod transfer;

pub use cache::{EvictPolicy, ExpertCache, LoadDecision, SlotState};
pub use pcie::{PcieSim, PcieStats};
pub use transfer::{
    DeviceState, EngineState, SharedCache, TransferEngine, TransferHandle, TransferOutcome,
    TransferPriority, TransferTuning,
};
