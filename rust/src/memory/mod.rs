//! The offloading substrate: GPU residency accounting, the expert cache
//! with eviction policies, the PCIe link simulator, and the background
//! transfer engine that moves experts CPU -> GPU.
//!
//! Everything here is xla-free: "GPU residency" is an accounting state; the
//! engine layer (`model::engine`) owns the corresponding device buffers and
//! keeps them in sync with cache events.

mod cache;
mod pcie;
mod transfer;

pub use cache::{EvictPolicy, ExpertCache, LoadDecision, SlotState};
pub use pcie::{PcieSim, PcieStats};
pub use transfer::{EngineState, SharedCache, TransferEngine, TransferHandle, TransferPriority};
