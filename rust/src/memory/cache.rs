//! The GPU expert cache: per-layer residency accounting with pluggable
//! eviction (paper §2.3's "expert cache"; EdgeMoE-style heuristics as one
//! policy option).
//!
//! States: `Cpu` (offloaded), `Loading` (in flight on the PCIe engine),
//! `Gpu` (resident and usable). Pinning protects experts scheduled in the
//! current micro-batch from eviction mid-step.

use anyhow::{bail, Result};

use crate::weights::ExpertKey;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Cpu,
    Loading,
    Gpu,
}

#[derive(Debug, Clone)]
struct Slot {
    state: SlotState,
    last_use: u64,
    uses: u64,
    pins: u32,
}

impl Default for Slot {
    fn default() -> Self {
        Self { state: SlotState::Cpu, last_use: 0, uses: 0, pins: 0 }
    }
}

/// Eviction policy for choosing a victim among GPU-resident experts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Least-recently used.
    Lru,
    /// Least-frequently used (activation count).
    Lfu,
    /// EdgeMoE-style: frequency weighted by layer depth — shallower layers
    /// are favoured in cache because they are reached first every step.
    FreqLayer,
}

impl EvictPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lru" => EvictPolicy::Lru,
            "lfu" => EvictPolicy::Lfu,
            "freq-layer" => EvictPolicy::FreqLayer,
            other => bail!("unknown eviction policy '{other}'"),
        })
    }
}

/// Outcome of a load request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadDecision {
    AlreadyGpu,
    AlreadyLoading,
    /// Caller should enqueue a transfer; `evicted` was demoted to make room.
    StartLoad { evicted: Option<ExpertKey> },
    /// No room: every resident expert in the layer is pinned.
    NoRoom,
}

#[derive(Debug)]
pub struct ExpertCache {
    n_layers: usize,
    n_experts: usize,
    capacity_per_layer: usize,
    policy: EvictPolicy,
    slots: Vec<Slot>, // [n_layers * n_experts]
    clock: u64,
}

impl ExpertCache {
    pub fn new(
        n_layers: usize,
        n_experts: usize,
        capacity_per_layer: usize,
        policy: EvictPolicy,
    ) -> Self {
        assert!(capacity_per_layer >= 1, "cache needs >= 1 slot per layer");
        Self {
            n_layers,
            n_experts,
            capacity_per_layer,
            policy,
            slots: vec![Slot::default(); n_layers * n_experts],
            clock: 0,
        }
    }

    fn idx(&self, k: ExpertKey) -> usize {
        debug_assert!(k.layer < self.n_layers && k.expert < self.n_experts);
        k.layer * self.n_experts + k.expert
    }

    pub fn capacity_per_layer(&self) -> usize {
        self.capacity_per_layer
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn state(&self, k: ExpertKey) -> SlotState {
        self.slots[self.idx(k)].state
    }

    pub fn is_gpu(&self, k: ExpertKey) -> bool {
        self.state(k) == SlotState::Gpu
    }

    /// Residency mask for one layer (Algorithm 1's M).
    pub fn residency_mask(&self, layer: usize) -> Vec<bool> {
        (0..self.n_experts)
            .map(|e| self.is_gpu(ExpertKey::new(layer, e)))
            .collect()
    }

    pub fn gpu_count(&self, layer: usize) -> usize {
        (0..self.n_experts)
            .filter(|&e| self.is_gpu(ExpertKey::new(layer, e)))
            .count()
    }

    /// Total routing hits recorded for one expert (live telemetry the
    /// online re-placement task ranks hot experts by).
    pub fn use_count(&self, k: ExpertKey) -> u64 {
        self.slots[self.idx(k)].uses
    }

    /// Record a use (routing hit) for recency/frequency bookkeeping.
    pub fn mark_use(&mut self, k: ExpertKey) {
        self.clock += 1;
        let clock = self.clock;
        let i = self.idx(k);
        self.slots[i].last_use = clock;
        self.slots[i].uses += 1;
    }

    pub fn pin(&mut self, k: ExpertKey) {
        let i = self.idx(k);
        self.slots[i].pins += 1;
    }

    pub fn unpin(&mut self, k: ExpertKey) {
        let i = self.idx(k);
        assert!(self.slots[i].pins > 0, "unpin without pin");
        self.slots[i].pins -= 1;
    }

    /// GPU-resident plus in-flight experts in one layer — the slots that
    /// count against `capacity_per_layer` (a `Loading` slot owns real GPU
    /// memory from the moment its transfer starts).
    fn occupied(&self, layer: usize) -> usize {
        (0..self.n_experts)
            .filter(|&e| {
                let s = self.state(ExpertKey::new(layer, e));
                s == SlotState::Gpu || s == SlotState::Loading
            })
            .count()
    }

    /// Ask to bring `k` onto the GPU. If the layer is full, a victim is
    /// selected by the eviction policy, demoted to Cpu, and reported so the
    /// engine can drop its device buffers.
    pub fn request_load(&mut self, k: ExpertKey) -> LoadDecision {
        self.request_load_protected(k, &[])
    }

    /// [`Self::request_load`] with an eviction shield: experts whose index
    /// is `true` in `protected` are never selected as victims (the fleet
    /// passes the replication-intent mask here, so a replica below its
    /// placement's home-set width cannot be evicted out from under it;
    /// only the sanctioned re-placement demotion path removes replicas).
    /// An empty mask protects nothing.
    pub fn request_load_protected(&mut self, k: ExpertKey, protected: &[bool]) -> LoadDecision {
        match self.state(k) {
            SlotState::Gpu => return LoadDecision::AlreadyGpu,
            SlotState::Loading => return LoadDecision::AlreadyLoading,
            SlotState::Cpu => {}
        }
        let evicted = if self.occupied(k.layer) >= self.capacity_per_layer {
            match self.select_victim(k.layer, protected) {
                Some(v) => {
                    let vi = self.idx(v);
                    self.slots[vi].state = SlotState::Cpu;
                    Some(v)
                }
                None => return LoadDecision::NoRoom,
            }
        } else {
            None
        };
        let i = self.idx(k);
        self.slots[i].state = SlotState::Loading;
        LoadDecision::StartLoad { evicted }
    }

    /// Transfer engine reports arrival.
    pub fn complete_load(&mut self, k: ExpertKey) {
        let i = self.idx(k);
        debug_assert_eq!(self.slots[i].state, SlotState::Loading);
        self.slots[i].state = SlotState::Gpu;
    }

    /// Abandon an in-flight load (failure injection / shutdown).
    pub fn abort_load(&mut self, k: ExpertKey) {
        let i = self.idx(k);
        if self.slots[i].state == SlotState::Loading {
            self.slots[i].state = SlotState::Cpu;
        }
    }

    /// Forcibly demote a GPU-resident, unpinned expert back to Cpu
    /// (benchmark/test harnesses re-creating miss pressure; not used on
    /// the serving path, which evicts via `request_load`). Returns whether
    /// the expert was demoted.
    pub fn demote(&mut self, k: ExpertKey) -> bool {
        let i = self.idx(k);
        if self.slots[i].state == SlotState::Gpu && self.slots[i].pins == 0 {
            self.slots[i].state = SlotState::Cpu;
            true
        } else {
            false
        }
    }

    /// Directly admit an expert (initial cache warm-up). `Loading` slots
    /// count against the layer budget exactly as in `request_load`: an
    /// in-flight transfer owns real GPU memory the moment it starts, so
    /// warm-up admits racing in-flight loads must not oversubscribe.
    pub fn admit(&mut self, k: ExpertKey) -> Result<()> {
        if self.occupied(k.layer) >= self.capacity_per_layer {
            bail!("layer {} cache full", k.layer);
        }
        let i = self.idx(k);
        self.slots[i].state = SlotState::Gpu;
        Ok(())
    }

    /// Device-failure invalidation: every unpinned `Gpu` slot and every
    /// `Loading` slot (pinned or not — its transfer is gone with the link)
    /// reverts to `Cpu`. Pinned `Gpu` slots survive: the in-flight decode
    /// step's activations already hold those weights, so faults act at step
    /// granularity for in-use experts. Returns the previously-`Gpu` keys so
    /// the engine can drop the matching device buffers.
    pub fn invalidate_unpinned(&mut self) -> Vec<ExpertKey> {
        let mut dropped = Vec::new();
        for l in 0..self.n_layers {
            for e in 0..self.n_experts {
                let k = ExpertKey::new(l, e);
                let i = self.idx(k);
                match self.slots[i].state {
                    SlotState::Gpu if self.slots[i].pins == 0 => {
                        self.slots[i].state = SlotState::Cpu;
                        dropped.push(k);
                    }
                    SlotState::Loading => self.slots[i].state = SlotState::Cpu,
                    _ => {}
                }
            }
        }
        dropped
    }

    fn select_victim(&self, layer: usize, protected: &[bool]) -> Option<ExpertKey> {
        let mut best: Option<(f64, ExpertKey)> = None;
        for e in 0..self.n_experts {
            let k = ExpertKey::new(layer, e);
            let s = &self.slots[self.idx(k)];
            if s.state != SlotState::Gpu || s.pins > 0 {
                continue;
            }
            if protected.get(e).copied().unwrap_or(false) {
                continue;
            }
            // Lower score = better victim.
            let score = match self.policy {
                EvictPolicy::Lru => s.last_use as f64,
                EvictPolicy::Lfu => s.uses as f64,
                EvictPolicy::FreqLayer => {
                    // EdgeMoE heuristic: deeper layers are cheaper to evict
                    // (they are needed later in the step), so discount score
                    // by depth.
                    s.uses as f64 / (1.0 + layer as f64)
                }
            };
            if best.map(|(b, _)| score < b).unwrap_or(true) {
                best = Some((score, k));
            }
        }
        best.map(|(_, k)| k)
    }

    /// Total GPU-resident experts (all layers).
    pub fn total_gpu(&self) -> usize {
        self.slots.iter().filter(|s| s.state == SlotState::Gpu).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    fn cache(cap: usize) -> ExpertCache {
        ExpertCache::new(2, 4, cap, EvictPolicy::Lru)
    }

    #[test]
    fn admit_until_full() {
        let mut c = cache(2);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        assert!(c.admit(k(0, 2)).is_err());
        assert_eq!(c.gpu_count(0), 2);
        assert_eq!(c.gpu_count(1), 0); // capacity is per layer
        c.admit(k(1, 0)).unwrap();
    }

    #[test]
    fn load_path_and_states() {
        let mut c = cache(2);
        assert_eq!(c.request_load(k(0, 0)), LoadDecision::StartLoad { evicted: None });
        assert_eq!(c.state(k(0, 0)), SlotState::Loading);
        assert_eq!(c.request_load(k(0, 0)), LoadDecision::AlreadyLoading);
        c.complete_load(k(0, 0));
        assert!(c.is_gpu(k(0, 0)));
        assert_eq!(c.request_load(k(0, 0)), LoadDecision::AlreadyGpu);
    }

    #[test]
    fn lru_eviction() {
        let mut c = cache(2);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        c.mark_use(k(0, 0));
        c.mark_use(k(0, 1));
        c.mark_use(k(0, 0)); // 1 is now LRU
        match c.request_load(k(0, 2)) {
            LoadDecision::StartLoad { evicted: Some(v) } => assert_eq!(v, k(0, 1)),
            other => panic!("{other:?}"),
        }
        assert_eq!(c.state(k(0, 1)), SlotState::Cpu);
    }

    #[test]
    fn lfu_eviction() {
        let mut c = ExpertCache::new(1, 4, 2, EvictPolicy::Lfu);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        for _ in 0..5 {
            c.mark_use(k(0, 0));
        }
        c.mark_use(k(0, 1));
        match c.request_load(k(0, 3)) {
            LoadDecision::StartLoad { evicted: Some(v) } => assert_eq!(v, k(0, 1)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pinned_never_evicted() {
        let mut c = cache(1);
        c.admit(k(0, 0)).unwrap();
        c.pin(k(0, 0));
        assert_eq!(c.request_load(k(0, 1)), LoadDecision::NoRoom);
        c.unpin(k(0, 0));
        assert!(matches!(
            c.request_load(k(0, 1)),
            LoadDecision::StartLoad { evicted: Some(_) }
        ));
    }

    #[test]
    fn loading_counts_toward_capacity() {
        let mut c = cache(2);
        assert!(matches!(c.request_load(k(0, 0)), LoadDecision::StartLoad { .. }));
        assert!(matches!(c.request_load(k(0, 1)), LoadDecision::StartLoad { evicted: None }));
        // Layer full with two in-flight loads; third must evict, but nothing
        // is Gpu yet -> NoRoom.
        assert_eq!(c.request_load(k(0, 2)), LoadDecision::NoRoom);
    }

    #[test]
    fn admit_counts_loading_toward_capacity() {
        // Regression: admit used to check only gpu_count, so a warm-up
        // admit plus an in-flight load could exceed capacity_per_layer.
        let mut c = cache(2);
        assert!(matches!(c.request_load(k(0, 0)), LoadDecision::StartLoad { .. }));
        c.admit(k(0, 1)).unwrap(); // 1 Loading + 1 Gpu == capacity
        assert!(
            c.admit(k(0, 2)).is_err(),
            "in-flight load owns a slot; a third admit must be refused"
        );
        c.complete_load(k(0, 0));
        assert!(c.admit(k(0, 2)).is_err(), "still full once the load lands");
        assert_eq!(c.gpu_count(0), 2);
    }

    #[test]
    fn protected_experts_never_selected_as_victims() {
        let mut c = cache(2);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        c.mark_use(k(0, 1));
        c.mark_use(k(0, 0)); // 1 is LRU and would normally be the victim
        let protected = vec![false, true, false, false];
        match c.request_load_protected(k(0, 2), &protected) {
            LoadDecision::StartLoad { evicted: Some(v) } => {
                assert_eq!(v, k(0, 0), "shielded LRU slot must be skipped");
            }
            other => panic!("{other:?}"),
        }
        assert!(c.is_gpu(k(0, 1)));
        // With every resident slot shielded there is no victim at all.
        let mut c = cache(1);
        c.admit(k(0, 0)).unwrap();
        assert_eq!(
            c.request_load_protected(k(0, 1), &[true, false, false, false]),
            LoadDecision::NoRoom
        );
    }

    #[test]
    fn use_count_tracks_hits() {
        let mut c = cache(2);
        c.admit(k(0, 0)).unwrap();
        assert_eq!(c.use_count(k(0, 0)), 0);
        c.mark_use(k(0, 0));
        c.mark_use(k(0, 0));
        assert_eq!(c.use_count(k(0, 0)), 2);
    }

    #[test]
    fn abort_load_returns_to_cpu() {
        let mut c = cache(2);
        c.request_load(k(0, 0));
        c.abort_load(k(0, 0));
        assert_eq!(c.state(k(0, 0)), SlotState::Cpu);
    }

    #[test]
    fn demote_only_touches_unpinned_gpu_slots() {
        let mut c = cache(3);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        c.pin(k(0, 1));
        assert!(c.demote(k(0, 0)));
        assert_eq!(c.state(k(0, 0)), SlotState::Cpu);
        assert!(!c.demote(k(0, 1)), "pinned expert must not demote");
        assert!(c.is_gpu(k(0, 1)));
        assert!(!c.demote(k(0, 2)), "Cpu slot demote is a no-op");
        c.request_load(k(0, 2));
        assert!(!c.demote(k(0, 2)), "Loading slot demote is a no-op");
    }

    #[test]
    fn invalidate_unpinned_spares_pinned_gpu_slots() {
        let mut c = cache(3);
        c.admit(k(0, 0)).unwrap();
        c.admit(k(0, 1)).unwrap();
        c.pin(k(0, 1));
        c.request_load(k(0, 2)); // Loading
        let dropped = c.invalidate_unpinned();
        assert_eq!(dropped, vec![k(0, 0)], "only unpinned Gpu slots are reported dropped");
        assert_eq!(c.state(k(0, 0)), SlotState::Cpu);
        assert!(c.is_gpu(k(0, 1)), "pinned in-use slot survives the fault");
        assert_eq!(c.state(k(0, 2)), SlotState::Cpu, "Loading slot loses its transfer");
        // The pin is preserved: unpin after the step still balances.
        c.unpin(k(0, 1));
    }

    #[test]
    fn admit_after_invalidation_does_not_double_count_loading_slots() {
        // Regression (device-down invalidation): a previously-Loading slot
        // flipped back to Cpu must stop counting toward layer occupancy, so
        // re-admission after the fault sees the true free space.
        let mut c = cache(2);
        assert!(matches!(c.request_load(k(0, 0)), LoadDecision::StartLoad { .. }));
        c.admit(k(0, 1)).unwrap();
        c.pin(k(0, 1));
        // Layer is full: 1 Loading + 1 Gpu.
        assert!(c.admit(k(0, 2)).is_err());
        c.invalidate_unpinned(); // k(0,0) Loading -> Cpu; k(0,1) pinned, survives
        assert_eq!(c.state(k(0, 0)), SlotState::Cpu);
        c.admit(k(0, 2))
            .expect("invalidated Loading slot must have released its capacity");
        assert!(c.admit(k(0, 3)).is_err(), "layer is genuinely full again");
        c.unpin(k(0, 1));
    }

    #[test]
    fn residency_mask_matches_states() {
        let mut c = cache(3);
        c.admit(k(0, 1)).unwrap();
        c.admit(k(0, 3)).unwrap();
        assert_eq!(c.residency_mask(0), vec![false, true, false, true]);
        assert_eq!(c.total_gpu(), 2);
    }
}
