//! Deterministic fault injection for the discrete-event fleet sim.
//!
//! A [`FaultPlan`] is a sorted list of [`FaultEvent`]s pinned to *virtual*
//! timestamps. The transfer engine expands the plan into a primitive
//! [`FaultTimeline`] at spawn and replays it inside `settle()`: whenever the
//! clock is about to advance past a fault's timestamp, the fleet is first
//! settled up to exactly that instant, then the fault mutates engine state as
//! one discrete event, then settling resumes. Faults are therefore totally
//! ordered against transfer starts/completions, exactly like every other
//! event in the sim.
//!
//! # Determinism rules
//!
//! - **No wall clock.** Fault timestamps come from the plan (virtual
//!   seconds); application points come from `SimClock`. Nothing in this
//!   module may read host time. Fault injection is only supported under
//!   `ClockMode::Virtual`.
//! - **Seeded jitter only.** The retry/backoff machinery in
//!   `memory/transfer.rs` draws jitter from a `util::rng::Rng` seeded from
//!   `ServingConfig.seed`; two runs with the same seed and the same plan are
//!   byte-identical.
//! - **Empty plan ⇒ byte-identical degenerate case.** With no events the
//!   timeline is never consulted, no RNG is advanced, and every code path
//!   reduces to the pre-fault behavior, so all existing golden sweeps are
//!   unchanged byte for byte.
//! - **A fault may only mutate engine-owned state**: device up/down flags,
//!   queued/in-flight transfer lists (aborting their `Loading` slots),
//!   cache residency (via `ExpertCache::invalidate_unpinned`), host-link
//!   bandwidth/busy horizons, and peer-link busy horizons. Faults never
//!   touch weights, routing state, or request state — recovery happens
//!   above, in the engine's degradation waterfall.

use std::time::Duration;

use crate::util::json::{Json, JsonError};

/// What a single fault does. User-level kinds carry their own duration where
/// the effect is a window (the timeline expands those into apply/restore
/// pairs).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Take a device out of service for `down_s` seconds (forever if `None`).
    /// Its queued and in-flight transfers are lost, its unpinned cache
    /// contents are invalidated, and it accepts no new transfers until it
    /// comes back up (empty, to be re-admitted lazily).
    DeviceDown { device: usize, down_s: Option<f64> },
    /// Scale a device's host-link bandwidth by `multiplier` (relative to the
    /// nominal bandwidth captured at spawn, so overlapping degrades do not
    /// compound) for `duration_s` seconds.
    HostDegrade { device: usize, multiplier: f64, duration_s: f64 },
    /// Stall a device's host link: no transfer may start on it until
    /// `duration_s` seconds after the event.
    HostStall { device: usize, duration_s: f64 },
    /// Flap a peer link: it is busy (down) for `duration_s` seconds.
    PeerFlap { link: usize, duration_s: f64 },
    /// Drop every in-flight host transfer on a device (the transfers' slots
    /// revert to CPU; waiters retry with backoff).
    LoseInFlight { device: usize },
}

/// One scheduled fault: `kind` fires at virtual time `at_s`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at_s: f64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, sorted by timestamp.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Build from events (sorts by timestamp; ties keep insertion order).
    pub fn from_events(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Self { events }
    }

    /// Parse a JSONL plan: one event object per non-empty line, e.g.
    ///
    /// ```text
    /// {"at_s": 1.0, "kind": "device-down", "device": 1, "duration_s": 2.0}
    /// {"at_s": 1.5, "kind": "host-degrade", "device": 0, "multiplier": 0.25, "duration_s": 1.0}
    /// {"at_s": 2.0, "kind": "host-stall", "device": 0, "duration_s": 0.05}
    /// {"at_s": 2.5, "kind": "peer-flap", "link": 0, "duration_s": 0.2}
    /// {"at_s": 3.0, "kind": "lose-inflight", "device": 2}
    /// ```
    ///
    /// `device-down` without `duration_s` downs the device permanently.
    pub fn parse_jsonl(text: &str) -> Result<Self, JsonError> {
        let mut events = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let j = Json::parse(line)?;
            let at_s = j.get("at_s")?.as_f64()?;
            let kind = match j.get("kind")?.as_str()? {
                "device-down" => FaultKind::DeviceDown {
                    device: j.get("device")?.as_usize()?,
                    down_s: match j.get("duration_s") {
                        Ok(v) => Some(v.as_f64()?),
                        Err(JsonError::MissingKey(_)) => None,
                        Err(e) => return Err(e),
                    },
                },
                "host-degrade" => FaultKind::HostDegrade {
                    device: j.get("device")?.as_usize()?,
                    multiplier: j.get("multiplier")?.as_f64()?,
                    duration_s: j.get("duration_s")?.as_f64()?,
                },
                "host-stall" => FaultKind::HostStall {
                    device: j.get("device")?.as_usize()?,
                    duration_s: j.get("duration_s")?.as_f64()?,
                },
                "peer-flap" => FaultKind::PeerFlap {
                    link: j.get("link")?.as_usize()?,
                    duration_s: j.get("duration_s")?.as_f64()?,
                },
                "lose-inflight" => FaultKind::LoseInFlight {
                    device: j.get("device")?.as_usize()?,
                },
                other => {
                    return Err(JsonError::Type { wanted: "known fault kind", got: kind_leak(other) })
                }
            };
            events.push(FaultEvent { at_s, kind });
        }
        Ok(Self::from_events(events))
    }

    /// Check the plan against a fleet shape. Returns a human-readable error
    /// for out-of-range devices/links or non-finite/negative numbers.
    pub fn validate(&self, n_devices: usize, n_peer_links: usize) -> Result<(), String> {
        for (i, ev) in self.events.iter().enumerate() {
            let ctx = |msg: String| format!("fault event {i}: {msg}");
            if !ev.at_s.is_finite() || ev.at_s < 0.0 {
                return Err(ctx(format!("at_s must be finite and >= 0, got {}", ev.at_s)));
            }
            let check_dur = |d: f64| {
                if !d.is_finite() || d < 0.0 {
                    Err(ctx(format!("duration_s must be finite and >= 0, got {d}")))
                } else {
                    Ok(())
                }
            };
            let check_dev = |d: usize| {
                if d >= n_devices {
                    Err(ctx(format!("device {d} out of range (n_devices {n_devices})")))
                } else {
                    Ok(())
                }
            };
            match &ev.kind {
                FaultKind::DeviceDown { device, down_s } => {
                    check_dev(*device)?;
                    if let Some(d) = down_s {
                        check_dur(*d)?;
                    }
                }
                FaultKind::HostDegrade { device, multiplier, duration_s } => {
                    check_dev(*device)?;
                    check_dur(*duration_s)?;
                    if !multiplier.is_finite() || *multiplier <= 0.0 {
                        return Err(ctx(format!(
                            "multiplier must be finite and > 0, got {multiplier}"
                        )));
                    }
                }
                FaultKind::HostStall { device, duration_s } => {
                    check_dev(*device)?;
                    check_dur(*duration_s)?;
                }
                FaultKind::PeerFlap { link, duration_s } => {
                    check_dur(*duration_s)?;
                    if *link >= n_peer_links {
                        return Err(ctx(format!(
                            "peer link {link} out of range (n_peer_links {n_peer_links})"
                        )));
                    }
                }
                FaultKind::LoseInFlight { device } => check_dev(*device)?,
            }
        }
        Ok(())
    }

    /// Fault windows `[start, end]` in virtual seconds: a device-down spans
    /// its down window, degrades/stalls/flaps span their durations, and an
    /// in-flight loss is a point window. Used to split counters into
    /// during-fault vs outside-fault buckets.
    pub fn windows(&self) -> Vec<(f64, f64)> {
        self.events
            .iter()
            .map(|ev| {
                let end = match &ev.kind {
                    FaultKind::DeviceDown { down_s, .. } => {
                        ev.at_s + down_s.unwrap_or(f64::INFINITY)
                    }
                    FaultKind::HostDegrade { duration_s, .. }
                    | FaultKind::HostStall { duration_s, .. }
                    | FaultKind::PeerFlap { duration_s, .. } => ev.at_s + duration_s,
                    FaultKind::LoseInFlight { .. } => ev.at_s,
                };
                (ev.at_s, end)
            })
            .collect()
    }

    /// Is virtual time `t` inside any fault window?
    pub fn in_window(&self, t: Duration) -> bool {
        let t = t.as_secs_f64();
        self.windows().iter().any(|&(a, b)| t >= a && t <= b)
    }

    /// Named scenario builders used by the fault sweep and CI. All assume a
    /// fleet of at least 2 devices; timestamps are virtual seconds chosen to
    /// land mid-sweep for the default load cells.
    pub fn scenario(name: &str) -> Option<Self> {
        let plan = match name {
            "baseline" => Self::empty(),
            "device-down" => Self::from_events(vec![FaultEvent {
                at_s: 1.0,
                kind: FaultKind::DeviceDown { device: 1, down_s: Some(2.0) },
            }]),
            "link-degrade" => Self::from_events(vec![FaultEvent {
                at_s: 1.0,
                kind: FaultKind::HostDegrade { device: 0, multiplier: 0.25, duration_s: 2.0 },
            }]),
            "flap" => Self::from_events(vec![
                FaultEvent {
                    at_s: 1.0,
                    kind: FaultKind::PeerFlap { link: 0, duration_s: 0.2 },
                },
                FaultEvent {
                    at_s: 1.6,
                    kind: FaultKind::PeerFlap { link: 0, duration_s: 0.2 },
                },
                FaultEvent {
                    at_s: 2.2,
                    kind: FaultKind::PeerFlap { link: 0, duration_s: 0.2 },
                },
            ]),
            "lose-inflight" => Self::from_events(vec![
                FaultEvent { at_s: 1.0, kind: FaultKind::LoseInFlight { device: 0 } },
                FaultEvent { at_s: 1.5, kind: FaultKind::LoseInFlight { device: 0 } },
            ]),
            _ => return None,
        };
        Some(plan)
    }

    /// Expand the user-level plan into the primitive apply/restore timeline
    /// the transfer engine replays.
    pub fn timeline(&self) -> FaultTimeline {
        let mut ticks = Vec::new();
        for ev in &self.events {
            let at = Duration::from_secs_f64(ev.at_s);
            match &ev.kind {
                FaultKind::DeviceDown { device, down_s } => {
                    ticks.push(FaultTick { at, action: FaultAction::DeviceDown { device: *device } });
                    if let Some(d) = down_s {
                        ticks.push(FaultTick {
                            at: Duration::from_secs_f64(ev.at_s + d),
                            action: FaultAction::DeviceUp { device: *device },
                        });
                    }
                }
                FaultKind::HostDegrade { device, multiplier, duration_s } => {
                    ticks.push(FaultTick {
                        at,
                        action: FaultAction::HostBandwidth { device: *device, multiplier: *multiplier },
                    });
                    ticks.push(FaultTick {
                        at: Duration::from_secs_f64(ev.at_s + duration_s),
                        action: FaultAction::HostBandwidth { device: *device, multiplier: 1.0 },
                    });
                }
                FaultKind::HostStall { device, duration_s } => {
                    ticks.push(FaultTick {
                        at,
                        action: FaultAction::HostStall {
                            device: *device,
                            until: Duration::from_secs_f64(ev.at_s + duration_s),
                        },
                    });
                }
                FaultKind::PeerFlap { link, duration_s } => {
                    ticks.push(FaultTick {
                        at,
                        action: FaultAction::PeerStall {
                            link: *link,
                            until: Duration::from_secs_f64(ev.at_s + duration_s),
                        },
                    });
                }
                FaultKind::LoseInFlight { device } => {
                    ticks.push(FaultTick { at, action: FaultAction::LoseInFlight { device: *device } });
                }
            }
        }
        ticks.sort_by_key(|t| t.at);
        FaultTimeline { ticks, next: 0 }
    }
}

// JsonError::Type wants a &'static str; unknown kinds come from user input,
// so leak the handful of bytes once rather than widen the error enum.
fn kind_leak(s: &str) -> &'static str {
    Box::leak(s.to_string().into_boxed_str())
}

/// Primitive, directly-applicable state mutation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    DeviceDown { device: usize },
    DeviceUp { device: usize },
    /// Set host-link bandwidth to `nominal * multiplier` (1.0 restores).
    HostBandwidth { device: usize, multiplier: f64 },
    /// Host link may not start a transfer before `until`.
    HostStall { device: usize, until: Duration },
    /// Peer link is busy until `until`.
    PeerStall { link: usize, until: Duration },
    LoseInFlight { device: usize },
}

/// One primitive mutation pinned to a virtual instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultTick {
    pub at: Duration,
    pub action: FaultAction,
}

/// The expanded, replayable schedule with a cursor. Owned by the transfer
/// engine's state; `settle()` drains ticks in timestamp order as the virtual
/// clock advances past them.
#[derive(Debug, Clone, Default)]
pub struct FaultTimeline {
    ticks: Vec<FaultTick>,
    next: usize,
}

impl FaultTimeline {
    /// Any ticks left to apply?
    pub fn is_active(&self) -> bool {
        self.next < self.ticks.len()
    }

    /// The next tick at or before `now`, if any (does not advance).
    pub fn peek_due(&self, now: Duration) -> Option<FaultTick> {
        self.ticks.get(self.next).filter(|t| t.at <= now).copied()
    }

    /// Advance past the tick returned by `peek_due`.
    pub fn pop(&mut self) {
        self.next += 1;
    }

    /// Timestamp of the next unapplied tick (for event-horizon computation).
    pub fn next_at(&self) -> Option<Duration> {
        self.ticks.get(self.next).map(|t| t.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        assert!(p.windows().is_empty());
        assert!(!p.in_window(Duration::from_secs(1)));
        let tl = p.timeline();
        assert!(!tl.is_active());
        assert!(tl.next_at().is_none());
    }

    #[test]
    fn parse_jsonl_roundtrip() {
        let text = r#"
            {"at_s": 1.0, "kind": "device-down", "device": 1, "duration_s": 2.0}
            # comment line
            {"at_s": 0.5, "kind": "host-degrade", "device": 0, "multiplier": 0.25, "duration_s": 1.0}
            {"at_s": 2.0, "kind": "peer-flap", "link": 0, "duration_s": 0.2}
            {"at_s": 3.0, "kind": "lose-inflight", "device": 2}
            {"at_s": 4.0, "kind": "host-stall", "device": 1, "duration_s": 0.05}
            {"at_s": 5.0, "kind": "device-down", "device": 0}
        "#;
        let p = FaultPlan::parse_jsonl(text).unwrap();
        assert_eq!(p.events().len(), 6);
        // Sorted by timestamp.
        assert_eq!(p.events()[0].at_s, 0.5);
        assert!(matches!(p.events()[1].kind, FaultKind::DeviceDown { device: 1, down_s: Some(d) } if d == 2.0));
        // Missing duration means permanent.
        assert!(matches!(p.events()[5].kind, FaultKind::DeviceDown { device: 0, down_s: None }));
    }

    #[test]
    fn parse_rejects_unknown_kind() {
        assert!(FaultPlan::parse_jsonl(r#"{"at_s": 0, "kind": "meteor-strike"}"#).is_err());
    }

    #[test]
    fn validate_bounds() {
        let p = FaultPlan::from_events(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::DeviceDown { device: 4, down_s: None },
        }]);
        assert!(p.validate(4, 1).is_err());
        assert!(p.validate(5, 1).is_ok());

        let p = FaultPlan::from_events(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::PeerFlap { link: 3, duration_s: 0.1 },
        }]);
        assert!(p.validate(4, 3).is_err());
        assert!(p.validate(4, 4).is_ok());

        let p = FaultPlan::from_events(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::HostDegrade { device: 0, multiplier: 0.0, duration_s: 1.0 },
        }]);
        assert!(p.validate(1, 1).is_err());

        let p = FaultPlan::from_events(vec![FaultEvent {
            at_s: -1.0,
            kind: FaultKind::LoseInFlight { device: 0 },
        }]);
        assert!(p.validate(1, 1).is_err());
    }

    #[test]
    fn windows_and_membership() {
        let p = FaultPlan::from_events(vec![
            FaultEvent { at_s: 1.0, kind: FaultKind::DeviceDown { device: 0, down_s: Some(2.0) } },
            FaultEvent { at_s: 5.0, kind: FaultKind::HostStall { device: 0, duration_s: 0.5 } },
        ]);
        assert_eq!(p.windows(), vec![(1.0, 3.0), (5.0, 5.5)]);
        assert!(!p.in_window(Duration::from_secs_f64(0.9)));
        assert!(p.in_window(Duration::from_secs_f64(2.0)));
        assert!(!p.in_window(Duration::from_secs_f64(4.0)));
        assert!(p.in_window(Duration::from_secs_f64(5.25)));
    }

    #[test]
    fn timeline_expands_windows_into_pairs() {
        let p = FaultPlan::from_events(vec![FaultEvent {
            at_s: 1.0,
            kind: FaultKind::HostDegrade { device: 0, multiplier: 0.5, duration_s: 2.0 },
        }]);
        let mut tl = p.timeline();
        assert!(tl.is_active());
        assert_eq!(tl.next_at(), Some(Duration::from_secs_f64(1.0)));
        assert!(tl.peek_due(Duration::from_secs_f64(0.5)).is_none());
        let t0 = tl.peek_due(Duration::from_secs_f64(1.5)).unwrap();
        assert!(
            matches!(t0.action, FaultAction::HostBandwidth { device: 0, multiplier } if multiplier == 0.5)
        );
        tl.pop();
        let t1 = tl.peek_due(Duration::from_secs_f64(10.0)).unwrap();
        assert_eq!(t1.at, Duration::from_secs_f64(3.0));
        assert!(
            matches!(t1.action, FaultAction::HostBandwidth { device: 0, multiplier } if multiplier == 1.0)
        );
        tl.pop();
        assert!(!tl.is_active());
    }

    #[test]
    fn scenarios_exist() {
        for name in ["baseline", "device-down", "link-degrade", "flap", "lose-inflight"] {
            let p = FaultPlan::scenario(name).unwrap();
            assert!(p.validate(4, 4).is_ok(), "scenario {name} invalid");
        }
        assert!(FaultPlan::scenario("nope").is_none());
        assert!(FaultPlan::scenario("baseline").unwrap().is_empty());
    }
}
