//! Row-major f32 host tensor used throughout the coordinator for
//! activations, KV caches, and weight staging — plus [`TensorView`], the
//! borrowed counterpart the hot path uses to read tensor data in place
//! (PR 5: zero-copy KV views).
//!
//! [`alloc_probe`] counts tensor-buffer constructions so tests can assert
//! allocation budgets on the decode hot path (see
//! `tests/zero_copy_decode.rs`).

use anyhow::{bail, Result};

/// Process-wide probe of tensor-buffer constructions (relaxed atomics;
/// negligible cost). Every path that materializes a fresh tensor buffer —
/// [`Tensor::new`], [`Tensor::zeros`], [`Tensor::gather_rows`],
/// [`Tensor::pad_rows`], and `Tensor::clone` (implemented manually so a
/// clone-based copy can't dodge the probe) — notes (1 tensor, n f32
/// elements); pooled-scratch
/// reuse ([`Tensor::reset_zeros`], the arena) does not. Tests diff
/// [`alloc_probe::snapshot`] around a region to bound its allocations;
/// counters are global, so such tests must serialize against other
/// tensor-allocating tests in the same process.
pub mod alloc_probe {
    use std::sync::atomic::{AtomicU64, Ordering};

    static TENSORS: AtomicU64 = AtomicU64::new(0);
    static ELEMS: AtomicU64 = AtomicU64::new(0);

    pub(super) fn note(n_elems: usize) {
        TENSORS.fetch_add(1, Ordering::Relaxed);
        ELEMS.fetch_add(n_elems as u64, Ordering::Relaxed);
    }

    /// (tensor buffers constructed, f32 elements allocated) since process
    /// start. Monotonic; diff two snapshots to measure a region.
    pub fn snapshot() -> (u64, u64) {
        (TENSORS.load(Ordering::Relaxed), ELEMS.load(Ordering::Relaxed))
    }
}

/// An owned row-major f32 tensor. `Default` is an empty placeholder for
/// pooled-scratch slots; call [`Tensor::reset_zeros`] before use.
#[derive(Debug, PartialEq, Default)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Clone for Tensor {
    /// Manual so the fresh buffer is visible to [`alloc_probe`] — a
    /// clone-based reintroduction of a KV-sized copy must not dodge the
    /// zero-copy regression tests.
    fn clone(&self) -> Self {
        alloc_probe::note(self.data.len());
        Self { dims: self.dims.clone(), data: self.data.clone() }
    }
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        alloc_probe::note(data.len());
        Ok(Self { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n: usize = dims.iter().product();
        alloc_probe::note(n);
        Self { dims, data: vec![0.0; n] }
    }

    /// Reset to `dims`, zero-filled, reusing the existing allocation — the
    /// pooled-scratch path. Not counted by [`alloc_probe`]; capacity is
    /// retained across uses, so steady-state reuse is allocation-free.
    pub fn reset_zeros(&mut self, dims: &[usize]) {
        let n: usize = dims.iter().product();
        self.dims.clear();
        self.dims.extend_from_slice(dims);
        self.data.clear();
        self.data.resize(n, 0.0);
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2, "row() needs rank-2");
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2, "row_mut() needs rank-2");
        let w = self.dims[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Gather rows into a new [idx.len(), W] tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.rank(), 2, "gather_rows() needs rank-2");
        let w = self.dims[1];
        let mut data = Vec::with_capacity(idx.len() * w);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        alloc_probe::note(data.len());
        Tensor { dims: vec![idx.len(), w], data }
    }

    /// Pad the leading dimension up to `n` rows with zeros (bucket
    /// padding). Single allocation at the final size.
    pub fn pad_rows(&self, n: usize) -> Tensor {
        assert_eq!(self.rank(), 2, "pad_rows() needs rank-2");
        assert!(n >= self.dims[0]);
        let w = self.dims[1];
        let mut data = Vec::with_capacity(n * w);
        data.extend_from_slice(&self.data);
        data.resize(n * w, 0.0);
        alloc_probe::note(data.len());
        Tensor { dims: vec![n, w], data }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// A borrowed, immutable view of a row-major f32 tensor: `dims` and
/// `data` reference storage owned elsewhere — a [`Tensor`], an arena
/// scratch buffer, a stack-held dims array. Constructing one never copies
/// or allocates, which is the point: the decode hot path hands views
/// across the stage boundary instead of assembling owned tensors.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    pub dims: &'a [usize],
    pub data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn new(dims: &'a [usize], data: &'a [f32]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("view shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(Self { dims, data })
    }

    pub fn from_tensor(t: &'a Tensor) -> Self {
        Self { dims: &t.dims, data: &t.data }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 view. The returned slice borrows the backing
    /// storage (`'a`), not the view, so it may outlive `self`.
    pub fn row(&self, i: usize) -> &'a [f32] {
        assert_eq!(self.rank(), 2, "row() needs rank-2");
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_gather() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[3., 4.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.dims, vec![2, 2]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    #[should_panic(expected = "gather_rows() needs rank-2")]
    fn gather_rows_rejects_non_rank2() {
        // Seed bug: rank-3 input silently used dims[1] as the row width,
        // gathering garbage stripes instead of logical rows.
        let t = Tensor::zeros(vec![2, 3, 4]);
        let _ = t.gather_rows(&[0]);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let t = Tensor::new(vec![1, 2], vec![7., 8.]).unwrap();
        let p = t.pad_rows(3);
        assert_eq!(p.dims, vec![3, 2]);
        assert_eq!(p.data, vec![7., 8., 0., 0., 0., 0.]);
    }

    #[test]
    fn pad_rows_matches_clone_resize_reference() {
        // The with_capacity+extend build must be behavior-identical to the
        // seed's clone-then-resize (which copied the data twice).
        for (rows, w, n) in [(1usize, 5usize, 4usize), (3, 2, 3), (2, 7, 6)] {
            let t =
                Tensor::new(vec![rows, w], (0..rows * w).map(|i| i as f32 * 0.5).collect())
                    .unwrap();
            let got = t.pad_rows(n);
            let mut want = t.data.clone();
            want.resize(n * w, 0.0);
            assert_eq!(got.dims, vec![n, w]);
            assert_eq!(got.data, want);
        }
    }

    #[test]
    fn reset_zeros_reuses_allocation() {
        let mut t = Tensor::zeros(vec![4, 8]);
        t.data.iter_mut().for_each(|v| *v = 1.0);
        let cap = t.data.capacity();
        t.reset_zeros(&[2, 8]);
        assert_eq!(t.dims, vec![2, 8]);
        assert!(t.data.iter().all(|&v| v == 0.0));
        assert_eq!(t.data.capacity(), cap, "shrinking reset must keep capacity");
    }

    #[test]
    fn view_rows_match_tensor() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let v = TensorView::from_tensor(&t);
        assert_eq!(v.rank(), 2);
        assert_eq!(v.len(), 6);
        for i in 0..3 {
            assert_eq!(v.row(i), t.row(i));
        }
        // A raw-slice view (the arena-scratch shape) agrees too.
        let dims = [3usize, 2];
        let v2 = TensorView::new(&dims, &t.data).unwrap();
        assert_eq!(v2.row(2), &[5., 6.]);
        assert!(TensorView::new(&dims, &t.data[..4]).is_err());
    }

    #[test]
    fn diff() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 2.0]).unwrap();
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn alloc_probe_counts_constructions() {
        let (t0, e0) = alloc_probe::snapshot();
        let _a = Tensor::zeros(vec![2, 3]);
        let (t1, e1) = alloc_probe::snapshot();
        assert!(t1 >= t0 + 1);
        assert!(e1 >= e0 + 6);
    }
}
