//! Row-major f32 host tensor used throughout the coordinator for
//! activations, KV caches, and weight staging.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(Self { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n: usize = dims.iter().product();
        Self { dims, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2, "row() needs rank-2");
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2, "row_mut() needs rank-2");
        let w = self.dims[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Gather rows into a new [idx.len(), W] tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let w = self.dims[1];
        let mut data = Vec::with_capacity(idx.len() * w);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor { dims: vec![idx.len(), w], data }
    }

    /// Pad the leading dimension up to `n` rows with zeros (bucket padding).
    pub fn pad_rows(&self, n: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert!(n >= self.dims[0]);
        let w = self.dims[1];
        let mut data = self.data.clone();
        data.resize(n * w, 0.0);
        Tensor { dims: vec![n, w], data }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_gather() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[3., 4.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.dims, vec![2, 2]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let t = Tensor::new(vec![1, 2], vec![7., 8.]).unwrap();
        let p = t.pad_rows(3);
        assert_eq!(p.dims, vec![3, 2]);
        assert_eq!(p.data, vec![7., 8., 0., 0., 0., 0.]);
    }

    #[test]
    fn diff() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 2.0]).unwrap();
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }
}
