//! Scoped-thread data parallelism for the reference-backend kernels and
//! the engine's expert fan-out (no thread-pool crate offline; plain
//! `std::thread::scope`).
//!
//! The determinism contract: work units are independent (disjoint output
//! rows / independent tasks) and compute bitwise-identical results on any
//! thread, so output is byte-identical at every thread count — the golden
//! virtual-clock sweeps must not change under `PALLAS_THREADS=4`
//! (asserted in `tests/kernel_equivalence.rs`).
//!
//! Thread count resolution, in priority order:
//! 1. [`set_threads`] runtime override (benches / tests; `0` clears it),
//! 2. the `PALLAS_THREADS` environment variable (read once),
//! 3. `std::thread::available_parallelism()`.
//!
//! Fan-out only happens when the estimated work amortizes the scoped
//! spawn cost (see [`MIN_WORK_PER_THREAD`]); tiny kernels stay inline, so
//! the test-sized models never pay threading overhead.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

thread_local! {
    /// True on threads spawned by this module. Nested fan-out (a kernel
    /// called from an engine-level worker) runs inline instead of
    /// multiplying thread counts — the outer fan-out already owns the
    /// core budget.
    static IN_PAR_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn in_worker() -> bool {
    IN_PAR_WORKER.with(|c| c.get())
}

/// Minimum inner-loop operations per worker before fan-out pays for a
/// scoped thread spawn (~10 us each on Linux). `1 << 16` f32 FMAs is a
/// few tens of microseconds of work — roughly break-even at two workers.
pub const MIN_WORK_PER_THREAD: usize = 1 << 16;

static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the thread count at runtime (benches / tests). `0` restores
/// the `PALLAS_THREADS` / `available_parallelism` default. Changing this
/// mid-run is safe: it alters scheduling, never results.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::SeqCst);
}

/// The configured maximum worker count (>= 1).
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o != 0 {
        return o;
    }
    static FROM_ENV: OnceLock<usize> = OnceLock::new();
    *FROM_ENV.get_or_init(|| {
        std::env::var("PALLAS_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Workers to actually use for `items` units of ~`work_per_item`
/// inner-loop operations each: capped by the configured thread count, the
/// item count, and the spawn-amortization floor. Always 1 on a thread
/// that is itself a par worker (no nested fan-out).
pub fn plan_threads(items: usize, work_per_item: usize) -> usize {
    if items == 0 || in_worker() {
        return 1;
    }
    let by_work = (items.saturating_mul(work_per_item) / MIN_WORK_PER_THREAD).max(1);
    num_threads().min(items).min(by_work).max(1)
}

/// Split `out` — `rows` rows of `out.len() / rows` elements — into
/// contiguous row chunks and run `f(first_row, chunk)` on each, fanning
/// out when `rows * work_per_row` warrants it. Rows are never split, so
/// each output element is produced by exactly one worker.
pub fn par_rows<F>(out: &mut [f32], rows: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if rows == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % rows, 0, "out must be rows * width");
    let w = out.len() / rows;
    let threads = plan_threads(rows, work_per_row);
    if threads <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(chunk_rows * w).enumerate() {
            let f = &f;
            s.spawn(move || {
                IN_PAR_WORKER.with(|c| c.set(true));
                f(ci * chunk_rows, chunk)
            });
        }
    });
}

/// Run `n` independent tasks of ~`work_per_item` operations each and
/// collect their results in task order, fanning out over contiguous index
/// ranges when the work warrants it.
pub fn par_map<T, F>(n: usize, work_per_item: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = plan_threads(n, work_per_item);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, slots) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                IN_PAR_WORKER.with(|c| c.set(true));
                for (k, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + k));
                }
            });
        }
    });
    out.into_iter().map(|t| t.expect("par_map worker filled its slots")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn plan_keeps_small_work_inline() {
        // 8 rows of 100 ops is far under the spawn floor.
        assert_eq!(plan_threads(8, 100), 1);
        assert_eq!(plan_threads(0, 1_000_000), 1);
    }

    #[test]
    fn par_rows_covers_every_row_once() {
        let rows = 37;
        let w = 5;
        let mut out = vec![0.0f32; rows * w];
        // Force enough planned work that fan-out triggers when >1 core.
        par_rows(&mut out, rows, MIN_WORK_PER_THREAD, |row0, chunk| {
            for (ri, r) in chunk.chunks_mut(w).enumerate() {
                for x in r.iter_mut() {
                    *x += (row0 + ri) as f32;
                }
            }
        });
        for r in 0..rows {
            for j in 0..w {
                assert_eq!(out[r * w + j], r as f32, "row {r} col {j}");
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let got = par_map(23, MIN_WORK_PER_THREAD, |i| i * i);
        let want: Vec<usize> = (0..23).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_empty() {
        let got: Vec<usize> = par_map(0, 1, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn nested_fanout_runs_inline() {
        // From inside a par worker (or a 1-thread plan), further fan-out
        // must collapse to a single thread — no thread multiplication.
        let mut out = vec![0.0f32; 8];
        par_rows(&mut out, 8, MIN_WORK_PER_THREAD, |_, chunk| {
            assert_eq!(plan_threads(64, MIN_WORK_PER_THREAD), 1);
            chunk.fill(1.0);
        });
        assert!(out.iter().all(|&x| x == 1.0));
    }
}
