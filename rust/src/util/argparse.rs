//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

#[derive(Debug, Default)]
pub struct ArgSpec {
    prog: String,
    about: String,
    opts: Vec<OptSpec>,
}

impl ArgSpec {
    pub fn new(prog: &str, about: &str) -> Self {
        Self { prog: prog.into(), about: about.into(), opts: Vec::new() }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// `--name <value>` option that must be provided.
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.prog, self.about);
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None if o.is_flag => String::new(),
                None => " [required]".to_string(),
            };
            out.push_str(&format!("{lhs:28} {}{def}\n", o.help));
        }
        out
    }

    /// Parse a raw arg list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .with_context(|| format!("unknown option --{key}\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.insert(key, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .with_context(|| format!("--{key} needs a value"))?
                                .clone()
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                bail!("missing required option --{}\n{}", o.name, self.usage());
            }
        }
        Ok(Args { values, flags, positional })
    }

    pub fn parse_env(&self) -> Result<Args> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&args)
    }
}

#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .with_context(|| format!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .with_context(|| format!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .with_context(|| format!("--{name} must be a number"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("alpha", "0.5", "alpha")
            .required("path", "a path")
            .flag("verbose", "talk more")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&sv(&["--path", "x"])).unwrap();
        assert_eq!(a.get("alpha"), "0.5");
        assert_eq!(a.get("path"), "x");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = spec()
            .parse(&sv(&["--path=y", "--alpha=0.9", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_f64("alpha").unwrap(), 0.9);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(spec().parse(&sv(&["--alpha", "1"])).is_err());
    }

    #[test]
    fn unknown_option_fails() {
        assert!(spec().parse(&sv(&["--path", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse(&sv(&["serve", "--path", "x"])).unwrap();
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn value_missing_fails() {
        assert!(spec().parse(&sv(&["--path"])).is_err());
    }

    #[test]
    fn flag_with_value_fails() {
        assert!(spec().parse(&sv(&["--path=x", "--verbose=1"])).is_err());
    }
}
