//! Simulation clock: one time source for the whole serving stack.
//!
//! Every time consumer in the system — the PCIe transfer engine, the
//! engine's compute-time model, batcher deadlines, server metrics, request
//! timestamps, and the eval harness — reads time from a [`SimClock`]
//! instead of `Instant::now()`. The clock runs in one of two modes:
//!
//! * [`ClockMode::Virtual`] — discrete-event time. `now()` returns a
//!   virtual duration since the clock's epoch; nothing ever sleeps.
//!   Components *advance* the clock by their modeled cost (a PCIe transfer,
//!   a decode step's compute), so a full Tables 2–4 sweep that used to take
//!   minutes of real sleeping completes in milliseconds, and the same seed
//!   produces byte-identical timelines (the golden-report tests rely on
//!   this).
//! * [`ClockMode::RealTime`] — wall-clock time. `now()` is elapsed real
//!   time since construction, `sleep()` really sleeps, and `advance()` is a
//!   no-op (real work already takes real time). This is the mode for
//!   genuine elapsed-time measurements on hardware.
//!
//! The clock is cheap to clone (it is a handle onto shared state) and
//! thread-safe; in virtual mode it is a monotone counter behind a mutex.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How the serving stack experiences time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClockMode {
    /// Discrete-event virtual time: deterministic, never sleeps.
    #[default]
    Virtual,
    /// Wall-clock time: sleeps are real, measurements are real.
    RealTime,
}

impl ClockMode {
    pub fn name(&self) -> &'static str {
        match self {
            ClockMode::Virtual => "virtual",
            ClockMode::RealTime => "real-time",
        }
    }
}

enum Inner {
    Virtual(Mutex<Duration>),
    Real(Instant),
}

/// Shared time source (cheap clone; all clones observe the same timeline).
#[derive(Clone)]
pub struct SimClock {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for SimClock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &*self.inner {
            Inner::Virtual(now) => {
                write!(f, "SimClock::Virtual({:?})", *now.lock().unwrap())
            }
            Inner::Real(epoch) => write!(f, "SimClock::Real(+{:?})", epoch.elapsed()),
        }
    }
}

impl SimClock {
    pub fn new(mode: ClockMode) -> Self {
        match mode {
            ClockMode::Virtual => Self::virtual_clock(),
            ClockMode::RealTime => Self::real_time(),
        }
    }

    /// A virtual clock starting at t = 0.
    pub fn virtual_clock() -> Self {
        Self { inner: Arc::new(Inner::Virtual(Mutex::new(Duration::ZERO))) }
    }

    /// A wall-clock handle with its epoch at construction.
    #[allow(clippy::disallowed_methods)]
    pub fn real_time() -> Self {
        Self { inner: Arc::new(Inner::Real(Instant::now())) }
    }

    pub fn mode(&self) -> ClockMode {
        match &*self.inner {
            Inner::Virtual(_) => ClockMode::Virtual,
            Inner::Real(_) => ClockMode::RealTime,
        }
    }

    pub fn is_virtual(&self) -> bool {
        self.mode() == ClockMode::Virtual
    }

    /// Elapsed (virtual or real) time since the clock's epoch.
    pub fn now(&self) -> Duration {
        match &*self.inner {
            Inner::Virtual(now) => *now.lock().unwrap(),
            Inner::Real(epoch) => epoch.elapsed(),
        }
    }

    /// `now()` in seconds — the common unit for metrics.
    pub fn now_s(&self) -> f64 {
        self.now().as_secs_f64()
    }

    /// Seconds elapsed since an earlier `now()` reading, saturating at
    /// zero (the one shared "stopwatch" helper, so no call site hand-rolls
    /// an underflow-prone `Duration` subtraction).
    pub fn since(&self, t0: Duration) -> f64 {
        self.now().checked_sub(t0).unwrap_or_default().as_secs_f64()
    }

    /// Move virtual time forward by `d` (modeled compute, batching windows,
    /// ...). In real-time mode this is a no-op: real work already consumed
    /// the real seconds it took.
    pub fn advance(&self, d: Duration) {
        if let Inner::Virtual(now) = &*self.inner {
            let mut t = now.lock().unwrap();
            *t += d;
        }
    }

    /// Move virtual time forward to `t` (monotone: earlier targets are
    /// ignored). No-op in real-time mode.
    pub fn advance_to(&self, t: Duration) {
        if let Inner::Virtual(now) = &*self.inner {
            let mut cur = now.lock().unwrap();
            if t > *cur {
                *cur = t;
            }
        }
    }

    /// Pass `d` of simulated time: advances the virtual clock, or really
    /// sleeps in real-time mode.
    pub fn sleep(&self, d: Duration) {
        match &*self.inner {
            Inner::Virtual(_) => self.advance(d),
            Inner::Real(_) => std::thread::sleep(d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_starts_at_zero_and_advances() {
        let c = SimClock::virtual_clock();
        assert!(c.is_virtual());
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(5));
        c.sleep(Duration::from_millis(7)); // no real sleep in virtual mode
        assert_eq!(c.now(), Duration::from_millis(12));
    }

    #[test]
    fn advance_to_is_monotone() {
        let c = SimClock::virtual_clock();
        c.advance_to(Duration::from_millis(10));
        c.advance_to(Duration::from_millis(4)); // ignored: in the past
        assert_eq!(c.now(), Duration::from_millis(10));
    }

    #[test]
    fn since_saturates_at_zero() {
        let c = SimClock::virtual_clock();
        c.advance(Duration::from_secs(3));
        assert!((c.since(Duration::from_secs(1)) - 2.0).abs() < 1e-12);
        assert_eq!(c.since(Duration::from_secs(9)), 0.0);
    }

    #[test]
    fn clones_share_the_timeline() {
        let a = SimClock::virtual_clock();
        let b = a.clone();
        a.advance(Duration::from_secs(1));
        assert_eq!(b.now(), Duration::from_secs(1));
    }

    #[test]
    fn real_time_moves_and_ignores_advance() {
        let c = SimClock::real_time();
        assert!(!c.is_virtual());
        let t0 = c.now();
        c.advance(Duration::from_secs(1000)); // no-op in real mode
        std::thread::sleep(Duration::from_millis(2));
        let t1 = c.now();
        assert!(t1 > t0);
        assert!(t1 < Duration::from_secs(500), "advance must not move real time");
    }

    #[test]
    fn mode_names() {
        assert_eq!(ClockMode::Virtual.name(), "virtual");
        assert_eq!(ClockMode::RealTime.name(), "real-time");
    }
}
