//! Deterministic, seedable PRNGs (no `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256**`, the same construction the reference
//! implementations recommend. Everything in the repo that needs randomness
//! (workload generation, eviction tie-breaks, property tests) goes through
//! this module so experiments are reproducible from a single seed.

/// SplitMix64: used for seeding and cheap one-off streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality general-purpose generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` (Lemire's method, unbiased enough for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate 1/scale.
    pub fn exponential(&mut self, scale: f64) -> f64 {
        -scale * self.f64().max(1e-300).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample needs positive mass");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(13);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(1);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
