//! A mutex-pooled f32 scratch arena: reusable buffers for hot-path
//! temporaries, shared by the reference stage backend (per-stage
//! activations) and the engine (per-expert-group gather+pad staging).
//!
//! `take(len)` hands out a zeroed buffer that returns to the pool on drop
//! with its capacity retained, so steady-state use performs no heap
//! allocation. The lock is held only for a pop/push, never across kernel
//! work, so `&self` users on scoped worker threads share one arena
//! without serializing their compute.

use std::sync::Mutex;

/// A pool of reusable f32 scratch buffers.
#[derive(Default)]
pub struct Arena {
    pool: Mutex<Vec<Vec<f32>>>,
}

impl Arena {
    pub fn new() -> Self {
        Self { pool: Mutex::new(Vec::new()) }
    }

    /// A zeroed scratch buffer of `len` elements, returned to the pool on
    /// drop (capacity is retained across uses).
    pub fn take(&self, len: usize) -> Scratch<'_> {
        let mut buf = self.pool.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        Scratch { arena: self, buf }
    }

    /// Buffers currently parked in the pool (test instrumentation).
    pub fn pooled(&self) -> usize {
        self.pool.lock().unwrap().len()
    }
}

/// A pooled buffer on loan from an [`Arena`]; derefs to `[f32]`.
pub struct Scratch<'a> {
    arena: &'a Arena,
    buf: Vec<f32>,
}

impl std::ops::Deref for Scratch<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for Scratch<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for Scratch<'_> {
    fn drop(&mut self) {
        self.arena.pool.lock().unwrap().push(std::mem::take(&mut self.buf));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_recycles() {
        let arena = Arena::new();
        {
            let mut a = arena.take(8);
            a.iter_mut().for_each(|v| *v = 3.0);
        }
        assert_eq!(arena.pooled(), 1);
        let b = arena.take(4);
        assert!(b.iter().all(|&v| v == 0.0), "recycled buffers must be zeroed");
        assert_eq!(b.len(), 4);
        drop(b);
        assert_eq!(arena.pooled(), 1);
    }

    #[test]
    fn concurrent_takes_get_disjoint_buffers() {
        let arena = Arena::new();
        let a = arena.take(4);
        let b = arena.take(4);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        assert_ne!(a.as_ptr(), b.as_ptr());
    }
}
