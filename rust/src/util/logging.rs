//! Minimal `log` facade backend: timestamped stderr logger with an
//! environment-controlled level (`BUDDYMOE_LOG=debug|info|warn|error`).

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INIT: Once = Once::new();

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = START.elapsed().as_secs_f64();
            eprintln!(
                "[{t:9.3}s {:5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level from `BUDDYMOE_LOG`, default info.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("BUDDYMOE_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        };
        let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
        log::set_max_level(LevelFilter::Trace);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
