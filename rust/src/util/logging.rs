//! Minimal `log` facade backend: timestamped stderr logger with an
//! environment-controlled level (`BUDDYMOE_LOG=debug|info|warn|error`).
//!
//! When a serving [`SimClock`] has been installed via [`set_clock`], log
//! lines are stamped with *virtual* serving time (the same timeline every
//! trace span uses), so a log line can be lined up against the Perfetto
//! trace. Without an installed clock, lines fall back to process elapsed
//! time as before.

use std::sync::{Mutex, Once};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

use super::clock::SimClock;

#[allow(clippy::disallowed_methods)]
// pallas-lint: allow(wall-clock, reason = "fallback stamp before a SimClock is installed; serving runs use set_clock")
static START: Lazy<Instant> = Lazy::new(Instant::now);
static INIT: Once = Once::new();
static CLOCK: Mutex<Option<SimClock>> = Mutex::new(None);

/// Install the serving clock as the logger's time source (latest wins).
/// Log lines then carry the clock's timestamp — virtual seconds in
/// simulation runs — instead of process elapsed time.
pub fn set_clock(clock: &SimClock) {
    let mut slot = CLOCK.lock().unwrap_or_else(|p| p.into_inner());
    *slot = Some(clock.clone());
}

/// The logger's current timestamp, in seconds: the installed serving
/// clock when present, process elapsed time otherwise.
fn timestamp_s() -> f64 {
    let slot = CLOCK.lock().unwrap_or_else(|p| p.into_inner());
    stamp(&slot)
}

fn stamp(slot: &Option<SimClock>) -> f64 {
    match slot {
        Some(clock) => clock.now_s(),
        // pallas-lint: allow(wall-clock, reason = "fallback stamp before a SimClock is installed; serving runs use set_clock")
        None => START.elapsed().as_secs_f64(),
    }
}

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = timestamp_s();
            eprintln!(
                "[{t:9.3}s {:5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level from `BUDDYMOE_LOG`, default info.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("BUDDYMOE_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        };
        let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
        log::set_max_level(LevelFilter::Trace);
    });
}

#[cfg(test)]
mod tests {
    use super::super::clock::SimClock;
    use std::time::Duration;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn installed_clock_drives_timestamps() {
        // Stamp logic is tested on a local slot: the global CLOCK is
        // latest-wins and other tests (any Engine construction) install
        // their own clocks concurrently.
        let clock = SimClock::virtual_clock();
        clock.advance(Duration::from_secs(42));
        assert_eq!(super::stamp(&Some(clock.clone())), 42.0);
        clock.advance(Duration::from_secs(1));
        assert_eq!(super::stamp(&Some(clock.clone())), 43.0);
        assert!(super::stamp(&None) >= 0.0);
        // And installing via the public API must not panic.
        super::set_clock(&clock);
        let _ = super::timestamp_s();
    }
}
