//! Small numeric helpers shared across the coordinator: softmax, top-k,
//! entropy (the TAE building block), percentiles, cosine similarity.

/// Numerically-stable in-place softmax. `-inf` entries get zero weight;
/// an all-`-inf` row becomes all zeros (fully-masked attention rows)
/// instead of NaN.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        xs.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Deterministic top-k: probability descending, index ascending on ties.
/// Mirrors `python/compile/model.py::top_k_select` exactly (binary contract
/// for the golden fixtures). Returns (indices, renormalized weights).
///
/// O(n + k log k): an O(n) `select_nth_unstable_by` partition brings the
/// top k to the front, then only those k are sorted — same descending-prob
/// / ascending-index order the old full sort produced.
pub fn top_k(probs: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    assert!(k <= probs.len());
    if k == 0 {
        return (Vec::new(), Vec::new());
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    // total_cmp: NaN gate probs rank deterministically (greatest-first)
    // instead of collapsing to Equal and leaking index order; softmax
    // probs are non-negative, so finite inputs sort exactly as before.
    let by_prob_desc = |a: &usize, b: &usize| probs[*b].total_cmp(&probs[*a]).then(a.cmp(b));
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, by_prob_desc);
        idx.truncate(k);
    }
    idx.sort_by(by_prob_desc);
    let sum: f32 = idx.iter().map(|&i| probs[i]).sum();
    let w = idx
        .iter()
        .map(|&i| if sum > 0.0 { probs[i] / sum } else { 1.0 / k as f32 })
        .collect();
    (idx, w)
}

/// Token Activating Entropy (paper Eq. 1): normalized entropy of the
/// renormalized top-k weights, in [0, 1].
pub fn tae(weights: &[f32]) -> f32 {
    let k = weights.len();
    if k <= 1 {
        return 0.0;
    }
    let mut h = 0.0f64;
    for &w in weights {
        if w > 0.0 {
            let w = w as f64;
            h -= w * w.ln();
        }
    }
    (h / (k as f64).ln()) as f32
}

/// Probability margin `p_max - p_2nd` over renormalized top-k weights
/// (the optional extra-caution gate in paper §3.1).
pub fn prob_margin(weights: &[f32]) -> f32 {
    if weights.len() < 2 {
        return 1.0;
    }
    let mut top = f32::NEG_INFINITY;
    let mut second = f32::NEG_INFINITY;
    for &w in weights {
        if w > top {
            second = top;
            top = w;
        } else if w > second {
            second = w;
        }
    }
    top - second
}

/// p-th percentile (linear interpolation) of data; p in [0, 100].
/// Already-sorted input is detected with one O(n) scan and used in place
/// — no clone, no re-sort.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    if xs.windows(2).all(|w| w[0] <= w[1]) {
        return percentile_sorted(xs, p);
    }
    let mut s: Vec<f32> = xs.to_vec();
    // total_cmp: NaN samples sort deterministically (positive NaN above
    // +inf) instead of feeding sort_by a non-transitive comparator, which
    // may panic and silently misorders NaN latency samples.
    s.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&s, p)
}

/// p-th percentile (linear interpolation) of ascending-sorted data.
pub fn percentile_sorted(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let rank = (p / 100.0) * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let f = (rank - lo as f64) as f32;
        xs[lo] * (1.0 - f) + xs[hi] * f
    }
}

/// Cosine similarity of two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

/// argmax with lowest-index tie-break.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// KL(p || q) for probability vectors (natural log).
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut kl = 0.0f64;
    for i in 0..p.len() {
        if p[i] > 0.0 {
            kl += p[i] as f64 * ((p[i] as f64) / (q[i] as f64).max(1e-12)).ln();
        }
    }
    kl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -1.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut xs = vec![1e4, 1e4 - 1.0];
        softmax(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn softmax_masked_entries_and_rows() {
        let mut xs = vec![0.0, f32::NEG_INFINITY, 0.0];
        softmax(&mut xs);
        assert_eq!(xs[1], 0.0);
        assert!((xs[0] - 0.5).abs() < 1e-6);
        let mut all_masked = vec![f32::NEG_INFINITY; 3];
        softmax(&mut all_masked);
        assert_eq!(all_masked, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn top_k_orders_and_renormalizes() {
        let probs = vec![0.1, 0.4, 0.2, 0.3];
        let (idx, w) = top_k(&probs, 2);
        assert_eq!(idx, vec![1, 3]);
        assert!((w[0] - 0.4 / 0.7).abs() < 1e-6);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn top_k_tie_break_low_index() {
        let probs = vec![0.25, 0.25, 0.25, 0.25];
        let (idx, _) = top_k(&probs, 2);
        assert_eq!(idx, vec![0, 1]);
    }

    #[test]
    fn top_k_matches_full_sort_reference() {
        // The select-then-sort path must reproduce the old full-sort
        // contract exactly, ties (quantized probs) included.
        let mut rng = crate::util::rng::Rng::new(77);
        for _ in 0..300 {
            let n = rng.range(1, 40);
            let k = rng.range(0, n + 1);
            let probs: Vec<f32> = (0..n).map(|_| (rng.below(6) as f32) / 5.0).collect();
            let mut want: Vec<usize> = (0..n).collect();
            // Same total_cmp order as top_k itself; inputs here are
            // finite and non-negative, where total_cmp == partial_cmp.
            want.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
            want.truncate(k);
            let (got, _) = top_k(&probs, k);
            assert_eq!(got, want, "n={n} k={k} probs={probs:?}");
        }
    }

    #[test]
    fn tae_extremes() {
        assert!((tae(&[0.25, 0.25, 0.25, 0.25]) - 1.0).abs() < 1e-6);
        assert!(tae(&[1.0, 0.0, 0.0, 0.0]).abs() < 1e-6);
    }

    #[test]
    fn tae_monotone_in_peakiness() {
        let diffuse = tae(&[0.3, 0.25, 0.25, 0.2]);
        let peaky = tae(&[0.9, 0.05, 0.03, 0.02]);
        assert!(diffuse > peaky);
    }

    #[test]
    fn margin_basic() {
        assert!((prob_margin(&[0.7, 0.2, 0.1]) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-6);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn percentile_sorted_fast_path_agrees() {
        let xs = vec![4.0, 1.0, 3.0, 2.0];
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for &p in &[0.0, 10.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
            // Sorted input takes the no-clone path and must agree too.
            assert_eq!(percentile(&sorted, p), percentile_sorted(&sorted, p));
        }
    }

    #[test]
    fn cosine_identity_and_orthogonal() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
    }

    #[test]
    fn kl_zero_for_identical() {
        let p = vec![0.5, 0.3, 0.2];
        assert!(kl_divergence(&p, &p).abs() < 1e-9);
    }

    #[test]
    fn argmax_tie_break() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
