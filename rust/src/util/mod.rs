//! Infrastructure substrates built in-tree (the environment is offline, so
//! the usual crates — rand, serde, clap — are hand-rolled here).

pub mod arena;
pub mod argparse;
pub mod clock;
pub mod json;
pub mod logging;
pub mod math;
pub mod par;
pub mod rng;
pub mod tensor;
