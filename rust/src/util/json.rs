//! Minimal JSON parser / writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar we produce and consume: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Numbers are stored as
//! f64 (adequate: our configs and fixtures stay within 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Hand-rolled `Display`/`Error` (thiserror is unavailable offline).
#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    Type { wanted: &'static str, got: &'static str },
    MissingKey(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => write!(f, "unexpected character '{c}' at byte {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type { wanted, got } => write!(f, "type error: wanted {wanted}, got {got}"),
            JsonError::MissingKey(k) => write!(f, "missing key '{k}'"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(JsonError::Type { wanted: "number", got: other.kind() }),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(x) => Ok(*x),
            other => Err(JsonError::Type { wanted: "bool", got: other.kind() }),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(x) => Ok(x),
            other => Err(JsonError::Type { wanted: "string", got: other.kind() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(x) => Ok(x),
            other => Err(JsonError::Type { wanted: "array", got: other.kind() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(x) => Ok(x),
            other => Err(JsonError::Type { wanted: "object", got: other.kind() }),
        }
    }

    /// Object field access: `j.get("a")?.get("b")?`.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// f32 vector from a numeric array.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>, JsonError> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report/profile serialization.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f32(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

pub fn arr_usize(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != c {
            return Err(JsonError::Unexpected(got as char, self.i));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::BadEscape(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        if let Ok(chunk) = std::str::from_utf8(&self.b[start..end]) {
                            out.push_str(chunk);
                            self.i = end;
                        } else {
                            out.push('\u{fffd}');
                        }
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.0));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("c".into())
        );
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(j, Json::Str("a\n\t\"Aé".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,-3],"y":{"z":true},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_truncated() {
        assert!(Json::parse(r#"{"a": [1, 2"#).is_err());
        assert!(Json::parse(r#""abc"#).is_err());
    }

    #[test]
    fn missing_key_error() {
        let j = Json::parse("{}").unwrap();
        assert!(matches!(j.get("nope"), Err(JsonError::MissingKey(_))));
    }

    #[test]
    fn type_errors() {
        let j = Json::parse("[1]").unwrap();
        assert!(j.as_obj().is_err());
        assert!(j.as_arr().is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1, 2.5]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.5]);
    }
}
