//! BuddyMoE CLI — the serving leader binary.
//!
//! Subcommands:
//!   profile   run the offline profiling corpus, save co-activation stats
//!             + buddy lists
//!   serve     offline serving run with a chosen method preset; prints
//!             throughput/latency metrics
//!   table     regenerate one of the paper's tables (2, 3, 4)
//!   figures   dump the data behind Figures 4/6/7/9
//!   smoke     end-to-end smoke test (tiny workload)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use buddymoe::buddy::BuddyProfile;
use buddymoe::config::{ModelConfig, ServingConfig};
use buddymoe::eval::{
    self, profile_model, run_table, table_methods, warm_rank_from_profile, TableSettings,
};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::profilecollect::{expert_similarity_matrix, ProfileCollector};
use buddymoe::server::Server;
use buddymoe::util::argparse::ArgSpec;
use buddymoe::util::clock::ClockMode;
use buddymoe::util::json::Json;
use buddymoe::util::logging;
use buddymoe::weights::WeightStore;

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "buddymoe <profile|serve|table|figures|smoke> [options]\n\
     run `buddymoe <cmd> --help` for per-command options"
        .to_string()
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        bail!("{}", usage());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "profile" => cmd_profile(rest),
        "serve" => cmd_serve(rest),
        "table" => cmd_table(rest),
        "figures" => cmd_figures(rest),
        "smoke" => cmd_smoke(rest),
        _ => bail!("unknown command '{cmd}'\n{}", usage()),
    }
}

fn load_model(dir: &str) -> Result<(ModelConfig, Arc<WeightStore>)> {
    let dir = PathBuf::from(dir);
    let cfg = ModelConfig::load(&dir)
        .with_context(|| format!("loading model config from {}", dir.display()))?;
    let store = Arc::new(WeightStore::load(&cfg)?);
    Ok((cfg, store))
}

fn cmd_profile(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("buddymoe profile", "offline co-activation profiling")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("prompts", "64", "profiling corpus size")
        .opt("seed", "7777", "corpus seed (held out from eval)")
        .opt("alpha", "0.8", "CFT alpha for buddy lists")
        .opt("k-max", "16", "buddy list cap")
        .opt("out", "artifacts/profile", "output directory");
    let a = spec.parse(rest)?;
    let (cfg, store) = load_model(a.get("artifacts"))?;
    let pc = profile_model(&cfg, store, a.get_usize("prompts")?, a.get_u64("seed")?)?;
    let out = PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("coactivation.json"), pc.to_json().to_string())?;
    let alphas = vec![a.get_f64("alpha")?; cfg.n_layers];
    let profile = BuddyProfile::build(&pc, &alphas, a.get_usize("k-max")?, 1e-3, true)?;
    profile.save(&out.join("buddies.json"))?;
    let sizes = profile.list_sizes(0);
    println!(
        "profiled {} prompts; layer-0 buddy list sizes: min {} max {} mean {:.1}",
        a.get_usize("prompts")?,
        sizes.iter().min().expect("profiled model has at least one layer-0 buddy list"),
        sizes.iter().max().expect("profiled model has at least one layer-0 buddy list"),
        sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
    );
    println!("wrote {}", out.display());
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("buddymoe serve", "offline serving benchmark run")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt(
            "preset",
            "buddy-rho3",
            "original|random|buddy-tight|buddy-wide|buddy-rho3|buddy-rho4|buddy-strict",
        )
        .opt("cache-rate", "0.75", "fraction of experts GPU-resident")
        .opt("requests", "16", "number of requests")
        .opt("max-new", "16", "tokens generated per request")
        .opt("max-batch", "8", "continuous-batching width")
        .opt("seed", "42", "workload seed")
        .opt("profile-prompts", "64", "profiling corpus size")
        .flag("real-time", "run on the wall clock (PCIe stalls really sleep); default is deterministic virtual time");
    let a = spec.parse(rest)?;
    let (cfg, store) = load_model(a.get("artifacts"))?;

    log::info!("profiling...");
    let pc = profile_model(&cfg, store.clone(), a.get_usize("profile-prompts")?, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let mut scfg = ServingConfig::default().preset(a.get("preset"))?;
    scfg.cache_rate = a.get_f64("cache-rate")?;
    scfg.max_batch = a.get_usize("max-batch")?;
    scfg.seed = a.get_u64("seed")?;
    let alphas = vec![scfg.cft_alpha; cfg.n_layers];
    let profile = BuddyProfile::build(&pc, &alphas, scfg.k_max, 1e-3, true)?;

    let clock_mode = if a.flag("real-time") {
        ClockMode::RealTime
    } else {
        ClockMode::Virtual
    };
    let opts = EngineOptions {
        clock: clock_mode,
        // §Perf A/B switch: literal path vs device-resident weight buffers.
        weight_buffers: std::env::var("BUDDYMOE_NO_WEIGHT_BUFFERS").is_err(),
        ..Default::default()
    };
    let engine = Engine::new(cfg.clone(), scfg, store, Some(profile), Some(warm), opts)?;
    let mut server = Server::new(engine);
    let settings = TableSettings {
        cache_rate: a.get_f64("cache-rate")?,
        n_easy: a.get_usize("requests")? / 2,
        n_hard: a.get_usize("requests")? - a.get_usize("requests")? / 2,
        max_new: a.get_usize("max-new")?,
        seed: a.get_u64("seed")?,
        clock: clock_mode,
    };
    let reqs = eval::build_requests(&cfg, &settings);
    log::info!("serving {} requests...", reqs.len());
    let responses = server.run_offline(reqs)?;
    println!("{}", server.metrics.report());
    println!("engine counters:");
    for (k, v) in server.engine.counters.iter() {
        println!("  {k}: {v}");
    }
    println!(
        "prefetch hit rate: {:.3}",
        server
            .engine
            .prefetch_counters()
            .ratio("prefetch_useful", "prefetch_issued")
    );
    let pcie = server
        .engine
        .transfer_handle()
        .with_state(|st| st.pcie_stats());
    println!(
        "pcie: demand {} B ({} transfers), prefetch {} B ({} transfers)",
        pcie.demand_bytes, pcie.demand_transfers, pcie.prefetch_bytes, pcie.prefetch_transfers
    );
    println!("responses: {}", responses.len());
    server.engine.shutdown();
    Ok(())
}

fn cmd_table(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("buddymoe table", "regenerate a paper table")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("cache-rate", "0.75", "paper tables: 0.75 (T2), 0.5 (T3), 0.375 (T4)")
        .opt("n-easy", "8", "easy prompts")
        .opt("n-hard", "8", "hard prompts")
        .opt("max-new", "16", "tokens per request")
        .opt("seed", "42", "workload seed")
        .opt("out", "", "also write markdown to this path")
        .flag("real-time", "measure wall-clock throughput instead of deterministic virtual time");
    let a = spec.parse(rest)?;
    let (cfg, store) = load_model(a.get("artifacts"))?;
    let settings = TableSettings {
        cache_rate: a.get_f64("cache-rate")?,
        n_easy: a.get_usize("n-easy")?,
        n_hard: a.get_usize("n-hard")?,
        max_new: a.get_usize("max-new")?,
        seed: a.get_u64("seed")?,
        clock: if a.flag("real-time") { ClockMode::RealTime } else { ClockMode::Virtual },
    };
    let (_rows, md) = run_table(&cfg, store, &settings, &table_methods())?;
    println!("{md}");
    let out = a.get("out");
    if !out.is_empty() {
        eval::write_report(&PathBuf::from(out), &md)?;
    }
    Ok(())
}

fn cmd_figures(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("buddymoe figures", "dump data behind Figures 4/6/7/9")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("prompts", "64", "profiling corpus size")
        .opt("out", "artifacts/figures", "output directory");
    let a = spec.parse(rest)?;
    let (cfg, store) = load_model(a.get("artifacts"))?;
    let out = PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&out)?;

    // Fig 4: weight-space similarity heatmap (layer 0).
    let sim = expert_similarity_matrix(&cfg, &store, 0)?;
    let sim_json = Json::Arr(
        sim.iter()
            .map(|row| buddymoe::util::json::arr_f32(row))
            .collect(),
    );
    std::fs::write(out.join("fig4_similarity_l0.json"), sim_json.to_string())?;

    // Figs 6/7/9 need routing statistics.
    let pc = profile_model(&cfg, store, a.get_usize("prompts")?, 7777)?;
    let dump_layer = |pc: &ProfileCollector, l: usize| -> Json {
        let la = pc.layer(l);
        buddymoe::util::json::obj(vec![
            (
                "activations",
                buddymoe::util::json::arr_f32(
                    &la.activations.iter().map(|&x| x as f32).collect::<Vec<_>>(),
                ),
            ),
            (
                "coactivation",
                buddymoe::util::json::arr_f32(
                    &la.binary.iter().map(|&x| x as f32).collect::<Vec<_>>(),
                ),
            ),
        ])
    };
    let fig6_layer = (cfg.n_layers - 1).min(11);
    std::fs::write(
        out.join(format!("fig6_activation_l{fig6_layer}.json")),
        dump_layer(&pc, fig6_layer).to_string(),
    )?;
    std::fs::write(
        out.join("fig7_coactivation_l0.json"),
        dump_layer(&pc, 0).to_string(),
    )?;
    println!("wrote figure data to {}", out.display());
    Ok(())
}

fn cmd_smoke(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("buddymoe smoke", "tiny end-to-end smoke run")
        .opt("artifacts", "artifacts", "artifacts directory");
    let a = spec.parse(rest)?;
    let (cfg, store) = load_model(a.get("artifacts"))?;
    let pc = profile_model(&cfg, store.clone(), 8, 7777)?;
    let warm = warm_rank_from_profile(&pc);
    let mut scfg = ServingConfig::default().preset("buddy-rho3")?;
    scfg.cache_rate = 0.5;
    let alphas = vec![scfg.cft_alpha; cfg.n_layers];
    let profile = BuddyProfile::build(&pc, &alphas, scfg.k_max, 1e-3, true)?;
    let engine = Engine::new(
        cfg.clone(),
        scfg,
        store,
        Some(profile),
        Some(warm),
        EngineOptions::default(),
    )?;
    let mut server = Server::new(engine);
    let mut gen = eval::WorkloadGen::new(&cfg, 1);
    gen.max_new = 8;
    let reqs = gen.requests(eval::Domain::Mixed, 4, 0);
    let responses = server.run_offline(reqs)?;
    println!("{}", server.metrics.report());
    anyhow::ensure!(responses.len() == 4, "smoke run lost responses");
    anyhow::ensure!(
        responses.iter().all(|r| r.tokens.len() == 8),
        "wrong generation lengths"
    );
    println!("smoke OK");
    server.engine.shutdown();
    Ok(())
}
