//! Multi-GPU expert-parallel topology: the simulated device graph and the
//! expert→device placement map.
//!
//! The paper's buddy score ψ carries a topology term `(1 − κ·hop(j))⁺`
//! (Eq. 3): substituting a missing expert with a buddy that lives on a
//! *different* GPU adds unplanned all-to-all traffic, one peer-link hop per
//! edge crossed. This module makes that term real:
//!
//! * [`Topology`] — N simulated GPUs plus the host. GPUs are connected by
//!   a peer interconnect (NVLink-class: fast, low latency) whose shape is a
//!   [`TopologyKind`] — fully connected (every pair one hop) or a ring
//!   (hop count = ring distance). Every GPU also has its own host link
//!   (PCIe-class: the slow path every demand miss pays). Both links live
//!   on the PR-1 virtual clock via [`crate::memory::PcieSim`] cost models.
//! * [`Placement`] — the expert→device map. An expert's *home* device is
//!   where it is cached and where its FFN runs; misses are fetched from
//!   host over the home device's own serialized link (see
//!   [`crate::memory::TransferEngine`]).
//!
//! ## How hop counts are derived from placement
//!
//! For a layer `l`, `Placement` fixes `device_of[e]` for every expert.
//! When the substitution engine weighs a candidate buddy `j` for a missing
//! pivot `i`, the hop count fed into ψ is
//!
//! ```text
//! hop(j | i) = Topology::hops(device_of[i], device_of[j])
//! ```
//!
//! i.e. the peer-link distance between the device that *would have* run
//! the pivot and the device that will run the buddy. A same-device buddy
//! costs zero hops (the dispatch was already in the all-to-all schedule);
//! a cross-device buddy pays one peer round trip per hop, which the engine
//! charges on the virtual clock ([`crate::model::Engine`]'s peer-dispatch
//! accounting) and which κ penalizes inside ψ so substitution is steered
//! toward same-device buddies. [`HopContext`] packages exactly this
//! lookup for `SubstitutionEngine`.
//!
//! With `n_devices = 1` every hop count is zero, the peer link is never
//! touched, and the whole subsystem degenerates byte-identically to the
//! single-GPU configuration (golden-tested).

use anyhow::{bail, Result};

use crate::weights::ExpertKey;

/// Shape of the inter-GPU peer interconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopologyKind {
    /// Every device pair is one hop apart (NVSwitch-style).
    #[default]
    FullyConnected,
    /// Devices on a ring; hop count is the shorter ring distance.
    Ring,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "full" | "fully-connected" => TopologyKind::FullyConnected,
            "ring" => TopologyKind::Ring,
            other => bail!("unknown topology '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::FullyConnected => "full",
            TopologyKind::Ring => "ring",
        }
    }
}

/// The device graph: N GPUs on a peer interconnect (plus the implicit
/// host reachable from every GPU over its own host link).
#[derive(Debug, Clone)]
pub struct Topology {
    n_devices: usize,
    kind: TopologyKind,
}

impl Topology {
    pub fn new(n_devices: usize, kind: TopologyKind) -> Self {
        assert!(n_devices >= 1, "topology needs >= 1 device");
        Self { n_devices, kind }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    /// Peer-link hops between two devices (0 on the same device).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        debug_assert!(a < self.n_devices && b < self.n_devices);
        if a == b {
            return 0;
        }
        match self.kind {
            TopologyKind::FullyConnected => 1,
            TopologyKind::Ring => {
                let d = a.abs_diff(b);
                d.min(self.n_devices - d)
            }
        }
    }

    /// Dense device×device hop matrix (precomputed once per engine).
    pub fn hop_matrix(&self) -> Vec<Vec<usize>> {
        (0..self.n_devices)
            .map(|a| (0..self.n_devices).map(|b| self.hops(a, b)).collect())
            .collect()
    }
}

/// Expert→device placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementKind {
    /// Expert `e` of layer `l` lives on device `(e + l) % n`: experts are
    /// striped across devices with a per-layer offset so each device holds
    /// an even, layer-rotated share.
    #[default]
    LayerStriped,
    /// Profile-aware: experts are ranked by profiled popularity per layer
    /// and dealt round-robin in descending rank, so every device gets an
    /// equal share of the hot experts (falls back to striping when no
    /// popularity ranking is available).
    Popularity,
}

impl PlacementKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "striped" | "layer-striped" => PlacementKind::LayerStriped,
            "popularity" => PlacementKind::Popularity,
            other => bail!("unknown placement '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::LayerStriped => "striped",
            PlacementKind::Popularity => "popularity",
        }
    }
}

/// The expert→device map: each expert has one *home* device where it is
/// cached and executed.
#[derive(Debug, Clone)]
pub struct Placement {
    n_layers: usize,
    n_experts: usize,
    n_devices: usize,
    /// [layer * n_experts + expert] -> device.
    device_of: Vec<usize>,
}

impl Placement {
    /// Everything on device 0 — the single-GPU degenerate case.
    pub fn single(n_layers: usize, n_experts: usize) -> Self {
        Self {
            n_layers,
            n_experts,
            n_devices: 1,
            device_of: vec![0; n_layers * n_experts],
        }
    }

    /// Build a placement. `popularity_rank` is the per-layer expert list
    /// in descending popularity (the engine's warm rank); it is required
    /// for [`PlacementKind::Popularity`] to differ from striping.
    pub fn build(
        kind: PlacementKind,
        n_layers: usize,
        n_experts: usize,
        n_devices: usize,
        popularity_rank: Option<&[Vec<usize>]>,
    ) -> Self {
        assert!(n_devices >= 1, "placement needs >= 1 device");
        let mut device_of = vec![0; n_layers * n_experts];
        if n_devices > 1 {
            match (kind, popularity_rank) {
                (PlacementKind::Popularity, Some(ranked)) => {
                    for l in 0..n_layers {
                        for (r, &e) in ranked[l].iter().enumerate() {
                            device_of[l * n_experts + e] = r % n_devices;
                        }
                    }
                }
                _ => {
                    for l in 0..n_layers {
                        for e in 0..n_experts {
                            device_of[l * n_experts + e] = (e + l) % n_devices;
                        }
                    }
                }
            }
        }
        Self { n_layers, n_experts, n_devices, device_of }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Home device of an expert.
    pub fn device_of(&self, k: ExpertKey) -> usize {
        debug_assert!(k.layer < self.n_layers && k.expert < self.n_experts);
        self.device_of[k.layer * self.n_experts + k.expert]
    }

    /// One layer's expert→device slice (indexed by expert id) — the form
    /// [`HopContext`] consumes.
    pub fn layer_devices(&self, layer: usize) -> &[usize] {
        &self.device_of[layer * self.n_experts..(layer + 1) * self.n_experts]
    }

    /// How many of a layer's experts live on `device`.
    pub fn experts_on(&self, layer: usize, device: usize) -> usize {
        self.layer_devices(layer).iter().filter(|&&d| d == device).count()
    }
}

/// Pivot-relative hop lookup for one layer, fed into the substitution
/// engine so ψ's κ term sees real placement-derived hop counts (see the
/// module docs for the derivation).
#[derive(Debug, Clone, Copy)]
pub struct HopContext<'a> {
    /// This layer's expert→device map ([`Placement::layer_devices`]).
    pub device_of: &'a [usize],
    /// Device×device hop matrix ([`Topology::hop_matrix`]).
    pub hop_matrix: &'a [Vec<usize>],
}

impl HopContext<'_> {
    /// Peer hops from the missing pivot's home device to the candidate's.
    pub fn hops(&self, pivot: usize, cand: usize) -> usize {
        self.hop_matrix[self.device_of[pivot]][self.device_of[cand]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_hops_are_binary() {
        let t = Topology::new(4, TopologyKind::FullyConnected);
        assert_eq!(t.hops(2, 2), 0);
        assert_eq!(t.hops(0, 3), 1);
        assert_eq!(t.hop_matrix()[1][2], 1);
    }

    #[test]
    fn ring_hops_take_shorter_arc() {
        let t = Topology::new(4, TopologyKind::Ring);
        assert_eq!(t.hops(0, 1), 1);
        assert_eq!(t.hops(0, 2), 2);
        assert_eq!(t.hops(0, 3), 1, "wraps the short way");
        let t2 = Topology::new(2, TopologyKind::Ring);
        assert_eq!(t2.hops(0, 1), 1);
    }

    #[test]
    fn single_placement_is_all_device_zero() {
        let p = Placement::single(2, 8);
        for l in 0..2 {
            for e in 0..8 {
                assert_eq!(p.device_of(ExpertKey::new(l, e)), 0);
            }
        }
        assert_eq!(p.experts_on(0, 0), 8);
    }

    #[test]
    fn striped_placement_is_even_and_layer_rotated() {
        let p = Placement::build(PlacementKind::LayerStriped, 2, 8, 2, None);
        assert_eq!(p.device_of(ExpertKey::new(0, 0)), 0);
        assert_eq!(p.device_of(ExpertKey::new(0, 1)), 1);
        // Layer offset rotates the stripe.
        assert_eq!(p.device_of(ExpertKey::new(1, 0)), 1);
        for l in 0..2 {
            assert_eq!(p.experts_on(l, 0), 4);
            assert_eq!(p.experts_on(l, 1), 4);
        }
    }

    #[test]
    fn popularity_placement_deals_hot_experts_round_robin() {
        // Popularity rank for one layer: 5 hottest, then 2, 7, 0...
        let ranked = vec![vec![5, 2, 7, 0, 1, 3, 4, 6]];
        let p = Placement::build(PlacementKind::Popularity, 1, 8, 2, Some(&ranked));
        assert_eq!(p.device_of(ExpertKey::new(0, 5)), 0, "hottest on device 0");
        assert_eq!(p.device_of(ExpertKey::new(0, 2)), 1, "2nd hottest on device 1");
        assert_eq!(p.device_of(ExpertKey::new(0, 7)), 0);
        assert_eq!(p.experts_on(0, 0), 4);
        assert_eq!(p.experts_on(0, 1), 4);
    }

    #[test]
    fn hop_context_is_pivot_relative() {
        let device_of = [0usize, 1, 0];
        let m = Topology::new(2, TopologyKind::FullyConnected).hop_matrix();
        let ctx = HopContext { device_of: &device_of, hop_matrix: &m };
        assert_eq!(ctx.hops(0, 2), 0, "same device");
        assert_eq!(ctx.hops(0, 1), 1, "cross device");
        assert_eq!(ctx.hops(1, 0), 1);
    }

    #[test]
    fn parse_roundtrip() {
        for k in ["full", "ring"] {
            assert_eq!(TopologyKind::parse(k).unwrap().name(), k);
        }
        for k in ["striped", "popularity"] {
            assert_eq!(PlacementKind::parse(k).unwrap().name(), k);
        }
        assert!(TopologyKind::parse("torus").is_err());
        assert!(PlacementKind::parse("bogus").is_err());
    }
}
