//! Multi-GPU expert-parallel topology: the simulated device graph and the
//! expert→device-set placement map.
//!
//! The paper's buddy score ψ carries a topology term `(1 − κ·hop(j))⁺`
//! (Eq. 3): substituting a missing expert with a buddy that lives on a
//! *different* GPU adds unplanned all-to-all traffic, one peer-link hop per
//! edge crossed. This module makes that term real:
//!
//! * [`Topology`] — N simulated GPUs plus the host. GPUs are connected by
//!   a peer interconnect (NVLink-class: fast, low latency) whose shape is a
//!   [`TopologyKind`] — fully connected (every pair one hop) or a ring
//!   (hop count = ring distance). Every GPU also has its own host link
//!   (PCIe-class: the slow path every demand miss pays). Both links live
//!   on the PR-1 virtual clock via [`crate::memory::PcieSim`] cost models.
//!   The peer interconnect is a *contended* resource: the fully connected
//!   fabric is one serialized link, a ring is one serialized link per
//!   edge, and [`Topology::peer_path`] maps a device pair to the links a
//!   dispatch crosses in order (FIFO busy-until queuing lives in
//!   [`crate::memory::TransferEngine`]'s `PeerLink` state).
//! * [`Placement`] — the expert→device-set map. Each expert has one or
//!   more *home* devices where it may be cached and executed; the first
//!   home is the *primary* (demand fetches and prefetches land there).
//!   With a `replication_factor` r > 1, the top-r popularity-ranked
//!   experts per layer are dealt to `min(r, n_devices)` homes each, so
//!   hot dispatches stay local. Misses are fetched from host over the
//!   primary home's own serialized link.
//!
//! ## How hop counts are derived from placement
//!
//! For a layer `l`, `Placement` fixes a home set `homes[e]` for every
//! expert. When the substitution engine weighs a candidate buddy `j` for
//! a missing pivot `i`, the hop count fed into ψ is the distance between
//! the *nearest replica pair*:
//!
//! ```text
//! hop(j | i) = min over (a in homes[i], b in homes[j]) of Topology::hops(a, b)
//! ```
//!
//! i.e. the shortest peer-link distance from any device that *would have*
//! run the pivot to any device holding the buddy. A same-device replica
//! costs zero hops (the dispatch was already in the all-to-all schedule);
//! a cross-device buddy pays one peer round trip per hop, which the engine
//! charges on the contended peer links of the virtual clock
//! ([`crate::model::Engine`]'s peer-dispatch accounting) and which κ
//! penalizes inside ψ so substitution is steered toward the nearest
//! replica. [`HopContext`] packages exactly this lookup (and the
//! arg-min device pair, for routing the charged dispatch) for
//! `SubstitutionEngine`.
//!
//! With `n_devices = 1` or `replication_factor = 1` every home set is a
//! singleton, every hop lookup degenerates to the single-home distance,
//! the peer links are never touched, and the whole subsystem degenerates
//! byte-identically to the single-GPU configuration (golden-tested).

use anyhow::{bail, Result};

use crate::weights::ExpertKey;

/// Shape of the inter-GPU peer interconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopologyKind {
    /// Every device pair is one hop apart (NVSwitch-style).
    #[default]
    FullyConnected,
    /// Devices on a ring; hop count is the shorter ring distance.
    Ring,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "full" | "fully-connected" => TopologyKind::FullyConnected,
            "ring" => TopologyKind::Ring,
            other => bail!("unknown topology '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::FullyConnected => "full",
            TopologyKind::Ring => "ring",
        }
    }
}

/// The device graph: N GPUs on a peer interconnect (plus the implicit
/// host reachable from every GPU over its own host link).
#[derive(Debug, Clone)]
pub struct Topology {
    n_devices: usize,
    kind: TopologyKind,
}

impl Topology {
    pub fn new(n_devices: usize, kind: TopologyKind) -> Self {
        assert!(n_devices >= 1, "topology needs >= 1 device");
        Self { n_devices, kind }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    /// Peer-link hops between two devices (0 on the same device).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        debug_assert!(a < self.n_devices && b < self.n_devices);
        if a == b {
            return 0;
        }
        match self.kind {
            TopologyKind::FullyConnected => 1,
            TopologyKind::Ring => {
                let d = a.abs_diff(b);
                d.min(self.n_devices - d)
            }
        }
    }

    /// Dense device×device hop matrix (precomputed once per engine).
    pub fn hop_matrix(&self) -> Vec<Vec<usize>> {
        (0..self.n_devices)
            .map(|a| (0..self.n_devices).map(|b| self.hops(a, b)).collect())
            .collect()
    }

    /// Number of serialized peer links: the fully connected fabric is one
    /// shared link (NVSwitch-style); a ring has one link per edge (edge
    /// `i` connects device `i` and `i+1 mod n`; a 2-ring has one edge).
    pub fn n_peer_links(&self) -> usize {
        match self.kind {
            TopologyKind::FullyConnected => 1,
            TopologyKind::Ring => {
                if self.n_devices >= 3 {
                    self.n_devices
                } else {
                    1
                }
            }
        }
    }

    /// The serialized peer links a dispatch from `a` to `b` crosses, in
    /// traversal order (empty when `a == b`). Fully connected: one
    /// traversal of the shared fabric per hop. Ring: the edges of the
    /// shorter arc, ties broken toward ascending device ids so the path
    /// is deterministic.
    pub fn peer_path(&self, a: usize, b: usize) -> Vec<usize> {
        debug_assert!(a < self.n_devices && b < self.n_devices);
        if a == b {
            return Vec::new();
        }
        match self.kind {
            TopologyKind::FullyConnected => vec![0; self.hops(a, b)],
            TopologyKind::Ring => {
                let n = self.n_devices;
                if n == 2 {
                    return vec![0];
                }
                let fwd = (b + n - a) % n;
                let bwd = (a + n - b) % n;
                let mut path = Vec::new();
                let mut cur = a;
                if fwd <= bwd {
                    for _ in 0..fwd {
                        path.push(cur); // edge cur -> cur+1 has id cur
                        cur = (cur + 1) % n;
                    }
                } else {
                    for _ in 0..bwd {
                        cur = (cur + n - 1) % n;
                        path.push(cur); // edge cur <- cur+1 has id cur
                    }
                }
                path
            }
        }
    }
}

/// Expert→device placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementKind {
    /// Expert `e` of layer `l` lives on device `(e + l) % n`: experts are
    /// striped across devices with a per-layer offset so each device holds
    /// an even, layer-rotated share.
    #[default]
    LayerStriped,
    /// Profile-aware: experts are ranked by profiled popularity per layer
    /// and dealt round-robin in descending rank, so every device gets an
    /// equal share of the hot experts (falls back to striping when no
    /// popularity ranking is available — the fallback is logged and
    /// carried on [`Placement::fallback`] so reports cannot mislabel the
    /// placement actually used).
    Popularity,
}

impl PlacementKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "striped" | "layer-striped" => PlacementKind::LayerStriped,
            "popularity" => PlacementKind::Popularity,
            other => bail!("unknown placement '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::LayerStriped => "striped",
            PlacementKind::Popularity => "popularity",
        }
    }
}

/// The expert→device-set map: each expert has one or more *home* devices
/// where it may be cached and executed. The first home is the primary
/// (demand fetches and prefetches target it); extra homes are replicas of
/// popularity-hot experts.
#[derive(Debug, Clone)]
pub struct Placement {
    n_layers: usize,
    n_experts: usize,
    n_devices: usize,
    /// [layer * n_experts + expert] -> home device set, primary first.
    homes: Vec<Vec<usize>>,
    kind: PlacementKind,
    /// Popularity placement was requested but no profiled rank was
    /// available, so the striped fallback was used.
    fallback: bool,
    /// Any expert currently has more than one home (sticky: stays true
    /// once replication has ever been active, which only costs a cheap
    /// mask computation on the eviction path).
    replicated: bool,
}

impl Placement {
    /// Everything on device 0 — the single-GPU degenerate case.
    pub fn single(n_layers: usize, n_experts: usize) -> Self {
        Self {
            n_layers,
            n_experts,
            n_devices: 1,
            homes: vec![vec![0]; n_layers * n_experts],
            kind: PlacementKind::LayerStriped,
            fallback: false,
            replicated: false,
        }
    }

    /// Build a placement. `popularity_rank` is the per-layer expert list
    /// in descending popularity (the engine's warm rank); it is required
    /// for [`PlacementKind::Popularity`] to differ from striping and for
    /// `replication_factor > 1` to pick the hot set. With
    /// `replication_factor = r > 1` the top-r ranked experts per layer
    /// are dealt to `min(r, n_devices)` homes each (primary first, then
    /// the next devices round the id space).
    pub fn build(
        kind: PlacementKind,
        n_layers: usize,
        n_experts: usize,
        n_devices: usize,
        popularity_rank: Option<&[Vec<usize>]>,
        replication_factor: usize,
    ) -> Self {
        assert!(n_devices >= 1, "placement needs >= 1 device");
        assert!(replication_factor >= 1, "replication_factor must be >= 1");
        let mut homes = vec![vec![0usize]; n_layers * n_experts];
        let mut fallback = false;
        if n_devices > 1 {
            match (kind, popularity_rank) {
                (PlacementKind::Popularity, Some(ranked)) => {
                    for l in 0..n_layers {
                        for (r, &e) in ranked[l].iter().enumerate() {
                            homes[l * n_experts + e][0] = r % n_devices;
                        }
                    }
                }
                (PlacementKind::Popularity, None) => {
                    log::warn!(
                        "popularity placement requested but no profiled rank is \
                         available; falling back to layer striping"
                    );
                    fallback = true;
                    Self::stripe(&mut homes, n_layers, n_experts, n_devices);
                }
                _ => Self::stripe(&mut homes, n_layers, n_experts, n_devices),
            }
        }
        let width = replication_factor.min(n_devices);
        let mut replicated = false;
        if width > 1 {
            match popularity_rank {
                Some(ranked) => {
                    let hot_n = replication_factor.min(n_experts);
                    for l in 0..n_layers {
                        for &e in ranked[l].iter().take(hot_n) {
                            let h = &mut homes[l * n_experts + e];
                            let primary = h[0];
                            for j in 1..width {
                                h.push((primary + j) % n_devices);
                            }
                            replicated = true;
                        }
                    }
                }
                None => log::warn!(
                    "replication_factor {replication_factor} requested but no \
                     popularity rank is available; experts stay single-homed"
                ),
            }
        }
        Self { n_layers, n_experts, n_devices, homes, kind, fallback, replicated }
    }

    fn stripe(homes: &mut [Vec<usize>], n_layers: usize, n_experts: usize, n_devices: usize) {
        for l in 0..n_layers {
            for e in 0..n_experts {
                homes[l * n_experts + e][0] = (e + l) % n_devices;
            }
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    fn idx(&self, k: ExpertKey) -> usize {
        debug_assert!(k.layer < self.n_layers && k.expert < self.n_experts);
        k.layer * self.n_experts + k.expert
    }

    /// Primary home device of an expert (demand fetches land here).
    pub fn device_of(&self, k: ExpertKey) -> usize {
        self.homes[self.idx(k)][0]
    }

    /// Full home set of an expert, primary first.
    pub fn homes(&self, k: ExpertKey) -> &[usize] {
        &self.homes[self.idx(k)]
    }

    /// Number of home devices of an expert (its replication intent).
    pub fn replication_of(&self, k: ExpertKey) -> usize {
        self.homes[self.idx(k)].len()
    }

    /// One layer's per-expert home sets (indexed by expert id) — the form
    /// [`HopContext`] consumes.
    pub fn layer_homes(&self, layer: usize) -> &[Vec<usize>] {
        &self.homes[layer * self.n_experts..(layer + 1) * self.n_experts]
    }

    /// Replace an expert's home set (online re-placement). The primary
    /// home must be preserved as the first entry; the set must be
    /// non-empty and within the fleet.
    pub fn set_homes(&mut self, k: ExpertKey, homes: Vec<usize>) {
        assert!(!homes.is_empty(), "an expert needs at least one home");
        debug_assert!(homes.iter().all(|&d| d < self.n_devices));
        if homes.len() > 1 {
            self.replicated = true;
        }
        let i = self.idx(k);
        self.homes[i] = homes;
    }

    /// How many of a layer's experts have `device` among their homes.
    pub fn experts_on(&self, layer: usize, device: usize) -> usize {
        self.layer_homes(layer).iter().filter(|h| h.contains(&device)).count()
    }

    /// Whether any expert has (ever had) more than one home.
    pub fn is_replicated(&self) -> bool {
        self.replicated
    }

    /// Whether popularity placement silently degraded to striping.
    pub fn fallback(&self) -> bool {
        self.fallback
    }

    /// Human-readable placement label for reports: the kind actually in
    /// effect, with the fallback made visible.
    pub fn label(&self) -> String {
        if self.fallback {
            format!("{}:striped-fallback", self.kind.name())
        } else {
            self.kind.name().to_string()
        }
    }
}

/// Pivot-relative hop lookup for one layer, fed into the substitution
/// engine so ψ's κ term sees real placement-derived hop counts scored
/// against the *nearest replica* (see the module docs for the
/// derivation).
#[derive(Debug, Clone, Copy)]
pub struct HopContext<'a> {
    /// This layer's per-expert home device sets ([`Placement::layer_homes`]).
    pub homes: &'a [Vec<usize>],
    /// Device×device hop matrix ([`Topology::hop_matrix`]).
    pub hop_matrix: &'a [Vec<usize>],
}

impl HopContext<'_> {
    /// Peer hops between the nearest (pivot replica, candidate replica)
    /// device pair.
    pub fn hops(&self, pivot: usize, cand: usize) -> usize {
        self.route(pivot, cand).2
    }

    /// The `(from_device, to_device, hops)` pair minimizing the hop count
    /// over both experts' home sets — the route the engine charges on the
    /// peer links. Ties break toward the first-listed homes (primary
    /// first), so the choice is deterministic.
    pub fn route(&self, pivot: usize, cand: usize) -> (usize, usize, usize) {
        let mut best: Option<(usize, usize, usize)> = None;
        for &a in &self.homes[pivot] {
            for &b in &self.homes[cand] {
                let h = self.hop_matrix[a][b];
                if best.map(|(_, _, bh)| h < bh).unwrap_or(true) {
                    best = Some((a, b, h));
                }
            }
        }
        best.expect("every expert has at least one home")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_hops_are_binary() {
        let t = Topology::new(4, TopologyKind::FullyConnected);
        assert_eq!(t.hops(2, 2), 0);
        assert_eq!(t.hops(0, 3), 1);
        assert_eq!(t.hop_matrix()[1][2], 1);
    }

    #[test]
    fn ring_hops_take_shorter_arc() {
        let t = Topology::new(4, TopologyKind::Ring);
        assert_eq!(t.hops(0, 1), 1);
        assert_eq!(t.hops(0, 2), 2);
        assert_eq!(t.hops(0, 3), 1, "wraps the short way");
        let t2 = Topology::new(2, TopologyKind::Ring);
        assert_eq!(t2.hops(0, 1), 1);
    }

    #[test]
    fn peer_paths_follow_topology() {
        let full = Topology::new(4, TopologyKind::FullyConnected);
        assert_eq!(full.n_peer_links(), 1, "one shared fabric");
        assert!(full.peer_path(2, 2).is_empty());
        assert_eq!(full.peer_path(0, 3), vec![0]);

        let ring = Topology::new(4, TopologyKind::Ring);
        assert_eq!(ring.n_peer_links(), 4);
        assert_eq!(ring.peer_path(0, 1), vec![0], "edge 0 connects 0 and 1");
        assert_eq!(ring.peer_path(0, 2), vec![0, 1], "two edges forward");
        assert_eq!(ring.peer_path(0, 3), vec![3], "wraps backward over edge 3");
        assert_eq!(ring.peer_path(3, 1), vec![3, 0], "forward across the wrap");

        let pair = Topology::new(2, TopologyKind::Ring);
        assert_eq!(pair.n_peer_links(), 1, "a 2-ring has a single edge");
        assert_eq!(pair.peer_path(1, 0), vec![0]);
    }

    #[test]
    fn single_placement_is_all_device_zero() {
        let p = Placement::single(2, 8);
        for l in 0..2 {
            for e in 0..8 {
                assert_eq!(p.device_of(ExpertKey::new(l, e)), 0);
                assert_eq!(p.homes(ExpertKey::new(l, e)), &[0]);
            }
        }
        assert_eq!(p.experts_on(0, 0), 8);
        assert!(!p.is_replicated());
    }

    #[test]
    fn striped_placement_is_even_and_layer_rotated() {
        let p = Placement::build(PlacementKind::LayerStriped, 2, 8, 2, None, 1);
        assert_eq!(p.device_of(ExpertKey::new(0, 0)), 0);
        assert_eq!(p.device_of(ExpertKey::new(0, 1)), 1);
        // Layer offset rotates the stripe.
        assert_eq!(p.device_of(ExpertKey::new(1, 0)), 1);
        for l in 0..2 {
            assert_eq!(p.experts_on(l, 0), 4);
            assert_eq!(p.experts_on(l, 1), 4);
        }
    }

    #[test]
    fn popularity_placement_deals_hot_experts_round_robin() {
        // Popularity rank for one layer: 5 hottest, then 2, 7, 0...
        let ranked = vec![vec![5, 2, 7, 0, 1, 3, 4, 6]];
        let p = Placement::build(PlacementKind::Popularity, 1, 8, 2, Some(&ranked), 1);
        assert_eq!(p.device_of(ExpertKey::new(0, 5)), 0, "hottest on device 0");
        assert_eq!(p.device_of(ExpertKey::new(0, 2)), 1, "2nd hottest on device 1");
        assert_eq!(p.device_of(ExpertKey::new(0, 7)), 0);
        assert_eq!(p.experts_on(0, 0), 4);
        assert_eq!(p.experts_on(0, 1), 4);
        assert!(!p.fallback());
        assert_eq!(p.label(), "popularity");
    }

    #[test]
    fn popularity_without_rank_flags_the_fallback() {
        let p = Placement::build(PlacementKind::Popularity, 1, 8, 2, None, 1);
        assert!(p.fallback(), "silent striping must be flagged");
        assert_eq!(p.label(), "popularity:striped-fallback");
        // The fallback *is* the stripe.
        let striped = Placement::build(PlacementKind::LayerStriped, 1, 8, 2, None, 1);
        for e in 0..8 {
            let k = ExpertKey::new(0, e);
            assert_eq!(p.device_of(k), striped.device_of(k));
        }
    }

    #[test]
    fn replication_deals_top_r_to_multiple_homes() {
        let ranked = vec![vec![5, 2, 7, 0, 1, 3, 4, 6]];
        let p = Placement::build(PlacementKind::Popularity, 1, 8, 4, Some(&ranked), 2);
        // Top-2 experts get 2 homes each: primary plus the next device.
        assert_eq!(p.homes(ExpertKey::new(0, 5)), &[0, 1]);
        assert_eq!(p.homes(ExpertKey::new(0, 2)), &[1, 2]);
        // Everyone else stays single-homed.
        assert_eq!(p.replication_of(ExpertKey::new(0, 7)), 1);
        assert!(p.is_replicated());
        // experts_on counts replicas: device 1 hosts its dealt share plus
        // expert 5's replica.
        assert_eq!(p.experts_on(0, 1), 3);
    }

    #[test]
    fn replication_width_caps_at_fleet_size() {
        let ranked = vec![vec![3, 1, 0, 2]];
        let p = Placement::build(PlacementKind::Popularity, 1, 4, 2, Some(&ranked), 4);
        // width = min(4, 2) = 2 homes; hot set = top-4 = every expert.
        for e in 0..4 {
            assert_eq!(p.replication_of(ExpertKey::new(0, e)), 2);
        }
    }

    #[test]
    fn set_homes_updates_replication() {
        let mut p = Placement::build(PlacementKind::LayerStriped, 1, 4, 2, None, 1);
        assert!(!p.is_replicated());
        let k = ExpertKey::new(0, 0);
        p.set_homes(k, vec![0, 1]);
        assert_eq!(p.homes(k), &[0, 1]);
        assert!(p.is_replicated());
        assert_eq!(p.experts_on(0, 1), 3);
    }

    #[test]
    fn hop_context_is_pivot_relative() {
        let homes = [vec![0usize], vec![1], vec![0]];
        let m = Topology::new(2, TopologyKind::FullyConnected).hop_matrix();
        let ctx = HopContext { homes: &homes, hop_matrix: &m };
        assert_eq!(ctx.hops(0, 2), 0, "same device");
        assert_eq!(ctx.hops(0, 1), 1, "cross device");
        assert_eq!(ctx.hops(1, 0), 1);
    }

    #[test]
    fn hop_context_scores_nearest_replica() {
        // Ring of 4: expert 1 lives on device 2 with a replica on device 1;
        // a pivot homed on device 0 must score the 1-hop replica, not the
        // 2-hop primary, and route to it.
        let homes = [vec![0usize], vec![2, 1]];
        let m = Topology::new(4, TopologyKind::Ring).hop_matrix();
        let ctx = HopContext { homes: &homes, hop_matrix: &m };
        assert_eq!(ctx.hops(0, 1), 1, "nearest replica wins");
        assert_eq!(ctx.route(0, 1), (0, 1, 1));
        // Ties break toward the first-listed (primary) home.
        let tied = [vec![0usize], vec![1, 3]];
        let ctx = HopContext { homes: &tied, hop_matrix: &m };
        assert_eq!(ctx.route(0, 1), (0, 1, 1));
    }

    #[test]
    fn parse_roundtrip() {
        for k in ["full", "ring"] {
            assert_eq!(TopologyKind::parse(k).unwrap().name(), k);
        }
        for k in ["striped", "popularity"] {
            assert_eq!(PlacementKind::parse(k).unwrap().name(), k);
        }
        assert!(TopologyKind::parse("torus").is_err());
        assert!(PlacementKind::parse("bogus").is_err());
    }
}
