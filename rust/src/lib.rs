//! # BuddyMoE
//!
//! A reproduction of *BuddyMoE: Exploiting Expert Redundancy to Accelerate
//! Memory-Constrained Mixture-of-Experts Inference* as a three-layer
//! rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request batching, the
//!   expert cache + PCIe offloading substrate, predictive prefetching, and
//!   the paper's contribution: offline co-activation profiling, CFT buddy
//!   lists, the TAE/distribution/Ψ gate pipeline, and Algorithm 1 buddy
//!   substitution. The [`traffic`] subsystem layers arrival-process
//!   generators and discrete-event admission on top, so tail latency
//!   under offered load is measurable on the virtual clock.
//! * **L2** — a miniature DeepSeek-V2-class MoE transformer written in JAX
//!   (`python/compile/model.py`), factored into per-stage functions and
//!   AOT-lowered to HLO text at build time.
//! * **L1** — Pallas kernels for the expert FFN, router, and decode
//!   attention (`python/compile/kernels/`), validated against pure-jnp
//!   oracles.
//!
//! Python never runs at serving time: the rust binary owns the entire
//! request path, executing stages through one of two backends
//! ([`runtime::StageRunner`]): the PJRT executor for `artifacts/*.hlo.txt`
//! (`xla` crate, behind the `pjrt` cargo feature) or a pure-Rust reference
//! interpreter of the same stage math that needs no artifacts at all —
//! the default build, and what the integration tests run end-to-end
//! against synthetic weights.
//!
//! ## Clock modes
//!
//! Every time consumer — PCIe transfers, compute-time accounting, batcher
//! deadlines, metrics, request timestamps, the table harness — reads one
//! [`util::clock::SimClock`], in one of two modes
//! ([`util::clock::ClockMode`]):
//!
//! * **`Virtual`** (default): discrete-event simulated time. Transfers
//!   and modeled compute advance a virtual timeline instead of sleeping;
//!   a full Tables 2–4 sweep finishes in milliseconds of wall time, and
//!   the same seed yields byte-identical reports (golden-tested). The
//!   compute model is `ServingConfig::sim_attn_s` per layer per step plus
//!   `ServingConfig::sim_expert_s` per expert invocation, against the
//!   PCIe link model's transfer durations — the paper's ~1 ms compute vs
//!   ~10 ms fetch race.
//! * **`RealTime`**: wall-clock execution — the transfer engine really
//!   sleeps for each simulated transfer and all measurements are genuine
//!   elapsed time (`EngineOptions::clock = ClockMode::RealTime`, or
//!   `--real-time` on the CLI).

pub mod buddy;
pub mod config;
pub mod eval;
pub mod fault;
pub mod memory;
pub mod model;
pub mod prefetch;
pub mod profilecollect;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod testing;
pub mod topology;
pub mod trace;
pub mod traffic;
pub mod util;
pub mod weights;
