//! # BuddyMoE
//!
//! A reproduction of *BuddyMoE: Exploiting Expert Redundancy to Accelerate
//! Memory-Constrained Mixture-of-Experts Inference* as a three-layer
//! rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request batching, the
//!   expert cache + PCIe offloading substrate, predictive prefetching, and
//!   the paper's contribution: offline co-activation profiling, CFT buddy
//!   lists, the TAE/distribution/Ψ gate pipeline, and Algorithm 1 buddy
//!   substitution.
//! * **L2** — a miniature DeepSeek-V2-class MoE transformer written in JAX
//!   (`python/compile/model.py`), factored into per-stage functions and
//!   AOT-lowered to HLO text at build time.
//! * **L1** — Pallas kernels for the expert FFN, router, and decode
//!   attention (`python/compile/kernels/`), validated against pure-jnp
//!   oracles.
//!
//! Python never runs at serving time: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and owns the
//! entire request path.

pub mod buddy;
pub mod config;
pub mod eval;
pub mod memory;
pub mod model;
pub mod prefetch;
pub mod profilecollect;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod testing;
pub mod util;
pub mod weights;
