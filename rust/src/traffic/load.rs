//! Load-sweep runner: tail latency under offered load.
//!
//! Drives [`Server::run`] with arrivals staged on the batcher's event
//! queue across a grid of (arrival process × offered load × miss policy)
//! cells, all under [`ClockMode::Virtual`] — a full sweep is a
//! discrete-event simulation that finishes in milliseconds of wall time
//! and is byte-identical per seed. Each cell records the serving metrics
//! the paper's "preserved throughput under load" claim actually needs:
//! TTFT, queue delay, time-between-tokens, end-to-end latency, and
//! admission-queue depth, as [`Summary`] percentile distributions.
//!
//! `examples/sweep_load.rs` renders the grid as a markdown table and
//! writes the machine-readable `BENCH_load.json` artifact.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{AdmissionControl, ModelConfig, ServingConfig};
use crate::eval::{engine_with_config, Domain};
use crate::fault::FaultPlan;
use crate::model::EngineOptions;
use crate::profilecollect::ProfileCollector;
use crate::server::{Server, SloClass};
use crate::stats::Summary;
use crate::topology::{PlacementKind, TopologyKind};
use crate::trace::{RequestAttribution, TraceSink};
use crate::util::clock::ClockMode;
use crate::util::json::{num, obj, s, Json};
use crate::weights::WeightStore;

use super::arrivals::{
    ArrivalProcess, BurstyProcess, ClosedLoopProcess, PoissonProcess, PromptSource,
};

/// Workload shape shared by every cell of one sweep.
#[derive(Debug, Clone)]
pub struct LoadSettings {
    /// Requests per cell.
    pub n_requests: usize,
    /// Decode tokens per request.
    pub max_new: usize,
    /// GPU-resident expert fraction (paper `c`): the memory pressure that
    /// makes miss policy matter.
    pub cache_rate: f64,
    pub domain: Domain,
    pub seed: u64,
    /// Record a trace per cell (`ServingConfig::trace = Ring`): every
    /// cell then carries the p99 request's stall attribution. Off by
    /// default — disabled sweeps stay byte-identical to the pre-trace
    /// goldens.
    pub trace: bool,
    /// Probability a generated request is tagged `SloClass::Interactive`
    /// (the rest are `Batch`). The default 1.0 tags everything
    /// Interactive *without* constructing the mixer RNG, so default
    /// prompt/arrival streams stay byte-identical to the pre-SLO
    /// generator.
    pub interactive_share: f64,
}

impl Default for LoadSettings {
    fn default() -> Self {
        Self {
            n_requests: 32,
            max_new: 8,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            trace: false,
            interactive_share: 1.0,
        }
    }
}

/// Arrival-process family for a sweep cell; `build` instantiates it at a
/// given offered load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcessKind {
    /// Open-loop Poisson at the offered rate.
    Poisson,
    /// On/off bursts: 2x the offered rate while bursting, silent while
    /// idle, equal mean dwell times — the same average load, much worse
    /// tails.
    Bursty,
    /// Closed loop: `round(offered_rps)` users (>= 1) with 50 ms mean
    /// think time.
    Closed,
}

impl ProcessKind {
    pub fn label(&self) -> &'static str {
        match self {
            ProcessKind::Poisson => "poisson",
            ProcessKind::Bursty => "bursty",
            ProcessKind::Closed => "closed",
        }
    }

    /// Instantiate the process at `offered_rps` for one cell. For the
    /// closed-loop kind the knob is repurposed as the user-population
    /// size (`round(offered_rps)` users), not a request rate — its
    /// achieved rate is population / (think + service time); compare
    /// closed cells by their `tok_s`, not `offered_rps`. Seeds are
    /// derived from the settings seed only, so the *open-loop* kinds
    /// replay the same arrival pattern per (kind, load) across miss
    /// policies — common random numbers. (Closed-loop timelines depend on
    /// completion times, which differ per policy, so CRN does not apply
    /// there.)
    pub fn build(
        &self,
        cfg: &ModelConfig,
        st: &LoadSettings,
        offered_rps: f64,
    ) -> Box<dyn ArrivalProcess> {
        // The SLO mixer draws from its own derived stream (a no-op at
        // the default share of 1.0 — see `PromptSource`).
        let src = PromptSource::new(cfg, st.seed, st.domain, st.max_new)
            .with_interactive_share(st.interactive_share, st.seed.wrapping_add(0x0000_510C_1A55));
        let proc_seed = st.seed.wrapping_add(0x0007_2AFF_1C00); // "traffic" stream
        match self {
            ProcessKind::Poisson => {
                Box::new(PoissonProcess::new(src, offered_rps, st.n_requests, proc_seed))
            }
            ProcessKind::Bursty => Box::new(BurstyProcess::new(
                src,
                2.0 * offered_rps,
                0.0,
                0.25,
                0.25,
                st.n_requests,
                proc_seed,
            )),
            ProcessKind::Closed => Box::new(ClosedLoopProcess::new(
                src,
                (offered_rps.round() as usize).max(1),
                0.05,
                st.n_requests,
                proc_seed,
            )),
        }
    }
}

/// Everything measured for one (process, load, policy) cell.
#[derive(Debug, Clone)]
pub struct LoadCell {
    /// Process label including its load knobs (`ArrivalProcess::name`).
    pub process: String,
    /// `ServingConfig::preset` name.
    pub policy: String,
    /// Nominal load knob for the cell: a request rate for open-loop
    /// processes, the user-population size for closed-loop (see
    /// [`ProcessKind::build`]).
    pub offered_rps: f64,
    pub requests_done: u64,
    pub tokens_out: u64,
    /// Virtual seconds from t=0 to the last completion.
    pub wall_s: f64,
    pub tok_s: f64,
    pub ttft: Summary,
    pub tbt: Summary,
    pub e2e: Summary,
    pub queue_delay: Summary,
    pub queue_depth: Summary,
    /// Stall attribution of the cell's p99 request (by end-to-end
    /// latency; deterministic tie-break on request id). `None` when the
    /// cell ran untraced.
    pub p99_attr: Option<RequestAttribution>,
}

/// Post-run engine state probed for the sweep reports: placement identity
/// is read back from the *live* engine (not echoed from the request), so
/// a popularity placement that silently fell back to striping is reported
/// as the fallback it actually ran as, and peer-link occupancy/replica
/// churn come from the same accounting the virtual clock charged.
#[derive(Debug, Clone)]
pub struct CellProbe {
    /// `Placement::label()` after the run (e.g. `popularity` or
    /// `popularity:striped-fallback`).
    pub placement: String,
    /// True when popularity placement degraded to striping for lack of a
    /// profiled rank.
    pub placement_fallback: bool,
    /// Seconds the peer links spent busy (sum over links).
    pub peer_busy_s: f64,
    /// Online re-placement churn: replicas promoted / demoted.
    pub replica_promotions: u64,
    pub replica_demotions: u64,
}

/// Serve one cell: stage the process's open-loop arrivals on the event
/// queue, hook completions back into it (closed-loop think time), run to
/// drain, and snapshot the metrics.
#[allow(clippy::too_many_arguments)]
pub fn run_load_cell(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<LoadCell> {
    let (cell, _probe) = run_load_cell_probed(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    Ok(cell)
}

/// [`run_load_cell`] plus the post-run [`CellProbe`].
#[allow(clippy::too_many_arguments)]
pub fn run_load_cell_probed(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, CellProbe)> {
    let (cell, probe, _fault) = run_fault_cell(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    Ok((cell, probe))
}

/// Fault-recovery accounting for one cell, read from the engine's
/// counters and the serving metrics after the run drained.
#[derive(Debug, Clone, Default)]
pub struct FaultProbe {
    /// Requests whose responses carry the degraded annotation.
    pub degraded_requests: u64,
    /// Routed expert-slot total (the availability denominator).
    pub routed_slots: u64,
    /// Slots dropped by the degradation waterfall's last arm.
    pub dropped_slots: u64,
    /// Fraction of routed slots served by their *true* expert — neither
    /// substituted nor dropped. The fault sweep's headline column: a
    /// replicated fleet holds availability through a device-down window
    /// that forces a single-homed fleet into substitution storms.
    pub availability: f64,
    pub substitutions: u64,
    /// Substitutions split by whether they landed inside a scheduled
    /// fault window.
    pub subs_in_window: u64,
    pub subs_outside_window: u64,
    pub drops_in_window: u64,
    pub drops_outside_window: u64,
    /// Waterfall arm 1: displaced experts served by surviving replicas.
    pub replica_hits: u64,
    /// Waterfall arm 2: buddy substitutions covering displaced experts.
    pub buddy_subs: u64,
    /// Waterfall arm 3: demand fetches that needed re-issues.
    pub retried_fetches: u64,
    /// Total transfer re-issues across all retried fetches.
    pub transfer_retries: u64,
    /// Timed-out fetches rescued losslessly via transient stream-through
    /// (only possible with the deadline disabled).
    pub transient_rescues: u64,
    /// Failover bookkeeping: experts rerouted to surviving replicas,
    /// single-homed experts rehomed, and home sets restored on recovery.
    pub failover_rerouted: u64,
    pub failover_rehomed: u64,
    pub failover_restored: u64,
    /// Replica copies promoted during failover, charged as peer transfers.
    pub emergency_promotions: u64,
}

/// Admission-layer accounting for one cell, read from the serving
/// metrics and the batcher's poll gauge after the run drained. All
/// zeros / empty on an admission-disabled cell (sheds cannot happen
/// without a gate), so probing it is free for the existing sweeps.
#[derive(Debug, Clone, Default)]
pub struct AdmissionProbe {
    /// Requests refused by the gate (disjoint from `requests_done`).
    pub shed_requests: u64,
    pub shed_interactive: u64,
    pub shed_batch: u64,
    /// Shed breakdown by reason.
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    /// shed / (shed + done) over the cell.
    pub shed_rate: f64,
    /// Brownout enter+exit edges and total browned-out virtual seconds.
    pub brownout_transitions: u64,
    pub brownout_dwell_s: f64,
    /// TTFT restricted to *admitted* requests of each SLO class — the
    /// overload acceptance bound is on the Interactive p99.9.
    pub ttft_interactive: Summary,
    pub ttft_batch: Summary,
    /// Batcher poll gauge: depth high-water mark and saturation, sampled
    /// on *every* release/admission poll (not just at admission, which
    /// undercounts between-step bursts).
    pub queue_depth_max: u64,
    pub batcher_polls: u64,
    pub saturated_polls: u64,
    /// Stall attribution of the p99 *admitted Interactive* request (by
    /// end-to-end latency; deterministic tie-break on id). `None` when
    /// the cell ran untraced or no Interactive request finished.
    pub p99_attr_interactive: Option<RequestAttribution>,
}

/// Exported trace of one traced cell: the Perfetto-loadable Chrome
/// trace-event document, the compact JSONL form, and every finished
/// request's stall attribution (completion order).
#[derive(Debug, Clone)]
pub struct TraceOutput {
    pub chrome_json: String,
    pub jsonl: String,
    pub attributions: Vec<RequestAttribution>,
}

/// Deterministic p99 pick over finished-request attributions: sort by
/// (end-to-end latency, id) and take the `ceil(0.99 n)`-th request.
fn p99_attribution(mut attrs: Vec<RequestAttribution>) -> Option<RequestAttribution> {
    if attrs.is_empty() {
        return None;
    }
    attrs.sort_by(|a, b| a.total().cmp(&b.total()).then(a.id.cmp(&b.id)));
    let n = attrs.len();
    let idx = ((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1;
    Some(attrs[idx])
}

/// [`run_load_cell_probed`] plus the post-run [`FaultProbe`] (zeros on a
/// fault-free cell).
#[allow(clippy::too_many_arguments)]
pub fn run_fault_cell(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, CellProbe, FaultProbe)> {
    let (cell, probe, fault, _adm, _) = run_cell_inner(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    Ok((cell, probe, fault))
}

/// [`run_load_cell`] plus the post-run [`AdmissionProbe`] (overload
/// sweeps; all-zero probe on an admission-disabled config).
#[allow(clippy::too_many_arguments)]
pub fn run_overload_cell(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, AdmissionProbe)> {
    let (cell, _probe, _fault, adm, _trace) = run_cell_inner(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    Ok((cell, adm))
}

/// [`run_fault_cell`] with tracing forced on: returns the exported
/// [`TraceOutput`] alongside the measurements.
#[allow(clippy::too_many_arguments)]
pub fn run_fault_cell_traced(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    mut scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, CellProbe, FaultProbe, TraceOutput)> {
    scfg.trace = TraceSink::Ring;
    let (cell, probe, fault, _adm, trace) = run_cell_inner(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    let trace = trace.expect("tracing was forced on; the engine must export a trace");
    Ok((cell, probe, fault, trace))
}

/// [`run_load_cell`] with tracing forced on.
#[allow(clippy::too_many_arguments)]
pub fn run_load_cell_traced(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, TraceOutput)> {
    let (cell, _probe, _fault, trace) = run_fault_cell_traced(
        cfg,
        store,
        collector,
        warm_rank,
        scfg,
        policy_label,
        offered_rps,
        process,
    )?;
    Ok((cell, trace))
}

#[allow(clippy::too_many_arguments)]
fn run_cell_inner(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    policy_label: &str,
    offered_rps: f64,
    mut process: Box<dyn ArrivalProcess>,
) -> Result<(LoadCell, CellProbe, FaultProbe, AdmissionProbe, Option<TraceOutput>)> {
    let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
    let engine = engine_with_config(cfg, store, collector, warm_rank, scfg, opts)?;
    let mut server = Server::new(engine);

    let process_name = process.name();
    server.batcher.stage_process(process.as_mut());
    // Terminal outcomes feed the process back (closed-loop next
    // arrivals); open-loop processes return None here. Sheds count too:
    // a rejected closed-loop user thinks and retries, which is the
    // admission layer's backpressure path.
    server.on_complete = Some(Box::new(move |now, _outcome, batcher| {
        if let Some(a) = process.on_completion(now) {
            batcher.stage_arrival(a.at, a.req);
        }
    }));
    server.batcher.close();

    let clock = server.engine.clock();
    let t0 = clock.now();
    let responses = server.run()?;
    let wall_s = clock.since(t0);

    // Ids of admitted Interactive completions, for the class-restricted
    // p99 attribution pick (BTreeSet: this feeds ordered report output).
    let interactive_ids: BTreeSet<u64> = responses
        .iter()
        .filter(|r| r.slo == SloClass::Interactive)
        .map(|r| r.id)
        .collect();

    // Trace export (before shutdown: the tracer lives in engine state).
    let (p99_attr, p99_attr_interactive, trace) = {
        let tracer = server.engine.tracer();
        if tracer.enabled() {
            let attributions = tracer.attributions();
            (
                p99_attribution(attributions.clone()),
                p99_attribution(
                    attributions
                        .iter()
                        .filter(|a| interactive_ids.contains(&a.id))
                        .cloned()
                        .collect(),
                ),
                Some(TraceOutput {
                    chrome_json: tracer.export_chrome(),
                    jsonl: tracer.export_jsonl(),
                    attributions,
                }),
            )
        } else {
            (None, None, None)
        }
    };

    let m = &server.metrics;
    let cell = LoadCell {
        process: process_name,
        policy: policy_label.to_string(),
        offered_rps,
        requests_done: m.requests_done,
        tokens_out: m.tokens_out,
        wall_s,
        tok_s: if wall_s > 0.0 { m.tokens_out as f64 / wall_s } else { 0.0 },
        ttft: m.ttft.clone(),
        tbt: m.tbt.clone(),
        e2e: m.request_latency.clone(),
        queue_delay: m.queue_delay.clone(),
        queue_depth: m.queue_depth.clone(),
        p99_attr,
    };
    let placement = server.engine.placement();
    let probe = CellProbe {
        placement: placement.label(),
        placement_fallback: placement.fallback(),
        peer_busy_s: server
            .engine
            .transfer_handle()
            .with_state(|st| st.peer_stats())
            .busy_seconds,
        replica_promotions: server.engine.counters.get("replica_promotions"),
        replica_demotions: server.engine.counters.get("replica_demotions"),
    };
    let ec = &server.engine.counters;
    let routed = ec.get("routed_slots");
    let dropped = ec.get("dropped_slots");
    let subs = ec.get("substitutions");
    let fault = FaultProbe {
        degraded_requests: server.metrics.degraded_requests,
        routed_slots: routed,
        dropped_slots: dropped,
        availability: if routed > 0 {
            1.0 - (dropped + subs) as f64 / routed as f64
        } else {
            1.0
        },
        substitutions: subs,
        subs_in_window: ec.get("subs_in_fault_window"),
        subs_outside_window: ec.get("subs_outside_fault_window"),
        drops_in_window: ec.get("drops_in_fault_window"),
        drops_outside_window: ec.get("drops_outside_fault_window"),
        replica_hits: ec.get("waterfall_replica_hits"),
        buddy_subs: ec.get("waterfall_buddy_subs"),
        retried_fetches: ec.get("waterfall_retried_fetches"),
        transfer_retries: ec.get("transfer_retries"),
        transient_rescues: ec.get("waterfall_transient_rescues"),
        failover_rerouted: ec.get("failover_rerouted"),
        failover_rehomed: ec.get("failover_rehomed"),
        failover_restored: ec.get("failover_restored"),
        emergency_promotions: ec.get("emergency_promotions"),
    };
    let m = &server.metrics;
    let poll = server.batcher.poll_stats();
    let terminal = m.shed_requests + m.requests_done;
    let adm = AdmissionProbe {
        shed_requests: m.shed_requests,
        shed_interactive: m.shed_interactive,
        shed_batch: m.shed_batch,
        shed_queue_full: m.shed_queue_full,
        shed_deadline: m.shed_deadline,
        shed_rate: if terminal > 0 {
            m.shed_requests as f64 / terminal as f64
        } else {
            0.0
        },
        brownout_transitions: m.brownout_transitions,
        brownout_dwell_s: m.brownout_dwell_s,
        ttft_interactive: m.ttft_interactive.clone(),
        ttft_batch: m.ttft_batch.clone(),
        queue_depth_max: poll.max_depth as u64,
        batcher_polls: poll.polls,
        saturated_polls: poll.saturated_polls,
        p99_attr_interactive,
    };
    server.engine.shutdown();
    Ok((cell, probe, fault, adm, trace))
}

/// The full grid: every (process kind × offered load × policy preset).
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub processes: Vec<ProcessKind>,
    pub loads_rps: Vec<f64>,
    /// `ServingConfig::preset` names.
    pub presets: Vec<String>,
    pub settings: LoadSettings,
}

pub fn run_sweep(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    spec: &SweepSpec,
) -> Result<Vec<LoadCell>> {
    let mut cells = Vec::new();
    for kind in &spec.processes {
        for &rps in &spec.loads_rps {
            for preset in &spec.presets {
                let mut scfg = ServingConfig::default().preset(preset)?;
                scfg.cache_rate = spec.settings.cache_rate;
                scfg.seed = spec.settings.seed;
                if spec.settings.trace {
                    scfg.trace = TraceSink::Ring;
                }
                let process = kind.build(cfg, &spec.settings, rps);
                cells.push(run_load_cell(
                    cfg,
                    store.clone(),
                    collector,
                    warm_rank,
                    scfg,
                    preset,
                    rps,
                    process,
                )?);
            }
        }
    }
    Ok(cells)
}

/// Markdown table over the sweep cells (deterministic formatting: the
/// golden determinism test asserts byte-identity per seed).
pub fn report_markdown(cells: &[LoadCell]) -> String {
    let mut out = String::from(
        "| process | rps | policy | done | tok/s | ttft p50/p95/p99 (ms) | \
         tbt p50/p95/p99 (ms) | e2e p99 (ms) | qdepth p95 |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {:.2} | {} | {} | {:.2} | {:.2}/{:.2}/{:.2} | {:.2}/{:.2}/{:.2} | {:.2} | {:.1} |\n",
            c.process,
            c.offered_rps,
            c.policy,
            c.requests_done,
            c.tok_s,
            c.ttft.p(50.0) * 1e3,
            c.ttft.p(95.0) * 1e3,
            c.ttft.p(99.0) * 1e3,
            c.tbt.p(50.0) * 1e3,
            c.tbt.p(95.0) * 1e3,
            c.tbt.p(99.0) * 1e3,
            c.e2e.p(99.0) * 1e3,
            c.queue_depth.p(95.0),
        ));
    }
    out
}

fn summary_json(x: &Summary) -> Json {
    obj(vec![
        ("mean", num(x.mean())),
        ("p50", num(x.p(50.0))),
        ("p95", num(x.p(95.0))),
        ("p99", num(x.p(99.0))),
        ("n", num(x.count() as f64)),
    ])
}

/// Machine-readable sweep (the `BENCH_load.json` payload).
pub fn cells_json(cells: &[LoadCell]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                let mut fields = vec![
                    ("process", s(&c.process)),
                    ("policy", s(&c.policy)),
                    ("offered_rps", num(c.offered_rps)),
                    ("requests_done", num(c.requests_done as f64)),
                    ("tokens_out", num(c.tokens_out as f64)),
                    ("wall_s", num(c.wall_s)),
                    ("tok_s", num(c.tok_s)),
                    ("ttft_s", summary_json(&c.ttft)),
                    ("tbt_s", summary_json(&c.tbt)),
                    ("e2e_s", summary_json(&c.e2e)),
                    ("queue_delay_s", summary_json(&c.queue_delay)),
                    ("queue_depth", summary_json(&c.queue_depth)),
                ];
                if let Some(a) = &c.p99_attr {
                    fields.push(("p99_attr", a.to_json()));
                }
                obj(fields)
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// Topology sweep: tail latency vs. expert-parallel device count
// ---------------------------------------------------------------------

/// The (device count × topology × replication factor × arrival process ×
/// miss policy) grid for the expert-parallel fleet: every cell serves the
/// same workload at the same offered load, varying the fleet shape (and,
/// for multi-device cells, turning κ on so ψ's topology term is live).
///
/// Degenerate-row dedup: on a one-device fleet every topology is the same
/// fleet and replication is meaningless, so `n_devices == 1` cells run
/// only for the first listed topology and `replication_factor == 1` —
/// those rows stay byte-identical to the pre-replication sweep.
#[derive(Debug, Clone)]
pub struct TopologySweep {
    /// Fleet sizes to compare (the acceptance grid is `[1, 2, 4]`).
    pub device_counts: Vec<usize>,
    /// Peer-interconnect shapes to compare.
    pub topologies: Vec<TopologyKind>,
    /// Home-set widths to compare; cells with a factor > 1 switch to
    /// popularity placement (replication deals the top-R *ranked* experts,
    /// so it needs the profiled rank popularity placement uses).
    pub replication_factors: Vec<usize>,
    /// Arrival-process families (the replication win shows under
    /// [`ProcessKind::Bursty`] tails).
    pub processes: Vec<ProcessKind>,
    /// `ServingConfig::preset` names.
    pub presets: Vec<String>,
    /// Open-loop offered load shared by every cell.
    pub load_rps: f64,
    /// ψ hop penalty κ applied when `n_devices > 1` (0 keeps ψ
    /// topology-blind; single-device cells always keep the preset's κ so
    /// they stay byte-identical to the non-topology sweeps).
    pub kappa: f64,
    pub settings: LoadSettings,
}

/// One topology-sweep row: a [`LoadCell`] measured at a fleet shape, plus
/// the post-run [`CellProbe`] (placement as-run, peer-link occupancy,
/// replica churn).
#[derive(Debug, Clone)]
pub struct TopologyCell {
    pub n_devices: usize,
    /// `TopologyKind::name()` of the peer interconnect.
    pub topology: &'static str,
    pub replication_factor: usize,
    /// `ProcessKind::label()` of the arrival process.
    pub process: &'static str,
    pub probe: CellProbe,
    pub cell: LoadCell,
}

pub fn run_topology_sweep(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    spec: &TopologySweep,
) -> Result<Vec<TopologyCell>> {
    let mut rows = Vec::new();
    for &n in &spec.device_counts {
        for (ti, &topo) in spec.topologies.iter().enumerate() {
            if n == 1 && ti > 0 {
                continue; // one device: every topology is the same fleet
            }
            for &rf in &spec.replication_factors {
                if n == 1 && rf != 1 {
                    continue; // one device: replication is meaningless
                }
                for &kind in &spec.processes {
                    for preset in &spec.presets {
                        let mut scfg = ServingConfig::default().preset(preset)?;
                        scfg.cache_rate = spec.settings.cache_rate;
                        scfg.seed = spec.settings.seed;
                        scfg.n_devices = n;
                        scfg.topology = topo;
                        if n > 1 {
                            scfg.kappa = spec.kappa;
                        }
                        if rf > 1 {
                            scfg.replication_factor = rf;
                            scfg.placement = PlacementKind::Popularity;
                        }
                        if spec.settings.trace {
                            scfg.trace = TraceSink::Ring;
                        }
                        let process = kind.build(cfg, &spec.settings, spec.load_rps);
                        let (cell, probe) = run_load_cell_probed(
                            cfg,
                            store.clone(),
                            collector,
                            warm_rank,
                            scfg,
                            preset,
                            spec.load_rps,
                            process,
                        )?;
                        rows.push(TopologyCell {
                            n_devices: n,
                            topology: topo.name(),
                            replication_factor: rf,
                            process: kind.label(),
                            probe,
                            cell,
                        });
                    }
                }
            }
        }
    }
    Ok(rows)
}

/// Markdown table over the topology rows (deterministic formatting; the
/// determinism test asserts byte-identity per seed). The `placement`
/// column is the probed post-run label, so a popularity fallback shows up
/// as `popularity:striped-fallback` instead of masquerading as the
/// requested placement.
pub fn topology_report_markdown(rows: &[TopologyCell]) -> String {
    let mut out = String::from(
        "| devices | topo | repl | process | placement | policy | done | tok/s | \
         ttft p50/p95/p99 (ms) | tbt p99 (ms) | e2e p99 (ms) | peer busy (ms) |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let c = &r.cell;
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {:.2} | {:.2}/{:.2}/{:.2} | {:.2} | {:.2} | {:.3} |\n",
            r.n_devices,
            r.topology,
            r.replication_factor,
            r.process,
            r.probe.placement,
            c.policy,
            c.requests_done,
            c.tok_s,
            c.ttft.p(50.0) * 1e3,
            c.ttft.p(95.0) * 1e3,
            c.ttft.p(99.0) * 1e3,
            c.tbt.p(99.0) * 1e3,
            c.e2e.p(99.0) * 1e3,
            r.probe.peer_busy_s * 1e3,
        ));
    }
    out
}

/// Machine-readable topology sweep (the `BENCH_topology.json` payload):
/// per-fleet-shape tail-latency rows.
pub fn topology_cells_json(rows: &[TopologyCell]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("n_devices", num(r.n_devices as f64)),
                    ("topology", s(r.topology)),
                    ("replication_factor", num(r.replication_factor as f64)),
                    ("process", s(r.process)),
                    ("placement", s(&r.probe.placement)),
                    ("placement_fallback", Json::Bool(r.probe.placement_fallback)),
                    ("policy", s(&r.cell.policy)),
                    ("offered_rps", num(r.cell.offered_rps)),
                    ("requests_done", num(r.cell.requests_done as f64)),
                    ("tokens_out", num(r.cell.tokens_out as f64)),
                    ("wall_s", num(r.cell.wall_s)),
                    ("tok_s", num(r.cell.tok_s)),
                    ("peer_busy_s", num(r.probe.peer_busy_s)),
                    ("replica_promotions", num(r.probe.replica_promotions as f64)),
                    ("replica_demotions", num(r.probe.replica_demotions as f64)),
                    ("ttft_s", summary_json(&r.cell.ttft)),
                    ("tbt_s", summary_json(&r.cell.tbt)),
                    ("e2e_s", summary_json(&r.cell.e2e)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// Fault sweep: availability and degradation under injected chaos
// ---------------------------------------------------------------------

/// The (fault scenario × replication factor × miss policy) grid on a
/// fixed fleet shape: every cell serves the same seeded workload while a
/// [`FaultPlan::scenario`] injects device/link chaos on the virtual
/// clock. The acceptance story: a replicated fleet rides out a
/// device-down window with zero dropped experts and near-baseline
/// availability, while the single-homed fleet degrades into substitution
/// storms and tail blowup.
#[derive(Debug, Clone)]
pub struct FaultSweep {
    /// `FaultPlan::scenario` names; include `"baseline"` for the
    /// fault-free reference rows.
    pub scenarios: Vec<String>,
    /// Fleet shape shared by every cell (the acceptance grid is a
    /// 4-device ring).
    pub n_devices: usize,
    pub topology: TopologyKind,
    /// Home-set widths to compare; factors > 1 switch to popularity
    /// placement (as in [`TopologySweep`]).
    pub replication_factors: Vec<usize>,
    /// `ServingConfig::preset` names.
    pub presets: Vec<String>,
    pub process: ProcessKind,
    pub load_rps: f64,
    /// Per-transfer deadline applied to every cell (`0` disables: timed
    /// out fetches then fall back to lossless transient rescues instead
    /// of drops).
    pub transfer_deadline_s: f64,
    pub settings: LoadSettings,
}

/// One fault-sweep row.
#[derive(Debug, Clone)]
pub struct FaultCell {
    /// `FaultPlan::scenario` name.
    pub scenario: String,
    pub replication_factor: usize,
    pub probe: CellProbe,
    pub fault: FaultProbe,
    pub cell: LoadCell,
}

pub fn run_fault_sweep(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    spec: &FaultSweep,
) -> Result<Vec<FaultCell>> {
    let mut rows = Vec::new();
    for scenario in &spec.scenarios {
        let plan = FaultPlan::scenario(scenario)
            .ok_or_else(|| anyhow::anyhow!("unknown fault scenario '{scenario}'"))?;
        for &rf in &spec.replication_factors {
            for preset in &spec.presets {
                let mut scfg = ServingConfig::default().preset(preset)?;
                scfg.cache_rate = spec.settings.cache_rate;
                scfg.seed = spec.settings.seed;
                scfg.n_devices = spec.n_devices;
                scfg.topology = spec.topology;
                scfg.fault_plan = plan.clone();
                scfg.transfer_deadline_s = spec.transfer_deadline_s;
                if rf > 1 {
                    scfg.replication_factor = rf;
                    scfg.placement = PlacementKind::Popularity;
                }
                if spec.settings.trace {
                    scfg.trace = TraceSink::Ring;
                }
                let process = spec.process.build(cfg, &spec.settings, spec.load_rps);
                let (cell, probe, fault) = run_fault_cell(
                    cfg,
                    store.clone(),
                    collector,
                    warm_rank,
                    scfg,
                    preset,
                    spec.load_rps,
                    process,
                )?;
                rows.push(FaultCell {
                    scenario: scenario.clone(),
                    replication_factor: rf,
                    probe,
                    fault,
                    cell,
                });
            }
        }
    }
    Ok(rows)
}

/// Markdown table over the fault rows (deterministic formatting; the
/// determinism test asserts byte-identity per seed).
pub fn fault_report_markdown(rows: &[FaultCell]) -> String {
    let mut out = String::from(
        "| scenario | repl | policy | done | degraded | avail | dropped | \
         subs in/out | replica hits | buddy subs | retries | rescues | \
         ttft p99 (ms) | tbt p99 (ms) |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let c = &r.cell;
        let f = &r.fault;
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.4} | {} | {}/{} | {} | {} | {} | {} | {:.2} | {:.2} |\n",
            r.scenario,
            r.replication_factor,
            c.policy,
            c.requests_done,
            f.degraded_requests,
            f.availability,
            f.dropped_slots,
            f.subs_in_window,
            f.subs_outside_window,
            f.replica_hits,
            f.buddy_subs,
            f.retried_fetches,
            f.transient_rescues,
            c.ttft.p(99.0) * 1e3,
            c.tbt.p(99.0) * 1e3,
        ));
    }
    out
}

/// Machine-readable fault sweep (the `BENCH_faults.json` payload).
pub fn fault_cells_json(rows: &[FaultCell]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let f = &r.fault;
                let mut fields = vec![
                    ("scenario", s(&r.scenario)),
                    ("replication_factor", num(r.replication_factor as f64)),
                    ("policy", s(&r.cell.policy)),
                    ("requests_done", num(r.cell.requests_done as f64)),
                    ("tokens_out", num(r.cell.tokens_out as f64)),
                    ("tok_s", num(r.cell.tok_s)),
                    ("degraded_requests", num(f.degraded_requests as f64)),
                    ("availability", num(f.availability)),
                    ("routed_slots", num(f.routed_slots as f64)),
                    ("dropped_slots", num(f.dropped_slots as f64)),
                    ("substitutions", num(f.substitutions as f64)),
                    ("subs_in_window", num(f.subs_in_window as f64)),
                    ("subs_outside_window", num(f.subs_outside_window as f64)),
                    ("drops_in_window", num(f.drops_in_window as f64)),
                    ("drops_outside_window", num(f.drops_outside_window as f64)),
                    ("replica_hits", num(f.replica_hits as f64)),
                    ("buddy_subs", num(f.buddy_subs as f64)),
                    ("retried_fetches", num(f.retried_fetches as f64)),
                    ("transfer_retries", num(f.transfer_retries as f64)),
                    ("transient_rescues", num(f.transient_rescues as f64)),
                    ("failover_rerouted", num(f.failover_rerouted as f64)),
                    ("failover_rehomed", num(f.failover_rehomed as f64)),
                    ("failover_restored", num(f.failover_restored as f64)),
                    ("emergency_promotions", num(f.emergency_promotions as f64)),
                    ("ttft_s", summary_json(&r.cell.ttft)),
                    ("tbt_s", summary_json(&r.cell.tbt)),
                    ("e2e_s", summary_json(&r.cell.e2e)),
                ];
                if let Some(a) = &r.cell.p99_attr {
                    fields.push(("p99_attr", a.to_json()));
                }
                obj(fields)
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// Overload sweep: SLO admission control vs FIFO past the knee
// ---------------------------------------------------------------------

/// Admission mode of an overload-sweep cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Admission control disabled: the seed FIFO serving loop. Under
    /// sustained overload its queue grows without bound and every class's
    /// TTFT collapses together.
    Fifo,
    /// SLO-aware gate: bounded queue, deadline-unmeetable shedding,
    /// priority batch composition, and brownout coupling.
    Slo,
}

impl AdmissionMode {
    pub fn label(&self) -> &'static str {
        match self {
            AdmissionMode::Fifo => "fifo",
            AdmissionMode::Slo => "slo",
        }
    }
}

/// The (offered load × policy preset × admission mode) overload grid:
/// MMPP bursts at rates past the FIFO saturation knee, a mixed
/// Interactive/Batch population, comparing the FIFO seed loop against
/// the SLO gate on the *admitted-Interactive* tail.
#[derive(Debug, Clone)]
pub struct OverloadSweep {
    /// Offered loads (requests/second); pick the top entries ≥ 1.5× the
    /// FIFO knee so the acceptance bound is exercised.
    pub loads_rps: Vec<f64>,
    /// `ServingConfig::preset` names.
    pub presets: Vec<String>,
    pub admissions: Vec<AdmissionMode>,
    /// Arrival family ([`ProcessKind::Bursty`] for the acceptance grid).
    pub process: ProcessKind,
    /// Gate knobs applied to the `Slo` cells
    /// ([`AdmissionControl::overload_protect`]).
    pub interactive_ttft_slo_s: f64,
    pub batch_ttft_slo_s: f64,
    pub queue_cap: usize,
    pub settings: LoadSettings,
}

/// One overload-sweep row.
#[derive(Debug, Clone)]
pub struct OverloadCell {
    /// `AdmissionMode::label()`.
    pub admission: &'static str,
    /// `ProcessKind::label()` of the arrival family.
    pub process: &'static str,
    pub probe: AdmissionProbe,
    pub cell: LoadCell,
}

pub fn run_overload_sweep(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    spec: &OverloadSweep,
) -> Result<Vec<OverloadCell>> {
    let mut rows = Vec::new();
    for &rps in &spec.loads_rps {
        for preset in &spec.presets {
            for &mode in &spec.admissions {
                let mut scfg = ServingConfig::default().preset(preset)?;
                scfg.cache_rate = spec.settings.cache_rate;
                scfg.seed = spec.settings.seed;
                if mode == AdmissionMode::Slo {
                    scfg.admission = AdmissionControl::overload_protect(
                        spec.interactive_ttft_slo_s,
                        spec.batch_ttft_slo_s,
                        spec.queue_cap,
                    );
                }
                if spec.settings.trace {
                    scfg.trace = TraceSink::Ring;
                }
                let process = spec.process.build(cfg, &spec.settings, rps);
                let (cell, probe) = run_overload_cell(
                    cfg,
                    store.clone(),
                    collector,
                    warm_rank,
                    scfg,
                    preset,
                    rps,
                    process,
                )?;
                rows.push(OverloadCell {
                    admission: mode.label(),
                    process: spec.process.label(),
                    probe,
                    cell,
                });
            }
        }
    }
    Ok(rows)
}

/// Markdown table over the overload rows (deterministic formatting; the
/// determinism test asserts byte-identity per seed). `ttft_i` is the
/// admitted-Interactive TTFT — the column the acceptance bound reads.
pub fn overload_report_markdown(rows: &[OverloadCell]) -> String {
    let mut out = String::from(
        "| process | rps | policy | admission | done | shed | shed rate | \
         brownout | ttft_i p50/p99/p99.9 (ms) | ttft_b p99 (ms) | qdepth max |\n\
         |---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let c = &r.cell;
        let p = &r.probe;
        out.push_str(&format!(
            "| {} | {:.2} | {} | {} | {} | {} | {:.4} | {}x/{:.3}s | {:.2}/{:.2}/{:.2} | {:.2} | {} |\n",
            r.process,
            c.offered_rps,
            c.policy,
            r.admission,
            c.requests_done,
            p.shed_requests,
            p.shed_rate,
            p.brownout_transitions,
            p.brownout_dwell_s,
            p.ttft_interactive.p(50.0) * 1e3,
            p.ttft_interactive.p(99.0) * 1e3,
            p.ttft_interactive.p(99.9) * 1e3,
            p.ttft_batch.p(99.0) * 1e3,
            p.queue_depth_max,
        ));
    }
    out
}

/// [`summary_json`] plus the p99.9 the overload acceptance bound reads.
fn summary_json_p999(x: &Summary) -> Json {
    obj(vec![
        ("mean", num(x.mean())),
        ("p50", num(x.p(50.0))),
        ("p95", num(x.p(95.0))),
        ("p99", num(x.p(99.0))),
        ("p999", num(x.p(99.9))),
        ("n", num(x.count() as f64)),
    ])
}

/// Machine-readable overload sweep (the `BENCH_overload.json` payload).
pub fn overload_cells_json(rows: &[OverloadCell]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let p = &r.probe;
                let mut fields = vec![
                    ("process", s(r.process)),
                    ("policy", s(&r.cell.policy)),
                    ("admission", s(r.admission)),
                    ("offered_rps", num(r.cell.offered_rps)),
                    ("requests_done", num(r.cell.requests_done as f64)),
                    ("tokens_out", num(r.cell.tokens_out as f64)),
                    ("wall_s", num(r.cell.wall_s)),
                    ("tok_s", num(r.cell.tok_s)),
                    ("shed_requests", num(p.shed_requests as f64)),
                    ("shed_interactive", num(p.shed_interactive as f64)),
                    ("shed_batch", num(p.shed_batch as f64)),
                    ("shed_queue_full", num(p.shed_queue_full as f64)),
                    ("shed_deadline", num(p.shed_deadline as f64)),
                    ("shed_rate", num(p.shed_rate)),
                    ("brownout_transitions", num(p.brownout_transitions as f64)),
                    ("brownout_dwell_s", num(p.brownout_dwell_s)),
                    ("queue_depth_max", num(p.queue_depth_max as f64)),
                    ("batcher_polls", num(p.batcher_polls as f64)),
                    ("saturated_polls", num(p.saturated_polls as f64)),
                    ("ttft_interactive_s", summary_json_p999(&p.ttft_interactive)),
                    ("ttft_batch_s", summary_json_p999(&p.ttft_batch)),
                    ("ttft_s", summary_json_p999(&r.cell.ttft)),
                    ("e2e_s", summary_json(&r.cell.e2e)),
                    ("queue_delay_s", summary_json(&r.cell.queue_delay)),
                ];
                if let Some(a) = &p.p99_attr_interactive {
                    fields.push(("p99_attr_interactive", a.to_json()));
                }
                obj(fields)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn process_kinds_build_at_any_load() {
        let cfg = ModelConfig::test_tiny();
        let st = LoadSettings { n_requests: 4, ..Default::default() };
        for kind in [ProcessKind::Poisson, ProcessKind::Bursty, ProcessKind::Closed] {
            let mut p = kind.build(&cfg, &st, 3.0);
            assert!(p.next_arrival().is_some(), "{} must emit", kind.label());
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn report_header_is_stable() {
        let md = report_markdown(&[]);
        assert!(md.starts_with("| process | rps | policy |"));
        assert_eq!(md.lines().count(), 2);
    }

    #[test]
    fn topology_report_header_is_stable() {
        let md = topology_report_markdown(&[]);
        assert!(md.starts_with("| devices | topo | repl | process | placement | policy |"));
        assert_eq!(md.lines().count(), 2);
        assert_eq!(topology_cells_json(&[]).to_string(), "[]");
    }

    #[test]
    fn fault_report_header_is_stable() {
        let md = fault_report_markdown(&[]);
        assert!(md.starts_with("| scenario | repl | policy | done | degraded | avail |"));
        assert_eq!(md.lines().count(), 2);
        assert_eq!(fault_cells_json(&[]).to_string(), "[]");
    }

    #[test]
    fn overload_report_header_is_stable() {
        let md = overload_report_markdown(&[]);
        assert!(md.starts_with("| process | rps | policy | admission | done | shed |"));
        assert_eq!(md.lines().count(), 2);
        assert_eq!(overload_cells_json(&[]).to_string(), "[]");
    }

    #[test]
    fn default_settings_keep_slo_tagging_inert() {
        let st = LoadSettings::default();
        assert_eq!(st.interactive_share, 1.0);
        let cfg = ModelConfig::test_tiny();
        let mut p = ProcessKind::Poisson.build(&cfg, &st, 10.0);
        while let Some(a) = p.next_arrival() {
            assert_eq!(a.req.slo, SloClass::Interactive);
        }
    }
}
