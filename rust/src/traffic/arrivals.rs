//! Seeded arrival-process generators: request streams on the virtual
//! timeline.
//!
//! Every generator implements [`ArrivalProcess`], producing
//! `(virtual_timestamp, InferenceRequest)` pairs ([`Arrival`]) from a
//! single seed — the same seed always produces the same stream, so load
//! sweeps are exactly reproducible. Request bodies come from the eval
//! workload generator ([`WorkloadGen`]) via a [`PromptSource`], so traffic
//! runs route through the same easy/hard expert-pressure domains the
//! paper's tables use.
//!
//! Processes:
//! * [`PoissonProcess`] — open-loop, exponential inter-arrivals at a fixed
//!   offered rate (requests/second).
//! * [`BurstyProcess`] — MMPP-style two-state on/off modulation: a burst
//!   state and an idle state, each with its own arrival rate and
//!   exponentially distributed dwell time. Models flash crowds.
//! * [`ClosedLoopProcess`] — a fixed population of users with think time:
//!   at most `concurrency` requests are ever outstanding; each completion
//!   (reported via [`ArrivalProcess::on_completion`]) schedules the next
//!   request after an exponential think pause.
//! * [`TraceReplay`] — replays a JSONL trace of timestamps (optionally
//!   with explicit prompts), validated to be time-monotone at parse time.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::eval::{Domain, WorkloadGen};
use crate::server::{InferenceRequest, SloClass};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One generated arrival: a request and the virtual instant it lands.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at: Duration,
    pub req: InferenceRequest,
}

impl Arrival {
    /// Build an arrival, stamping the request's `arrival_time` with `at`.
    pub fn new(at: Duration, req: InferenceRequest) -> Self {
        Self { at, req: req.arriving_at(at) }
    }
}

/// A seeded stream of request arrivals on the virtual timeline.
pub trait ArrivalProcess {
    /// The next open-loop arrival, or `None` when the stream is exhausted.
    fn next_arrival(&mut self) -> Option<Arrival>;

    /// React to a request completing at virtual time `now`. Open-loop
    /// processes ignore completions; closed-loop processes schedule the
    /// population's next request (after think time) here.
    fn on_completion(&mut self, _now: Duration) -> Option<Arrival> {
        None
    }

    /// Human-readable label for reports (includes the offered-load knobs).
    fn name(&self) -> String;
}

/// Request-body factory: eval-workload prompts with sequential ids.
#[derive(Debug, Clone)]
pub struct PromptSource {
    gen: WorkloadGen,
    domain: Domain,
    next_id: u64,
    /// SLO-class mixer: present only for a genuine mix (`0 < share < 1`).
    /// `None` at the default share of 1.0 — every request is Interactive
    /// and *no* RNG is constructed or drawn, keeping default streams
    /// byte-identical to the pre-SLO generator.
    slo_mix: Option<(f64, Rng)>,
}

impl PromptSource {
    pub fn new(cfg: &ModelConfig, seed: u64, domain: Domain, max_new: usize) -> Self {
        let mut gen = WorkloadGen::new(cfg, seed);
        gen.max_new = max_new;
        Self { gen, domain, next_id: 0, slo_mix: None }
    }

    /// Builder: tag each generated request `Interactive` with probability
    /// `share` (else `Batch`), drawn from a dedicated seeded stream.
    /// `share >= 1.0` (the default) and `share <= 0.0` are degenerate —
    /// all-Interactive / all-Batch with no RNG stream at all.
    pub fn with_interactive_share(mut self, share: f64, seed: u64) -> Self {
        assert!(share.is_finite(), "interactive share must be finite");
        self.slo_mix = if share > 0.0 && share < 1.0 {
            Some((share, Rng::new(seed)))
        } else if share <= 0.0 {
            // All-Batch: encode as a mix with probability 0 and no draws.
            Some((0.0, Rng::new(seed)))
        } else {
            None
        };
        self
    }

    /// Next request body (sequential id, workload-domain prompt, SLO tag).
    pub fn next_request(&mut self) -> InferenceRequest {
        let id = self.next_id;
        self.next_id += 1;
        let req = self.gen.request(self.domain, id);
        match self.slo_mix.as_mut() {
            None => req,
            Some((share, _)) if *share <= 0.0 => req.with_slo(SloClass::Batch),
            Some((share, rng)) => {
                let slo = if rng.f64() < *share {
                    SloClass::Interactive
                } else {
                    SloClass::Batch
                };
                req.with_slo(slo)
            }
        }
    }

    /// As `next_request`, with optional prompt / length overrides (trace
    /// replay lines that carry explicit bodies).
    pub fn next_request_with(
        &mut self,
        prompt: Option<Vec<i32>>,
        max_new: Option<usize>,
    ) -> InferenceRequest {
        let mut req = self.next_request();
        if let Some(p) = prompt {
            req.prompt = p;
        }
        if let Some(m) = max_new {
            req.max_new = m;
        }
        req
    }
}

// ---------------------------------------------------------------------
// Poisson (open loop)
// ---------------------------------------------------------------------

/// Open-loop Poisson arrivals at `rate_rps` requests/second.
pub struct PoissonProcess {
    src: PromptSource,
    rng: Rng,
    rate_rps: f64,
    remaining: usize,
    t_s: f64,
}

impl PoissonProcess {
    pub fn new(src: PromptSource, rate_rps: f64, n_requests: usize, seed: u64) -> Self {
        assert!(rate_rps > 0.0, "poisson rate must be positive");
        Self { src, rng: Rng::new(seed), rate_rps, remaining: n_requests, t_s: 0.0 }
    }
}

impl ArrivalProcess for PoissonProcess {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.t_s += self.rng.exponential(1.0 / self.rate_rps);
        Some(Arrival::new(
            Duration::from_secs_f64(self.t_s),
            self.src.next_request(),
        ))
    }

    fn name(&self) -> String {
        format!("poisson({:.2} rps)", self.rate_rps)
    }
}

// ---------------------------------------------------------------------
// Bursty on/off (MMPP-style, two states)
// ---------------------------------------------------------------------

/// Two-state Markov-modulated Poisson process: `burst_rps` arrivals while
/// in the burst state, `idle_rps` (often 0) while idle, with exponential
/// state dwell times `mean_burst_s` / `mean_idle_s`.
pub struct BurstyProcess {
    src: PromptSource,
    rng: Rng,
    burst_rps: f64,
    idle_rps: f64,
    mean_burst_s: f64,
    mean_idle_s: f64,
    remaining: usize,
    t_s: f64,
    in_burst: bool,
    state_end_s: f64,
}

impl BurstyProcess {
    pub fn new(
        src: PromptSource,
        burst_rps: f64,
        idle_rps: f64,
        mean_burst_s: f64,
        mean_idle_s: f64,
        n_requests: usize,
        seed: u64,
    ) -> Self {
        assert!(burst_rps > 0.0, "burst rate must be positive");
        assert!(idle_rps >= 0.0, "idle rate must be non-negative");
        assert!(
            mean_burst_s > 0.0 && mean_idle_s > 0.0,
            "state dwell times must be positive"
        );
        let mut rng = Rng::new(seed);
        let state_end_s = rng.exponential(mean_burst_s);
        Self {
            src,
            rng,
            burst_rps,
            idle_rps,
            mean_burst_s,
            mean_idle_s,
            remaining: n_requests,
            t_s: 0.0,
            in_burst: true,
            state_end_s,
        }
    }

    /// Long-run average offered rate (state-time-weighted).
    pub fn mean_rate_rps(&self) -> f64 {
        (self.burst_rps * self.mean_burst_s + self.idle_rps * self.mean_idle_s)
            / (self.mean_burst_s + self.mean_idle_s)
    }
}

impl ArrivalProcess for BurstyProcess {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        loop {
            let rate = if self.in_burst { self.burst_rps } else { self.idle_rps };
            if rate > 0.0 {
                // Memorylessness lets us redraw the inter-arrival on every
                // state boundary instead of carrying residuals across.
                let dt = self.rng.exponential(1.0 / rate);
                if self.t_s + dt <= self.state_end_s {
                    self.t_s += dt;
                    self.remaining -= 1;
                    return Some(Arrival::new(
                        Duration::from_secs_f64(self.t_s),
                        self.src.next_request(),
                    ));
                }
            }
            // No arrival fits before the state flips: jump to the boundary.
            self.t_s = self.state_end_s;
            self.in_burst = !self.in_burst;
            let mean = if self.in_burst { self.mean_burst_s } else { self.mean_idle_s };
            self.state_end_s = self.t_s + self.rng.exponential(mean);
        }
    }

    fn name(&self) -> String {
        format!(
            "bursty({:.2}/{:.2} rps, {:.2}s/{:.2}s)",
            self.burst_rps, self.idle_rps, self.mean_burst_s, self.mean_idle_s
        )
    }
}

// ---------------------------------------------------------------------
// Closed loop with think time
// ---------------------------------------------------------------------

/// Fixed user population: `concurrency` requests outstanding at most; each
/// completion schedules the next request after exponential think time.
pub struct ClosedLoopProcess {
    src: PromptSource,
    rng: Rng,
    concurrency: usize,
    think_s: f64,
    /// Requests not yet emitted (initial wave + completion follow-ups).
    remaining: usize,
    /// How many of the initial at-t=0 wave are still to emit.
    initial_left: usize,
}

impl ClosedLoopProcess {
    pub fn new(
        src: PromptSource,
        concurrency: usize,
        think_s: f64,
        n_requests: usize,
        seed: u64,
    ) -> Self {
        assert!(concurrency >= 1, "closed loop needs at least one user");
        assert!(think_s >= 0.0, "think time must be non-negative");
        Self {
            src,
            rng: Rng::new(seed),
            concurrency,
            think_s,
            remaining: n_requests,
            initial_left: concurrency.min(n_requests),
        }
    }
}

impl ArrivalProcess for ClosedLoopProcess {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.initial_left == 0 {
            return None; // further arrivals only via on_completion
        }
        self.initial_left -= 1;
        self.remaining -= 1;
        Some(Arrival::new(Duration::ZERO, self.src.next_request()))
    }

    fn on_completion(&mut self, now: Duration) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let think = if self.think_s > 0.0 {
            self.rng.exponential(self.think_s)
        } else {
            0.0
        };
        Some(Arrival::new(
            now + Duration::from_secs_f64(think),
            self.src.next_request(),
        ))
    }

    fn name(&self) -> String {
        format!("closed(n={}, think {:.2}s)", self.concurrency, self.think_s)
    }
}

// ---------------------------------------------------------------------
// Trace replay (JSONL)
// ---------------------------------------------------------------------

/// Replays a JSONL arrival trace. Each non-empty line is an object:
///
/// ```json
/// {"at_ms": 12.5}
/// {"at_ms": 14.0, "prompt": [3, 9, 17], "max_new": 8}
/// ```
///
/// `at_ms` (virtual milliseconds since t=0) is required and must be
/// non-decreasing line to line; `prompt` / `max_new` override the workload
/// generator's body when present. A synthetic example trace ships at
/// `rust/tests/data/example_trace.jsonl`.
pub struct TraceReplay {
    src: PromptSource,
    /// Remaining entries, soonest first (reversed so `pop` is the front).
    entries: Vec<TraceEntry>,
    label: String,
}

#[derive(Debug, Clone)]
struct TraceEntry {
    at: Duration,
    prompt: Option<Vec<i32>>,
    max_new: Option<usize>,
}

impl TraceReplay {
    pub fn from_path(path: &std::path::Path, src: PromptSource) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let label = path
            .file_name()
            .map(|f| f.to_string_lossy().into_owned())
            .unwrap_or_else(|| "trace".into());
        Self::parse(&text, src, label)
    }

    pub fn from_text(text: &str, src: PromptSource) -> Result<Self> {
        Self::parse(text, src, "inline".into())
    }

    fn parse(text: &str, src: PromptSource, label: String) -> Result<Self> {
        let mut entries = Vec::new();
        let mut prev = Duration::ZERO;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
            let at_ms = j
                .get("at_ms")
                .and_then(|v| v.as_f64())
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
            if !(at_ms.is_finite() && at_ms >= 0.0) {
                bail!("trace line {}: at_ms must be finite and >= 0", lineno + 1);
            }
            let at = Duration::from_secs_f64(at_ms / 1e3);
            if at < prev {
                bail!(
                    "trace line {}: timestamps must be non-decreasing ({:?} after {:?})",
                    lineno + 1,
                    at,
                    prev
                );
            }
            prev = at;
            let prompt = match j.get("prompt") {
                Ok(v) => Some(
                    v.as_usize_vec()
                        .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?
                        .into_iter()
                        .map(|x| x as i32)
                        .collect(),
                ),
                Err(_) => None,
            };
            let max_new = match j.get("max_new") {
                Ok(v) => Some(
                    v.as_usize()
                        .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
                ),
                Err(_) => None,
            };
            entries.push(TraceEntry { at, prompt, max_new });
        }
        entries.reverse(); // pop() from the back = chronological order
        Ok(Self { src, entries, label })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl ArrivalProcess for TraceReplay {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let e = self.entries.pop()?;
        let req = self.src.next_request_with(e.prompt, e.max_new);
        Some(Arrival::new(e.at, req))
    }

    fn name(&self) -> String {
        format!("trace({})", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(seed: u64) -> PromptSource {
        let cfg = ModelConfig::test_tiny();
        PromptSource::new(&cfg, seed, Domain::Mixed, 4)
    }

    fn drain(p: &mut dyn ArrivalProcess) -> Vec<Arrival> {
        let mut out = Vec::new();
        while let Some(a) = p.next_arrival() {
            out.push(a);
        }
        out
    }

    #[test]
    fn poisson_is_seed_deterministic_and_monotone() {
        let mut a = PoissonProcess::new(src(1), 100.0, 32, 9);
        let mut b = PoissonProcess::new(src(1), 100.0, 32, 9);
        let xs = drain(&mut a);
        let ys = drain(&mut b);
        assert_eq!(xs.len(), 32);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.arrival_time, Some(x.at));
        }
        for w in xs.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let ids: Vec<u64> = xs.iter().map(|a| a.req.id).collect();
        assert_eq!(ids, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn bursty_is_monotone_and_finite() {
        let mut p = BurstyProcess::new(src(2), 200.0, 0.0, 0.05, 0.05, 64, 3);
        let xs = drain(&mut p);
        assert_eq!(xs.len(), 64);
        for w in xs.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn bursty_mean_rate_weighs_states() {
        let p = BurstyProcess::new(src(2), 100.0, 0.0, 1.0, 1.0, 1, 3);
        assert!((p.mean_rate_rps() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn closed_loop_initial_wave_is_bounded_by_concurrency() {
        let mut p = ClosedLoopProcess::new(src(3), 4, 0.1, 100, 5);
        let initial = drain(&mut p);
        assert_eq!(initial.len(), 4);
        assert!(initial.iter().all(|a| a.at == Duration::ZERO));
        // A completion releases exactly one follow-up, after think time.
        let now = Duration::from_millis(500);
        let next = p.on_completion(now).unwrap();
        assert!(next.at >= now);
        assert!(p.on_completion(now).is_some());
    }

    #[test]
    fn closed_loop_respects_total_budget() {
        let mut p = ClosedLoopProcess::new(src(3), 8, 0.0, 3, 5);
        assert_eq!(drain(&mut p).len(), 3, "initial wave capped by budget");
        assert!(p.on_completion(Duration::ZERO).is_none());
    }

    #[test]
    fn trace_replay_parses_and_overrides() {
        let text = "\n{\"at_ms\": 1.5}\n{\"at_ms\": 4.0, \"prompt\": [3, 9], \"max_new\": 2}\n";
        let mut t = TraceReplay::from_text(text, src(4)).unwrap();
        assert_eq!(t.len(), 2);
        let a = t.next_arrival().unwrap();
        assert_eq!(a.at, Duration::from_micros(1500));
        let b = t.next_arrival().unwrap();
        assert_eq!(b.at, Duration::from_millis(4));
        assert_eq!(b.req.prompt, vec![3, 9]);
        assert_eq!(b.req.max_new, 2);
        assert!(t.next_arrival().is_none());
    }

    #[test]
    fn slo_mix_is_deterministic_and_degenerate_at_the_edges() {
        use crate::server::SloClass;
        // Default / share=1.0: all Interactive, and the prompt stream is
        // identical to an untagged source (no RNG draws interleave).
        let mut plain = src(7);
        let mut full = src(7).with_interactive_share(1.0, 99);
        for _ in 0..16 {
            let a = plain.next_request();
            let b = full.next_request();
            assert_eq!(b.slo, SloClass::Interactive);
            assert_eq!(a.prompt, b.prompt);
        }
        // share=0.0: all Batch, prompts still identical.
        let mut none = src(7).with_interactive_share(0.0, 99);
        let mut plain2 = src(7);
        for _ in 0..16 {
            assert_eq!(none.next_request().slo, SloClass::Batch);
            let _ = plain2.next_request();
        }
        // A genuine mix is seeded: same seed → same class sequence, and
        // both classes appear.
        let seq = |seed: u64| -> Vec<SloClass> {
            let mut s = src(7).with_interactive_share(0.5, seed);
            (0..64).map(|_| s.next_request().slo).collect()
        };
        let a = seq(11);
        assert_eq!(a, seq(11));
        assert!(a.contains(&SloClass::Interactive));
        assert!(a.contains(&SloClass::Batch));
    }

    #[test]
    fn trace_replay_rejects_time_regressions() {
        let text = "{\"at_ms\": 5.0}\n{\"at_ms\": 4.0}\n";
        assert!(TraceReplay::from_text(text, src(4)).is_err());
        assert!(TraceReplay::from_text("{\"at_ms\": -1}", src(4)).is_err());
        assert!(TraceReplay::from_text("{\"nope\": 1}", src(4)).is_err());
    }
}
