//! Discrete-event admission queue: future request arrivals keyed on
//! virtual time.
//!
//! An [`EventQueue`] holds `(virtual_timestamp, InferenceRequest)` pairs in
//! a min-heap ordered by arrival time (FIFO within equal timestamps). The
//! [`DynamicBatcher`](crate::server::DynamicBatcher) owns one: staged
//! arrivals are *released* into the live admission queue as the shared
//! clock reaches their timestamps, which is what lets the virtual-clock
//! batching window observe mid-window arrivals — and close early on a full
//! batch — exactly as the real-time path does when another thread calls
//! `submit`.
//!
//! The queue itself is clock-agnostic: it just answers "what is the next
//! arrival time?" (`peek_time`) and "give me everything due by `now`"
//! (`pop_due`). All clock movement stays in the batcher.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Duration;

use crate::server::InferenceRequest;

use super::arrivals::ArrivalProcess;

struct Entry {
    at: Duration,
    /// Monotone push sequence number: FIFO tie-break for equal timestamps,
    /// which keeps replayed traces (and same-instant bursts) in submission
    /// order deterministically.
    seq: u64,
    req: InferenceRequest,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    /// Reversed (earliest first): `BinaryHeap` is a max-heap, so the
    /// greatest entry must be the soonest arrival.
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Min-heap of future arrivals on the virtual timeline.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stage a request to arrive at virtual time `at`.
    pub fn push(&mut self, at: Duration, req: InferenceRequest) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { at, seq, req });
    }

    /// Drain an arrival process's open-loop stream into the queue.
    pub fn extend_from(&mut self, process: &mut dyn ArrivalProcess) {
        while let Some(a) = process.next_arrival() {
            self.push(a.at, a.req);
        }
    }

    /// Timestamp of the soonest staged arrival, if any.
    pub fn peek_time(&self) -> Option<Duration> {
        self.heap.peek().map(|e| e.at)
    }

    /// Remove and return every arrival with timestamp `<= now`, in
    /// (time, push-order) order.
    pub fn pop_due(&mut self, now: Duration) -> Vec<(Duration, InferenceRequest)> {
        let mut due = Vec::new();
        while self.heap.peek().is_some_and(|e| e.at <= now) {
            let e = self.heap.pop().expect("peek() just reported a due arrival");
            due.push((e.at, e.req));
        }
        due
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2], 4)
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ms(30), req(3));
        q.push(ms(10), req(1));
        q.push(ms(20), req(2));
        let due = q.pop_due(ms(25));
        assert_eq!(due.iter().map(|(_, r)| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(due[0].0, ms(10));
        assert_eq!(q.peek_time(), Some(ms(30)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn equal_timestamps_stay_fifo() {
        let mut q = EventQueue::new();
        for id in 0..5 {
            q.push(ms(7), req(id));
        }
        let ids: Vec<u64> = q.pop_due(ms(7)).into_iter().map(|(_, r)| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn nothing_due_before_first_arrival() {
        let mut q = EventQueue::new();
        q.push(ms(50), req(1));
        assert!(q.pop_due(ms(49)).is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn pop_due_on_empty_queue_is_noop() {
        // Regression for the unwrap-audit sweep: pop_due's inner pop is
        // guarded by peek(), so an empty queue must drain to nothing
        // rather than hitting the "due arrival" invariant.
        let mut q = EventQueue::new();
        assert!(q.pop_due(ms(1_000)).is_empty());
        assert!(q.is_empty());
    }
}
