//! Traffic subsystem: request arrival processes, discrete-event
//! admission, and tail-latency-under-load evaluation.
//!
//! The eval harness's offline mode (submit everything, close, drain)
//! measures throughput but can't say anything about *tail latency under
//! load* — the regime where prefetch misses stall PCIe and buddy
//! substitution is supposed to pay off. This module supplies the missing
//! pieces, all on the PR-1 virtual clock so a full load sweep is a
//! deterministic discrete-event simulation:
//!
//! * [`arrivals`] — seeded arrival-process generators behind the
//!   [`ArrivalProcess`] trait: open-loop Poisson, bursty on/off
//!   (MMPP-style), closed-loop with think time, and JSONL trace replay.
//!   Request bodies come from the eval workload generator, so traffic
//!   exercises the same easy/hard expert-pressure domains as the tables.
//! * [`events`] — the [`EventQueue`] of future arrivals (min-heap on
//!   virtual time) that the [`crate::server::DynamicBatcher`] releases
//!   requests from as the clock reaches their timestamps. This is what
//!   lets the virtual batching window close early on a full batch instead
//!   of assuming no request can land mid-window.
//! * [`load`] — the sweep runners: the (arrival process × offered load ×
//!   miss policy) grid, each cell recording TTFT / queue delay / TBT / e2e
//!   latency / queue depth percentiles (rendered by
//!   `examples/sweep_load.rs` into `BENCH_load.json`), and the topology
//!   sweep over (device count × miss policy) for the expert-parallel fleet
//!   (rendered by `examples/sweep_topology.rs` into
//!   `BENCH_topology.json`), and the fault sweep over (fault scenario ×
//!   replication factor × miss policy) measuring availability and
//!   degradation under injected device/link chaos (rendered by
//!   `examples/sweep_faults.rs` into `BENCH_faults.json`), and the
//!   overload sweep over (offered load × admission mode) comparing the
//!   FIFO seed loop against SLO-aware admission control past the
//!   saturation knee (rendered by `examples/sweep_overload.rs` into
//!   `BENCH_overload.json`).

pub mod arrivals;
pub mod events;
pub mod load;

pub use arrivals::{
    Arrival, ArrivalProcess, BurstyProcess, ClosedLoopProcess, PoissonProcess, PromptSource,
    TraceReplay,
};
pub use events::EventQueue;
pub use load::{
    cells_json, fault_cells_json, fault_report_markdown, overload_cells_json,
    overload_report_markdown, report_markdown, run_fault_cell, run_fault_cell_traced,
    run_fault_sweep, run_load_cell, run_load_cell_probed, run_load_cell_traced,
    run_overload_cell, run_overload_sweep, run_sweep, run_topology_sweep, topology_cells_json,
    topology_report_markdown, AdmissionMode, AdmissionProbe, CellProbe, FaultCell, FaultProbe,
    FaultSweep, LoadCell, LoadSettings, OverloadCell, OverloadSweep, ProcessKind, SweepSpec,
    TopologyCell, TopologySweep, TraceOutput,
};
