//! Serving-time configuration: memory budget, PCIe model, BuddyMoE gate
//! parameters, miss policy, and the preset grids used by Tables 2–4.

use anyhow::{bail, Result};

use crate::fault::FaultPlan;
use crate::topology::{PlacementKind, Topology, TopologyKind};
use crate::trace::TraceSink;

/// What to do when a selected expert is CPU-resident (paper §5.1 baselines
/// plus the BuddyMoE policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissPolicy {
    /// Synchronously fetch the true expert over PCIe (lossless, slow).
    OnDemand,
    /// Substitute a uniformly random GPU-resident expert (fast, lossy).
    Random,
    /// Drop the expert from the computation and renormalize the rest.
    Drop,
    /// BuddyMoE: gated substitution with a CFT buddy list; falls back to
    /// OnDemand when gates forbid or no buddy is resident.
    Buddy,
}

impl MissPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "on-demand" | "original" => MissPolicy::OnDemand,
            "random" => MissPolicy::Random,
            "drop" => MissPolicy::Drop,
            "buddy" => MissPolicy::Buddy,
            other => bail!("unknown miss policy '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MissPolicy::OnDemand => "on-demand",
            MissPolicy::Random => "random",
            MissPolicy::Drop => "drop",
            MissPolicy::Buddy => "buddy",
        }
    }
}

/// Expert prefetcher flavour (paper §2.3 related systems).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchKind {
    /// No prefetching: every miss is an on-demand load.
    None,
    /// Historical activation frequency (MoE-Infinity-style).
    TopFreq,
    /// Run layer l+1's router on layer l's hidden state (Pre-gated-style,
    /// the Figure 3 pipeline).
    PreGate,
    /// Oracle with a controllable false-negative rate (Table 1 harness).
    OracleNoisy,
}

impl PrefetchKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => PrefetchKind::None,
            "topfreq" => PrefetchKind::TopFreq,
            "pregate" => PrefetchKind::PreGate,
            "oracle" => PrefetchKind::OracleNoisy,
            other => bail!("unknown prefetcher '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrefetchKind::None => "none",
            PrefetchKind::TopFreq => "topfreq",
            PrefetchKind::PreGate => "pregate",
            PrefetchKind::OracleNoisy => "oracle",
        }
    }
}

/// SLO-aware admission control, backpressure, and brownout degradation.
///
/// Disabled (the default) is the byte-identical degenerate case, matching
/// the `FaultPlan` contract: no gate is constructed, no queue cap applies,
/// no shed decision is ever made, no brownout controller runs, and every
/// existing golden sweep reproduces exactly. All transitions the enabled
/// policy makes are driven by the shared [`crate::util::clock::SimClock`]
/// and seeded state only — never the wall clock.
#[derive(Debug, Clone)]
pub struct AdmissionControl {
    /// Master switch. `false` (default) short-circuits everything below.
    pub enabled: bool,
    /// Hard staging-queue depth cap: a request that would push the
    /// admission queue past this depth is shed with `ShedReason::QueueFull`
    /// (backpressure). 0 = unbounded (the pre-admission behavior).
    pub queue_cap: usize,
    /// TTFT budget for `SloClass::Interactive`, simulated seconds.
    pub interactive_ttft_slo_s: f64,
    /// TTFT budget for `SloClass::Batch`, simulated seconds (loose).
    pub batch_ttft_slo_s: f64,
    /// Shed requests whose TTFT budget is already unmeetable at staging,
    /// estimated from live queue depth × recent per-slot drain time plus
    /// the recent prefill tail (`ShedReason::DeadlineUnmeetable`). Never
    /// fires before the first completed request seeds the estimator.
    pub shed_unmeetable: bool,
    /// EWMA smoothing factor for the drain-time / queue-delay estimators,
    /// in (0, 1]; 1 = no smoothing (latest observation wins).
    pub ewma_alpha: f64,
    /// At saturation (more queued than free slots), compose batches by
    /// (tightest remaining budget, largest expert-working-set overlap with
    /// the device residency masks) instead of FIFO.
    pub priority_compose: bool,
    /// Brownout enter threshold: when EWMA(queue delay) / interactive TTFT
    /// budget crosses this ratio, the engine shifts miss handling toward ψ
    /// buddy substitution and tightens the transfer deadline. 0 disables
    /// brownout entirely.
    pub brownout_enter_ratio: f64,
    /// Brownout exit threshold (hysteresis): leave brownout when the EWMA
    /// ratio drops back below this. Must be < enter ratio.
    pub brownout_exit_ratio: f64,
    /// TAE gate τ used while browned out (more permissive than the
    /// configured `tae_tau`, so more misses resolve by ψ substitution
    /// instead of demand fetch). Only meaningful under `MissPolicy::Buddy`.
    pub brownout_tae_tau: f64,
    /// Transfer deadline while browned out, simulated seconds: tightens
    /// (or introduces) `transfer_deadline_s` so stragglers hit the
    /// degradation waterfall instead of stalling the batch. 0 keeps the
    /// configured deadline unchanged.
    pub brownout_transfer_deadline_s: f64,
}

impl AdmissionControl {
    /// The degenerate case: everything off, byte-identical to the
    /// pre-admission system.
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            queue_cap: 0,
            interactive_ttft_slo_s: 0.25,
            batch_ttft_slo_s: 2.5,
            shed_unmeetable: false,
            ewma_alpha: 0.2,
            priority_compose: false,
            brownout_enter_ratio: 0.0,
            brownout_exit_ratio: 0.0,
            brownout_tae_tau: 0.45,
            brownout_transfer_deadline_s: 0.0,
        }
    }

    /// A full overload-protection policy: bounded queue, deadline
    /// shedding, priority batch composition, and brownout coupling to the
    /// degradation waterfall. Budgets are in simulated seconds and should
    /// be sized against the configured compute model.
    pub fn overload_protect(interactive_ttft_slo_s: f64, batch_ttft_slo_s: f64, queue_cap: usize) -> Self {
        Self {
            enabled: true,
            queue_cap,
            interactive_ttft_slo_s,
            batch_ttft_slo_s,
            shed_unmeetable: true,
            ewma_alpha: 0.2,
            priority_compose: true,
            brownout_enter_ratio: 0.5,
            brownout_exit_ratio: 0.25,
            brownout_tae_tau: 0.45,
            brownout_transfer_deadline_s: 0.02,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if !(self.interactive_ttft_slo_s.is_finite() && self.interactive_ttft_slo_s > 0.0) {
            bail!("interactive_ttft_slo_s must be finite and positive when admission is enabled");
        }
        if !(self.batch_ttft_slo_s.is_finite() && self.batch_ttft_slo_s > 0.0) {
            bail!("batch_ttft_slo_s must be finite and positive when admission is enabled");
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            bail!("ewma_alpha must be in (0,1]");
        }
        if self.brownout_enter_ratio != 0.0 {
            if !(self.brownout_enter_ratio.is_finite() && self.brownout_enter_ratio > 0.0) {
                bail!("brownout_enter_ratio must be finite and positive (0 disables)");
            }
            if !(self.brownout_exit_ratio.is_finite()
                && self.brownout_exit_ratio >= 0.0
                && self.brownout_exit_ratio < self.brownout_enter_ratio)
            {
                bail!("brownout_exit_ratio must be in [0, brownout_enter_ratio) for hysteresis");
            }
            if !(0.0..=1.0).contains(&self.brownout_tae_tau) {
                bail!("brownout_tae_tau must be in [0,1]");
            }
            if !(self.brownout_transfer_deadline_s.is_finite()
                && self.brownout_transfer_deadline_s >= 0.0)
            {
                bail!("brownout_transfer_deadline_s must be finite and non-negative (0 keeps the configured deadline)");
            }
        }
        Ok(())
    }
}

/// Full serving configuration. Field names follow the paper's symbols.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Fraction of each layer's experts kept GPU-resident (paper `c`).
    pub cache_rate: f64,
    /// Simulated PCIe bandwidth GPU<-CPU, bytes/second.
    pub pcie_bandwidth: f64,
    /// Simulated fixed per-transfer latency, seconds.
    pub pcie_base_latency: f64,
    /// Artificial scaling of expert bytes for the latency model, so one
    /// mini expert (384 KiB real) costs what one DeepSeek-V2-Lite expert
    /// (~thousands of KiB over 16 GB/s, i.e. ~10 ms) costs in the paper.
    pub transfer_bytes_scale: f64,

    // --- expert-parallel topology (crate::topology) ---
    /// Number of simulated expert-parallel GPUs. 1 (the default) is the
    /// single-device configuration and is byte-identical to the
    /// pre-topology system; each device gets its own expert cache and its
    /// own serialized host link.
    pub n_devices: usize,
    /// Peer-interconnect shape: hop counts for ψ's κ penalty and for the
    /// cross-device dispatch cost of substituted buddies.
    pub topology: TopologyKind,
    /// Expert→device placement strategy.
    pub placement: PlacementKind,
    /// Peer (GPU↔GPU) link bandwidth, bytes/second. NVLink-class: fast
    /// next to the host link, so a peer hop beats a host round trip.
    pub peer_bandwidth: f64,
    /// Peer link per-hop base latency, seconds.
    pub peer_base_latency: f64,
    /// Home-set width intent for popularity-hot experts: the top-R ranked
    /// experts per layer are dealt to `min(R, n_devices)` home devices
    /// each, so hot dispatches stay local. 1 (the default) keeps every
    /// expert single-homed and is byte-identical to the pre-replication
    /// system. Replicas consume real cache slots out of the same budget.
    pub replication_factor: usize,
    /// Decode steps between online re-placement passes: the engine reads
    /// live per-expert use counters and promotes/demotes replicas as the
    /// traffic mix drifts, charging promotions as real peer transfers.
    /// 0 disables online re-placement; only active when
    /// `replication_factor > 1` on a multi-device fleet.
    pub replan_interval_steps: usize,
    pub miss_policy: MissPolicy,
    pub prefetch: PrefetchKind,
    /// Oracle prefetcher false-negative rate (Table 1 harness only).
    pub oracle_miss_rate: f64,
    /// Max experts prefetched per (layer, step).
    pub prefetch_width: usize,

    // --- BuddyMoE gates (paper §3.1) ---
    /// TAE threshold tau: forbid substitution when TAE <= tau.
    pub tae_tau: f64,
    /// Optional probability-margin threshold gamma (None = disabled).
    pub margin_gamma: Option<f64>,
    /// Distribution-gate threshold beta: bypass substitution when the
    /// CPU-resident fraction of requested experts >= beta.
    pub dist_beta: f64,
    /// CFT alpha for buddy-list construction.
    pub cft_alpha: f64,
    /// Cap on buddy-list length (paper K_max).
    pub k_max: usize,
    /// Maximum buddy search rank at runtime (paper Algorithm 1 H).
    pub search_h: usize,
    /// Per-token replacement budget rho (None = unlimited).
    pub rho: Option<usize>,
    /// Psi score: local router-logit compatibility weight eta.
    pub eta: f64,
    /// Psi score: cross-partition hop penalty kappa.
    pub kappa: f64,
    /// Psi score: multiplicative diversity discount for re-picking the
    /// same buddy for one token.
    pub diversity_discount: f64,

    // --- fault injection & recovery ---
    /// Scheduled device/link faults applied as discrete events on the
    /// virtual clock. Empty (the default) injects nothing and keeps the
    /// system byte-identical to the fault-free build.
    pub fault_plan: FaultPlan,
    /// Per-awaited-transfer deadline, simulated seconds: a waiter that
    /// exceeds it abandons the fetch and the engine's degradation
    /// waterfall decides what happens next. 0 disables deadlines (a
    /// waiter retries until its bounded re-issues are exhausted).
    pub transfer_deadline_s: f64,
    /// Bounded re-issues per awaited transfer after its in-flight copy
    /// vanishes (fault, or a completion lost to a device failure). The
    /// first re-issue is immediate — matching the pre-fault engine —
    /// and later ones back off exponentially with seeded jitter.
    pub transfer_max_retries: u32,
    /// Base of the exponential retry backoff, simulated seconds.
    pub transfer_backoff_base_s: f64,

    // --- admission control & overload protection ---
    /// SLO-aware admission gate, backpressure, and brownout policy.
    /// Disabled (the default) is the byte-identical degenerate case.
    pub admission: AdmissionControl,

    // --- observability (crate::trace) ---
    /// Trace sink: `Off` (the default) is the zero-cost no-op — no
    /// recorder is allocated and every golden sweep is byte-identical to
    /// a build without tracing. `Ring` records SimClock-stamped spans
    /// into bounded in-memory rings, exportable as Perfetto-loadable
    /// Chrome trace JSON or JSONL.
    pub trace: TraceSink,
    /// Global trace-ring capacity in events (per-request flight
    /// recorders use `trace::recorder::PER_REQUEST_RING`).
    pub trace_ring: usize,

    // --- serving shape ---
    pub max_batch: usize,
    pub batch_timeout_us: u64,
    pub seed: u64,

    // --- virtual-clock compute model ---
    /// Simulated seconds of non-expert compute (attention + router) per
    /// layer per step. Only consumed by the virtual clock; under a
    /// real-time clock compute takes the real time it takes.
    pub sim_attn_s: f64,
    /// Simulated seconds per expert-FFN invocation (paper §2.2: expert
    /// compute ~1 ms vs ~10 ms PCIe fetch — that 10:1 ratio is the whole
    /// scheduling game).
    pub sim_expert_s: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            cache_rate: 0.75,
            // 16 GB/s PCIe 4.0-ish with 10us base latency; bytes scale
            // chosen so one expert transfer ~= 9.8 ms (paper Table 1 says
            // 9-10 ms): 98304 B * 1600 / 16e9 ~= 9.8e-3 s.
            pcie_bandwidth: 16e9,
            pcie_base_latency: 10e-6,
            transfer_bytes_scale: 1600.0,
            n_devices: 1,
            topology: TopologyKind::FullyConnected,
            placement: PlacementKind::LayerStriped,
            // NVLink-ish: 64 GB/s with single-digit-microsecond latency —
            // a peer hop costs ~µs where a host fetch costs ~10 ms.
            peer_bandwidth: 64e9,
            peer_base_latency: 3e-6,
            replication_factor: 1,
            replan_interval_steps: 32,
            miss_policy: MissPolicy::Buddy,
            prefetch: PrefetchKind::TopFreq,
            oracle_miss_rate: 0.0,
            prefetch_width: 12,
            tae_tau: 0.95,
            margin_gamma: None,
            dist_beta: 0.9,
            cft_alpha: 0.8,
            k_max: 16,
            search_h: 16,
            rho: Some(3),
            eta: 0.0,
            kappa: 0.0,
            diversity_discount: 0.5,
            fault_plan: FaultPlan::empty(),
            transfer_deadline_s: 0.0,
            transfer_max_retries: 4,
            transfer_backoff_base_s: 2e-3,
            admission: AdmissionControl::disabled(),
            trace: TraceSink::Off,
            trace_ring: 1 << 16,
            max_batch: 8,
            batch_timeout_us: 2_000,
            seed: 0x00ddf00d,
            sim_attn_s: 0.3e-3,
            sim_expert_s: 1.0e-3,
        }
    }
}

impl ServingConfig {
    /// Simulated seconds to move one expert of `bytes` real bytes.
    pub fn transfer_seconds(&self, bytes: usize) -> f64 {
        self.pcie_base_latency + (bytes as f64 * self.transfer_bytes_scale) / self.pcie_bandwidth
    }

    /// Experts per layer kept on GPU for `n_experts` total.
    pub fn gpu_experts_per_layer(&self, n_experts: usize) -> usize {
        ((n_experts as f64) * self.cache_rate).round() as usize
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.cache_rate) {
            bail!("cache_rate must be in [0,1]");
        }
        if !(0.0..=1.0).contains(&self.tae_tau) {
            bail!("tae_tau must be in [0,1]");
        }
        if !(0.0..=1.0).contains(&self.dist_beta) {
            bail!("dist_beta must be in [0,1]");
        }
        if !(0.0..=1.0).contains(&self.cft_alpha) || self.cft_alpha == 0.0 {
            bail!("cft_alpha must be in (0,1]");
        }
        if self.k_max == 0 || self.search_h == 0 {
            bail!("k_max and search_h must be >= 1");
        }
        if self.pcie_bandwidth <= 0.0 {
            bail!("pcie_bandwidth must be positive");
        }
        if self.n_devices == 0 {
            bail!("n_devices must be >= 1");
        }
        if self.peer_bandwidth <= 0.0 {
            bail!("peer_bandwidth must be positive");
        }
        if !(self.peer_base_latency.is_finite() && self.peer_base_latency >= 0.0) {
            bail!("peer_base_latency must be finite and non-negative");
        }
        if self.replication_factor == 0 {
            bail!("replication_factor must be >= 1");
        }
        if !(self.kappa.is_finite() && self.kappa >= 0.0) {
            bail!("kappa must be finite and non-negative");
        }
        if !(self.sim_attn_s.is_finite() && self.sim_attn_s >= 0.0)
            || !(self.sim_expert_s.is_finite() && self.sim_expert_s >= 0.0)
        {
            bail!("sim_attn_s / sim_expert_s must be finite and non-negative");
        }
        if !(self.transfer_deadline_s.is_finite() && self.transfer_deadline_s >= 0.0) {
            bail!("transfer_deadline_s must be finite and non-negative (0 disables)");
        }
        if !(self.transfer_backoff_base_s.is_finite() && self.transfer_backoff_base_s >= 0.0) {
            bail!("transfer_backoff_base_s must be finite and non-negative");
        }
        if self.trace.is_on() && self.trace_ring == 0 {
            bail!("trace_ring must be >= 1 when tracing is enabled");
        }
        if let Err(e) = self.admission.validate() {
            bail!("admission invalid: {e}");
        }
        if !self.fault_plan.is_empty() {
            let links = Topology::new(self.n_devices, self.topology).n_peer_links();
            if let Err(e) = self.fault_plan.validate(self.n_devices, links) {
                bail!("fault_plan invalid: {e}");
            }
        }
        Ok(())
    }

    /// Named presets matching the paper's table rows.
    ///
    /// Mapping note (EXPERIMENTS.md §Params): in the paper's tables the
    /// "τ" column acts as an *aggressiveness* knob — τ=0.95/|B|=16 rows
    /// substitute far more (and lose more accuracy) than τ=0.75/|B|=4.
    /// Under the Eq. 1 gate semantics (forbid when TAE ≤ τ) a larger τ is
    /// *more* conservative, so we map each row to gate settings that
    /// reproduce its observed behaviour: wide lists pair with a permissive
    /// TAE threshold, tight lists with a strict one.
    pub fn preset(mut self, name: &str) -> Result<Self> {
        match name {
            "original" => {
                self.miss_policy = MissPolicy::OnDemand;
            }
            "random" => {
                self.miss_policy = MissPolicy::Random;
            }
            "buddy-tight" => {
                // Paper row (τ=0.75, |B|=4): conservative substitution.
                self.miss_policy = MissPolicy::Buddy;
                self.tae_tau = 0.80;
                self.cft_alpha = 0.5;
                self.k_max = 4;
                self.search_h = 4;
                self.rho = None;
            }
            "buddy-wide" => {
                // Paper row (τ=0.95, |B|=16, no ρ): aggressive — wide
                // lists, permissive gate, unlimited replacements.
                self.miss_policy = MissPolicy::Buddy;
                self.tae_tau = 0.45;
                self.cft_alpha = 0.9;
                self.k_max = 16;
                self.search_h = 16;
                self.rho = None;
            }
            "buddy-rho3" => {
                // Paper row (τ=0.95, |B|=16, ρ=3): aggressive but budgeted
                // — the paper's best configuration.
                self = self.preset("buddy-wide")?;
                self.rho = Some(3);
            }
            "buddy-rho4" => {
                self = self.preset("buddy-wide")?;
                self.rho = Some(4);
            }
            "buddy-strict" => {
                // Paper row (τ=0.99, |B|=2): tiny lists, strict gate.
                self.miss_policy = MissPolicy::Buddy;
                self.tae_tau = 0.90;
                self.cft_alpha = 0.3;
                self.k_max = 2;
                self.search_h = 2;
                self.rho = None;
            }
            other => bail!("unknown preset '{other}'"),
        }
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ServingConfig::default().validate().unwrap();
    }

    #[test]
    fn transfer_time_matches_paper_scale() {
        let c = ServingConfig::default();
        // dsv2-mini expert = 3*64*128*4 bytes = 98304.
        let t = c.transfer_seconds(98304);
        assert!(
            (0.008..0.011).contains(&t),
            "expert transfer {t}s should match the paper's 9-10 ms"
        );
    }

    #[test]
    fn gpu_expert_counts() {
        let mut c = ServingConfig::default();
        c.cache_rate = 0.75;
        assert_eq!(c.gpu_experts_per_layer(64), 48);
        c.cache_rate = 0.375;
        assert_eq!(c.gpu_experts_per_layer(64), 24);
    }

    #[test]
    fn presets_match_table_rows() {
        let c = ServingConfig::default().preset("buddy-rho3").unwrap();
        assert_eq!(c.rho, Some(3));
        assert_eq!(c.k_max, 16);
        assert!((c.tae_tau - 0.45).abs() < 1e-9);
        let c = ServingConfig::default().preset("original").unwrap();
        assert_eq!(c.miss_policy, MissPolicy::OnDemand);
        let c = ServingConfig::default().preset("buddy-strict").unwrap();
        assert_eq!(c.k_max, 2);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ServingConfig::default();
        c.cache_rate = 1.5;
        assert!(c.validate().is_err());
        let mut c = ServingConfig::default();
        c.cft_alpha = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_knobs_validated() {
        let c = ServingConfig::default();
        assert!(c.fault_plan.is_empty(), "fault-free is the default");
        assert_eq!(c.transfer_deadline_s, 0.0, "no deadline by default");
        let mut c = ServingConfig::default();
        c.transfer_deadline_s = -1.0;
        assert!(c.validate().is_err());
        // A plan that names a device outside the fleet is rejected.
        let mut c = ServingConfig::default();
        c.n_devices = 2;
        c.fault_plan = crate::fault::FaultPlan::scenario("device-down").unwrap();
        c.validate().unwrap();
        c.n_devices = 1;
        assert!(c.validate().is_err(), "device 1 does not exist on a 1-device fleet");
    }

    #[test]
    fn topology_knobs_validated() {
        let mut c = ServingConfig::default();
        assert_eq!(c.n_devices, 1, "single device is the default");
        c.n_devices = 4;
        c.validate().unwrap();
        c.n_devices = 0;
        assert!(c.validate().is_err());
        let mut c = ServingConfig::default();
        assert_eq!(c.replication_factor, 1, "single-homed is the default");
        c.replication_factor = 0;
        assert!(c.validate().is_err());
        let mut c = ServingConfig::default();
        c.peer_bandwidth = 0.0;
        assert!(c.validate().is_err());
        let mut c = ServingConfig::default();
        c.kappa = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn trace_knob_validated() {
        let c = ServingConfig::default();
        assert!(!c.trace.is_on(), "tracing is off by default");
        let mut c = ServingConfig::default();
        c.trace = TraceSink::Ring;
        c.validate().unwrap();
        c.trace_ring = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn admission_knobs_validated() {
        let c = ServingConfig::default();
        assert!(!c.admission.enabled, "admission control is off by default");
        c.validate().unwrap();

        // A disabled config validates even with nonsense knobs (they are
        // inert), matching the FaultPlan empty-plan contract.
        let mut c = ServingConfig::default();
        c.admission.interactive_ttft_slo_s = -1.0;
        c.validate().unwrap();

        let mut c = ServingConfig::default();
        c.admission = AdmissionControl::overload_protect(0.25, 2.5, 64);
        c.validate().unwrap();

        let mut c = ServingConfig::default();
        c.admission = AdmissionControl::overload_protect(0.25, 2.5, 64);
        c.admission.interactive_ttft_slo_s = 0.0;
        assert!(c.validate().is_err());

        let mut c = ServingConfig::default();
        c.admission = AdmissionControl::overload_protect(0.25, 2.5, 64);
        c.admission.ewma_alpha = 0.0;
        assert!(c.validate().is_err());

        // Hysteresis: exit must sit strictly below enter.
        let mut c = ServingConfig::default();
        c.admission = AdmissionControl::overload_protect(0.25, 2.5, 64);
        c.admission.brownout_exit_ratio = c.admission.brownout_enter_ratio;
        assert!(c.validate().is_err());

        let mut c = ServingConfig::default();
        c.admission = AdmissionControl::overload_protect(0.25, 2.5, 64);
        c.admission.brownout_tae_tau = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in ["on-demand", "random", "drop", "buddy"] {
            assert_eq!(MissPolicy::parse(p).unwrap().name(), p);
        }
        assert!(MissPolicy::parse("bogus").is_err());
    }
}
