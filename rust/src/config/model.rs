//! Model architecture config, deserialized from artifacts/model_config.json.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One AOT artifact (stage x bucket) from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub num_args: usize,
    pub arg_shapes: Vec<Vec<usize>>,
    pub tuple_output: bool,
}

/// Mirror of `python/compile/configs.py::ModelSpec` plus artifact manifest.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rms_eps: f64,
    pub token_buckets: Vec<usize>,
    pub batch_buckets: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub weights_file: String,
    pub hlo_dir: String,
    pub golden_file: String,
    pub family_size: usize,
    /// Directory the config was loaded from; artifact paths resolve under it.
    pub root: PathBuf,
}

impl ModelConfig {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing model_config.json")?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, root: &Path) -> Result<Self> {
        let spec = j.get("spec")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            let shapes = a
                .get("arg_shapes")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize_vec())
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    file: a.get("file")?.as_str()?.to_string(),
                    num_args: a.get("num_args")?.as_usize()?,
                    arg_shapes: shapes,
                    tuple_output: a.get("tuple_output")?.as_bool()?,
                },
            );
        }
        Ok(Self {
            name: spec.get("name")?.as_str()?.to_string(),
            vocab_size: spec.get("vocab_size")?.as_usize()?,
            d_model: spec.get("d_model")?.as_usize()?,
            n_heads: spec.get("n_heads")?.as_usize()?,
            head_dim: spec.get("head_dim")?.as_usize()?,
            n_layers: spec.get("n_layers")?.as_usize()?,
            n_experts: spec.get("n_experts")?.as_usize()?,
            top_k: spec.get("top_k")?.as_usize()?,
            d_ff: spec.get("d_ff")?.as_usize()?,
            max_seq: spec.get("max_seq")?.as_usize()?,
            rms_eps: spec.get("rms_eps")?.as_f64()?,
            token_buckets: spec.get("token_buckets")?.as_usize_vec()?,
            batch_buckets: spec.get("batch_buckets")?.as_usize_vec()?,
            artifacts,
            weights_file: j.get("weights_file")?.as_str()?.to_string(),
            hlo_dir: j.get("hlo_dir")?.as_str()?.to_string(),
            golden_file: j.get("golden_file")?.as_str()?.to_string(),
            family_size: j.get("weightgen")?.get("family_size")?.as_usize()?,
            root: root.to_path_buf(),
        })
    }

    /// f32 parameters in one expert (w1 + w3 + w2).
    pub fn expert_param_count(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    pub fn expert_bytes(&self) -> usize {
        4 * self.expert_param_count()
    }

    /// Total experts across all layers.
    pub fn total_experts(&self) -> usize {
        self.n_layers * self.n_experts
    }

    /// Smallest token bucket >= n (serving pads token groups up to this).
    pub fn token_bucket_for(&self, n: usize) -> Option<usize> {
        self.token_buckets.iter().copied().find(|&b| b >= n)
    }

    pub fn batch_bucket_for(&self, n: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().find(|&b| b >= n)
    }

    pub fn hlo_path(&self, artifact: &str) -> Result<PathBuf> {
        let info = self
            .artifacts
            .get(artifact)
            .with_context(|| format!("unknown artifact {artifact}"))?;
        Ok(self.root.join(&self.hlo_dir).join(&info.file))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.root.join(&self.weights_file)
    }

    pub fn golden_path(&self) -> PathBuf {
        self.root.join(&self.golden_file)
    }

    /// A hand-built config sized for full-pipeline integration tests on
    /// the reference backend (no artifacts): big enough for continuous
    /// batching at the default `max_batch` and the standard workload
    /// generator's prompt lengths, small enough that a whole table sweep
    /// under the virtual clock takes well under a second.
    pub fn synthetic_small() -> Self {
        Self {
            name: "synthetic-small".into(),
            vocab_size: 64,
            d_model: 16,
            n_heads: 2,
            head_dim: 8,
            n_layers: 3,
            n_experts: 8,
            top_k: 2,
            d_ff: 32,
            max_seq: 48,
            rms_eps: 1e-5,
            token_buckets: vec![1, 2, 4, 8, 16, 32, 48],
            batch_buckets: vec![1, 2, 4, 8, 16],
            artifacts: BTreeMap::new(),
            weights_file: "weights.bmw".into(),
            hlo_dir: "hlo".into(),
            golden_file: "golden/decode.json".into(),
            family_size: 4,
            root: PathBuf::from("/nonexistent"),
        }
    }

    /// A tiny hand-built config for unit tests that never touch artifacts.
    pub fn test_tiny() -> Self {
        Self {
            name: "test-tiny".into(),
            vocab_size: 64,
            d_model: 16,
            n_heads: 2,
            head_dim: 8,
            n_layers: 3,
            n_experts: 8,
            top_k: 2,
            d_ff: 32,
            max_seq: 16,
            rms_eps: 1e-5,
            token_buckets: vec![1, 2, 4, 8, 16],
            batch_buckets: vec![1, 2, 4],
            artifacts: BTreeMap::new(),
            weights_file: "weights.bmw".into(),
            hlo_dir: "hlo".into(),
            golden_file: "golden/decode.json".into(),
            family_size: 4,
            root: PathBuf::from("/nonexistent"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "spec": {"name": "x", "vocab_size": 8, "d_model": 4, "n_heads": 2,
               "head_dim": 2, "n_layers": 1, "n_experts": 4, "top_k": 2,
               "d_ff": 8, "max_seq": 4, "rms_eps": 1e-5,
               "token_buckets": [1, 2, 4], "batch_buckets": [1, 2]},
      "weights_file": "weights.bmw",
      "hlo_dir": "hlo",
      "golden_file": "golden/decode.json",
      "weightgen": {"seed": 7, "family_size": 2, "n_families": 2},
      "artifacts": {
        "expert_T1": {"file": "expert_T1.hlo.txt", "num_args": 4,
                       "arg_shapes": [[1,4],[4,8],[4,8],[8,4]],
                       "tuple_output": false}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let c = ModelConfig::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(c.n_experts, 4);
        assert_eq!(c.expert_param_count(), 3 * 4 * 8);
        assert_eq!(c.artifacts["expert_T1"].num_args, 4);
        assert!(!c.artifacts["expert_T1"].tuple_output);
        assert_eq!(
            c.hlo_path("expert_T1").unwrap(),
            PathBuf::from("/tmp/a/hlo/expert_T1.hlo.txt")
        );
    }

    #[test]
    fn bucket_selection() {
        let c = ModelConfig::test_tiny();
        assert_eq!(c.token_bucket_for(1), Some(1));
        assert_eq!(c.token_bucket_for(3), Some(4));
        assert_eq!(c.token_bucket_for(16), Some(16));
        assert_eq!(c.token_bucket_for(17), None);
    }

    #[test]
    fn expert_bytes() {
        let c = ModelConfig::test_tiny();
        assert_eq!(c.expert_bytes(), 4 * 3 * 16 * 32);
        assert_eq!(c.total_experts(), 24);
    }
}
