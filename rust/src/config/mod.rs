//! Configuration: the model spec (read from `artifacts/model_config.json`,
//! whose source of truth is `python/compile/configs.py`) and the serving
//! config (cache rate, PCIe model, gate parameters, miss policy).

mod model;
mod serving;

pub use model::{ArtifactInfo, ModelConfig};
pub use serving::{AdmissionControl, MissPolicy, PrefetchKind, ServingConfig};
