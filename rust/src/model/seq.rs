//! A serving sequence: prompt, generation state, and per-layer KV cache —
//! plus [`KvBatchView`], the borrowed per-layer view of a decode batch's
//! caches that the engine lends to `StageRunner::attn_decode` (PR 5:
//! zero-copy KV).

use crate::config::ModelConfig;
use crate::runtime::KvSource;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generated token ids.
    pub generated: Vec<i32>,
    /// Token to feed at the next decode step.
    pub next_token: i32,
    /// Number of KV positions filled (prompt + generated so far).
    pub pos: usize,
    /// Per-layer K / V caches, each [max_seq, d_model].
    pub kv_k: Vec<Tensor>,
    pub kv_v: Vec<Tensor>,
    /// Generation budget.
    pub max_new: usize,
    /// Per-step logits kept when telemetry is enabled (accuracy eval).
    pub logits_log: Vec<Vec<f32>>,
    /// Logits at the last prompt position (prefill), when recorded.
    pub prefill_logits: Option<Vec<f32>>,
    /// The model's argmax at every position (prefill + each decode step),
    /// regardless of what token is actually fed next.
    pub predictions: Vec<i32>,
    /// Teacher forcing: when set, position i feeds `force_tokens[i]`
    /// instead of the model's own argmax. Used by the accuracy harness so
    /// every position is scored under the oracle's context (greedy
    /// free-running comparison is chaotic: one near-tie fp flip poisons
    /// the whole continuation).
    pub force_tokens: Option<Vec<i32>>,
}

impl Sequence {
    pub fn new(cfg: &ModelConfig, id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(
            prompt.len() + max_new <= cfg.max_seq,
            "prompt {} + max_new {} exceeds max_seq {}",
            prompt.len(),
            max_new,
            cfg.max_seq
        );
        let mk = || {
            (0..cfg.n_layers)
                .map(|_| Tensor::zeros(vec![cfg.max_seq, cfg.d_model]))
                .collect::<Vec<_>>()
        };
        Self {
            id,
            prompt,
            generated: Vec::new(),
            next_token: 0,
            pos: 0,
            kv_k: mk(),
            kv_v: mk(),
            max_new,
            logits_log: Vec::new(),
            prefill_logits: None,
            predictions: Vec::new(),
            force_tokens: None,
        }
    }

    /// The token to feed after `n_generated` tokens have been produced,
    /// honouring teacher forcing.
    pub fn fed_token(&self, model_argmax: i32, position: usize) -> i32 {
        match &self.force_tokens {
            Some(f) => f.get(position).copied().unwrap_or(model_argmax),
            None => model_argmax,
        }
    }

    pub fn prefilled(&self) -> bool {
        self.pos >= self.prompt.len()
    }

    pub fn done(&self) -> bool {
        self.prefilled() && self.generated.len() >= self.max_new
    }

    /// Write this step's new K/V row for `layer` at the current position.
    pub fn write_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let pos = self.pos;
        self.kv_k[layer].row_mut(pos).copy_from_slice(k_row);
        self.kv_v[layer].row_mut(pos).copy_from_slice(v_row);
    }

    /// Advance after a completed decode step.
    pub fn advance(&mut self, generated_token: i32) {
        self.generated.push(self.next_token);
        self.next_token = generated_token;
        self.pos += 1;
    }
}

/// Borrowed view of one layer's KV caches across a decode batch: holds a
/// shared ref to the batch's sequences and hands out each sequence's
/// `[max_seq, d_model]` K / V cache tensor **in place** — constructing
/// one allocates nothing and copies nothing.
///
/// Who may borrow: the engine builds a fresh view per layer, and the
/// borrow ends before `write_kv` appends the step's new row (attention
/// reads that row separately as `k_new`/`v_new`), so the caches are
/// immutable for the lifetime of the view.
pub struct KvBatchView<'a> {
    seqs: &'a [&'a mut Sequence],
    layer: usize,
}

impl<'a> KvBatchView<'a> {
    pub fn new(seqs: &'a [&'a mut Sequence], layer: usize) -> Self {
        Self { seqs, layer }
    }
}

impl KvSource for KvBatchView<'_> {
    fn batch(&self) -> usize {
        self.seqs.len()
    }

    fn k(&self, i: usize) -> &Tensor {
        &self.seqs[i].kv_k[self.layer]
    }

    fn v(&self, i: usize) -> &Tensor {
        &self.seqs[i].kv_v[self.layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let cfg = ModelConfig::test_tiny();
        let mut s = Sequence::new(&cfg, 1, vec![1, 2, 3], 4);
        assert!(!s.prefilled());
        assert!(!s.done());
        s.pos = 3; // prefill done
        s.next_token = 9;
        assert!(s.prefilled());
        s.advance(11);
        assert_eq!(s.generated, vec![9]);
        assert_eq!(s.next_token, 11);
        assert_eq!(s.pos, 4);
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq")]
    fn too_long_rejected() {
        let cfg = ModelConfig::test_tiny();
        Sequence::new(&cfg, 1, vec![0; 10], 10);
    }

    #[test]
    fn kv_batch_view_borrows_in_place() {
        let cfg = ModelConfig::test_tiny();
        let mut a = Sequence::new(&cfg, 1, vec![1], 2);
        let mut b = Sequence::new(&cfg, 2, vec![2], 2);
        a.kv_k[1].row_mut(0)[0] = 5.0;
        let a_ptr = a.kv_k[1].data.as_ptr();
        let batch = [&mut a, &mut b];
        let view = KvBatchView::new(&batch, 1);
        assert_eq!(view.batch(), 2);
        // The view aliases the sequence's own allocation — no copy.
        assert_eq!(view.k(0).data.as_ptr(), a_ptr);
        assert_eq!(view.k(0).row(0)[0], 5.0);
        assert_eq!(view.v(1).dims, vec![cfg.max_seq, cfg.d_model]);
    }

    #[test]
    fn kv_write() {
        let cfg = ModelConfig::test_tiny();
        let mut s = Sequence::new(&cfg, 1, vec![1], 2);
        s.pos = 1;
        let row = vec![0.5; cfg.d_model];
        s.write_kv(0, &row, &row);
        assert_eq!(s.kv_k[0].row(1), &row[..]);
        assert_eq!(s.kv_v[0].row(1), &row[..]);
        assert_eq!(s.kv_k[0].row(0), vec![0.0; cfg.d_model].as_slice());
    }
}
