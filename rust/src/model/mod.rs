//! The serving-side model: sequences with KV caches, routing helpers, and
//! the layer-orchestrating inference engine that glues the AOT artifacts to
//! the offloading + buddy-substitution machinery.

mod engine;
mod route;
mod seq;

pub use engine::{Engine, EngineOptions, StepTelemetry};
pub use route::routings_from_probs;
pub use seq::{KvBatchView, Sequence};
