//! Routing helpers: turn router-stage probabilities into per-token top-k
//! selections (the exact deterministic rule the golden fixtures use).

use crate::buddy::TokenRouting;
use crate::util::math::top_k;
use crate::util::tensor::Tensor;

/// probs: [T, E] -> per-token TokenRouting (top-k, renormalized weights).
/// Only the first `n_real` rows are routed (bucket padding is skipped).
pub fn routings_from_probs(probs: &Tensor, n_real: usize, k: usize) -> Vec<TokenRouting> {
    assert_eq!(probs.rank(), 2);
    (0..n_real)
        .map(|t| {
            let (selected, weights) = top_k(probs.row(t), k);
            TokenRouting { selected, weights }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_and_skips_padding() {
        let probs = Tensor::new(
            vec![3, 4],
            vec![
                0.1, 0.4, 0.3, 0.2, // token 0 -> top2 = [1, 2]
                0.7, 0.1, 0.1, 0.1, // token 1 -> top2 = [0, 1] (tie low idx)
                0.25, 0.25, 0.25, 0.25, // padding row, ignored
            ],
        )
        .unwrap();
        let r = routings_from_probs(&probs, 2, 2);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].selected, vec![1, 2]);
        assert!((r[0].weights[0] - 0.4 / 0.7).abs() < 1e-6);
        assert_eq!(r[1].selected, vec![0, 1]);
    }
}
