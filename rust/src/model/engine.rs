//! The inference engine: orchestrates the AOT stages per layer, routes
//! tokens, applies the miss policy (buddy substitution / on-demand /
//! random / drop), schedules expert execution against the cache, and
//! drives the prefetcher — the complete Figure 3 + Algorithm 1 pipeline.
//!
//! All PJRT interaction happens on the thread that owns the `Engine`; the
//! transfer engine thread only touches host-side state.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::buddy::{BuddyProfile, GateParams, PsiParams, SlotDecision, SubstitutionEngine, TokenRouting};
use crate::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use crate::memory::{EvictPolicy, ExpertCache, LoadDecision, PcieSim, TransferEngine, TransferHandle, TransferPriority};
use crate::model::route::routings_from_probs;
use crate::model::seq::Sequence;
use crate::prefetch::{OracleNoisy, PreGate, PredictContext, Predictor, PrefetchEngine, TopFreq};
use crate::profilecollect::ProfileCollector;
use crate::runtime::{lit_i32, lit_tensor, ArtifactRegistry, Runtime};
use crate::stats::Counters;
use crate::util::math::argmax;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;
use crate::weights::{ExpertKey, WeightStore};

/// Engine construction options orthogonal to the serving config.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Scales simulated PCIe sleeps (1.0 = real; 0.0 = instant, tests).
    pub time_scale: f64,
    /// Record pre-substitution routing into a ProfileCollector.
    pub collect_profile: bool,
    /// Keep per-step logits on each sequence (accuracy evaluation).
    pub record_logits: bool,
    pub evict_policy: EvictPolicy,
    /// Keep non-expert weights (embedding, attention, router) as device
    /// buffers and run stages via the buffer path, instead of shipping
    /// weight literals host->device on every call. §Perf optimization; the
    /// literal path is retained for before/after measurement.
    pub weight_buffers: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            time_scale: 1.0,
            collect_profile: false,
            record_logits: false,
            evict_policy: EvictPolicy::Lru,
            weight_buffers: true,
        }
    }
}

/// Per-step telemetry (aggregated into server metrics).
#[derive(Debug, Clone, Default)]
pub struct StepTelemetry {
    /// Wall seconds spent stalled on demand transfers this step.
    pub stall_seconds: f64,
    pub substitutions: u64,
    pub fetches: u64,
    /// Fetches served outside the cache (all slots pinned).
    pub transient_fetches: u64,
}

struct LayerLits {
    ln1: xla::Literal,
    wq: xla::Literal,
    wk: xla::Literal,
    wv: xla::Literal,
    wo: xla::Literal,
    ln2: xla::Literal,
    wg: xla::Literal,
    rbias: xla::Literal,
}

/// Device-resident copies of per-layer non-expert weights (§Perf: created
/// once, reused every call — saves one host->device weight copy per stage
/// invocation on the hot path).
struct LayerBufs {
    ln1: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    ln2: xla::PjRtBuffer,
    wg: xla::PjRtBuffer,
    rbias: xla::PjRtBuffer,
}

pub struct Engine {
    pub cfg: ModelConfig,
    pub scfg: ServingConfig,
    pub opts: EngineOptions,
    rt: Runtime,
    reg: ArtifactRegistry,
    store: Arc<WeightStore>,
    transfer: TransferHandle,
    buddy_profile: Option<BuddyProfile>,
    predictor: Option<Box<dyn Predictor>>,
    prefetcher: PrefetchEngine,
    pub counters: Counters,
    pub profile_out: Option<ProfileCollector>,
    rng: Rng,
    lit_embed: xla::Literal,
    lit_final_gain: xla::Literal,
    layer_lits: Vec<LayerLits>,
    buf_embed: Option<xla::PjRtBuffer>,
    buf_final_gain: Option<xla::PjRtBuffer>,
    layer_bufs: Vec<LayerBufs>,
    next_seq_id: u64,
}

impl Engine {
    /// Build the engine: compile artifacts, warm the cache with the most
    /// popular experts per layer, start the transfer engine.
    ///
    /// `warm_rank` ranks experts per layer for cache warm-up + the TopFreq
    /// predictor (pass profiled activation ranks; falls back to router-bias
    /// popularity).
    pub fn new(
        cfg: ModelConfig,
        scfg: ServingConfig,
        store: Arc<WeightStore>,
        buddy_profile: Option<BuddyProfile>,
        warm_rank: Option<Vec<Vec<usize>>>,
        opts: EngineOptions,
    ) -> Result<Self> {
        scfg.validate()?;
        let rt = Runtime::cpu()?;
        let mut reg = rt.load_artifacts(&cfg)?;

        let capacity = scfg.gpu_experts_per_layer(cfg.n_experts).max(1);
        let mut cache = ExpertCache::new(cfg.n_layers, cfg.n_experts, capacity, opts.evict_policy);

        let warm_rank = warm_rank.unwrap_or_else(|| Self::bias_rank(&cfg, &store));
        for (l, ranked) in warm_rank.iter().enumerate() {
            for &e in ranked.iter().take(capacity) {
                let key = ExpertKey::new(l, e);
                cache.admit(key).context("cache warm-up")?;
                let w = store.expert(key)?;
                reg.admit_expert(&rt, key, &w)?;
            }
        }
        log::info!(
            "cache warmed: {}/{} experts per layer ({}%)",
            capacity,
            cfg.n_experts,
            (scfg.cache_rate * 100.0) as u32
        );

        let pcie = PcieSim::new(scfg.pcie_bandwidth, scfg.pcie_base_latency, scfg.transfer_bytes_scale);
        let transfer = TransferEngine::spawn(cache, pcie, store.clone(), opts.time_scale);

        let predictor: Option<Box<dyn Predictor>> = match scfg.prefetch {
            PrefetchKind::None => None,
            PrefetchKind::TopFreq => Some(Box::new(TopFreq::from_ranked(warm_rank.clone()))),
            PrefetchKind::PreGate => Some(Box::new(PreGate::new(
                store.clone(),
                cfg.d_model,
                cfg.top_k,
                cfg.rms_eps as f32,
            ))),
            PrefetchKind::OracleNoisy => {
                Some(Box::new(OracleNoisy::new(scfg.oracle_miss_rate, scfg.seed ^ 0xa5)))
            }
        };
        let prefetcher = PrefetchEngine::new(transfer.clone(), cfg.n_layers, scfg.prefetch_width);

        // Cache non-expert weights as literals once.
        let lit_embed = lit_tensor(store.tensor("embed")?)?;
        let lit_final_gain = lit_tensor(store.tensor("final_gain")?)?;
        let mut layer_lits = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g = |n: &str| -> Result<xla::Literal> {
                lit_tensor(store.tensor(&format!("L{l}.{n}"))?)
            };
            layer_lits.push(LayerLits {
                ln1: g("ln1")?,
                wq: g("wq")?,
                wk: g("wk")?,
                wv: g("wv")?,
                wo: g("wo")?,
                ln2: g("ln2")?,
                wg: g("wg")?,
                rbias: g("rbias")?,
            });
        }

        // §Perf: device-resident non-expert weights for the buffer path.
        let (buf_embed, buf_final_gain, layer_bufs) = if opts.weight_buffers {
            let te = store.tensor("embed")?;
            let tg = store.tensor("final_gain")?;
            let mut bufs = Vec::with_capacity(cfg.n_layers);
            for l in 0..cfg.n_layers {
                let g = |n: &str| -> Result<xla::PjRtBuffer> {
                    let t = store.tensor(&format!("L{l}.{n}"))?;
                    rt.to_device(&t.data, &t.dims)
                };
                bufs.push(LayerBufs {
                    ln1: g("ln1")?,
                    wq: g("wq")?,
                    wk: g("wk")?,
                    wv: g("wv")?,
                    wo: g("wo")?,
                    ln2: g("ln2")?,
                    wg: g("wg")?,
                    rbias: g("rbias")?,
                });
            }
            (
                Some(rt.to_device(&te.data, &te.dims)?),
                Some(rt.to_device(&tg.data, &tg.dims)?),
                bufs,
            )
        } else {
            (None, None, Vec::new())
        };

        let profile_out = opts
            .collect_profile
            .then(|| ProfileCollector::new(cfg.n_layers, cfg.n_experts));

        Ok(Self {
            rng: Rng::new(scfg.seed),
            cfg,
            scfg,
            opts,
            rt,
            reg,
            store,
            transfer,
            buddy_profile,
            predictor,
            prefetcher,
            counters: Counters::new(),
            profile_out,
            lit_embed,
            lit_final_gain,
            layer_lits,
            buf_embed,
            buf_final_gain,
            layer_bufs,
            next_seq_id: 0,
        })
    }

    /// Rank experts per layer by router bias (popularity prior).
    pub fn bias_rank(cfg: &ModelConfig, store: &WeightStore) -> Vec<Vec<usize>> {
        (0..cfg.n_layers)
            .map(|l| {
                let bias = &store.tensor(&format!("L{l}.rbias")).unwrap().data;
                let mut idx: Vec<usize> = (0..cfg.n_experts).collect();
                idx.sort_by(|&a, &b| bias[b].partial_cmp(&bias[a]).unwrap().then(a.cmp(&b)));
                idx
            })
            .collect()
    }

    pub fn new_sequence(&mut self, prompt: Vec<i32>, max_new: usize) -> Sequence {
        self.next_seq_id += 1;
        Sequence::new(&self.cfg, self.next_seq_id, prompt, max_new)
    }

    pub fn transfer_handle(&self) -> &TransferHandle {
        &self.transfer
    }

    pub fn prefetch_counters(&self) -> &Counters {
        &self.prefetcher.counters
    }

    pub fn shutdown(&self) {
        self.transfer.shutdown();
    }

    // ------------------------------------------------------------------
    // Stage wrappers: buffer path (weights device-resident) vs literal path
    // ------------------------------------------------------------------

    fn run_embed(&self, tb: usize, toks: &[i32]) -> Result<Tensor> {
        let name = format!("embed_T{tb}");
        if let Some(be) = &self.buf_embed {
            let bt = self.rt.to_device_i32(toks, &[toks.len()])?;
            self.reg.run_buffers(&name, &[&bt, be])?.single()
        } else {
            let lt = lit_i32(toks);
            self.reg.run_lits(&name, &[&lt, &self.lit_embed])?.single()
        }
    }

    fn run_attn_prefill(&self, l: usize, x: &Tensor, mask: &Tensor) -> Result<Vec<Tensor>> {
        if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[l];
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            let bm = self.rt.to_device(&mask.data, &mask.dims)?;
            Ok(self
                .reg
                .run_buffers(
                    "attn_prefill",
                    &[&bx, &bm, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &lb.wo],
                )?
                .outputs)
        } else {
            let ll = &self.layer_lits[l];
            let lx = lit_tensor(x)?;
            let lm = lit_tensor(mask)?;
            Ok(self
                .reg
                .run_lits(
                    "attn_prefill",
                    &[&lx, &lm, &ll.ln1, &ll.wq, &ll.wk, &ll.wv, &ll.wo],
                )?
                .outputs)
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_attn_decode(
        &self,
        l: usize,
        bb: usize,
        x: &Tensor,
        kc: &Tensor,
        vc: &Tensor,
        pos_mask: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let name = format!("attn_decode_B{bb}");
        if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[l];
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            let bk = self.rt.to_device(&kc.data, &kc.dims)?;
            let bv = self.rt.to_device(&vc.data, &vc.dims)?;
            let bm = self.rt.to_device(&pos_mask.data, &pos_mask.dims)?;
            Ok(self
                .reg
                .run_buffers(
                    &name,
                    &[&bx, &bk, &bv, &bm, &lb.ln1, &lb.wq, &lb.wk, &lb.wv, &lb.wo],
                )?
                .outputs)
        } else {
            let ll = &self.layer_lits[l];
            let lx = lit_tensor(x)?;
            let lk = lit_tensor(kc)?;
            let lv = lit_tensor(vc)?;
            let lm = lit_tensor(pos_mask)?;
            Ok(self
                .reg
                .run_lits(
                    &name,
                    &[&lx, &lk, &lv, &lm, &ll.ln1, &ll.wq, &ll.wk, &ll.wv, &ll.wo],
                )?
                .outputs)
        }
    }

    fn run_lm_head(&self, tb: usize, x: &Tensor) -> Result<Tensor> {
        let name = format!("lm_head_T{tb}");
        if let (Some(bg), Some(be)) = (&self.buf_final_gain, &self.buf_embed) {
            let bx = self.rt.to_device(&x.data, &x.dims)?;
            self.reg.run_buffers(&name, &[&bx, bg, be])?.single()
        } else {
            let lx = lit_tensor(x)?;
            self.reg
                .run_lits(&name, &[&lx, &self.lit_final_gain, &self.lit_embed])?
                .single()
        }
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run the prompt through the model, filling the KV cache and setting
    /// the first generated token.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<StepTelemetry> {
        let s = self.cfg.max_seq;
        let s0 = seq.prompt.len();
        let mut tel = StepTelemetry::default();

        // Embed the padded prompt.
        let mut toks = vec![0i32; s];
        toks[..s0].copy_from_slice(&seq.prompt);
        let mut x = self.run_embed(s, &toks)?;

        let mut len_mask = vec![0.0f32; s];
        len_mask[..s0].fill(1.0);
        let mask_t = Tensor::new(vec![s], len_mask)?;

        for l in 0..self.cfg.n_layers {
            let out = self.run_attn_prefill(l, &x, &mask_t)?;
            let [y, k, v]: [Tensor; 3] = out
                .try_into()
                .map_err(|_| anyhow::anyhow!("attn_prefill output arity"))?;
            for p in 0..s0 {
                seq.kv_k[l].row_mut(p).copy_from_slice(k.row(p));
                seq.kv_v[l].row_mut(p).copy_from_slice(v.row(p));
            }
            let (h, mut routings) = self.run_router(l, &y, s0)?;
            let moe = self.run_moe(l, &h, &mut routings, &mut tel)?;
            // Residual: x = y + moe on the real rows (padding rows unused).
            x = y;
            for t in 0..s0 {
                let row = x.row_mut(t);
                for (a, b) in row.iter_mut().zip(moe.row(t)) {
                    *a += b;
                }
            }
            self.prefetch_next(l, &x);
        }
        // LM head on the last real position.
        let last = Tensor::new(vec![1, self.cfg.d_model], x.row(s0 - 1).to_vec())?;
        let logits = self.run_lm_head(1, &last)?;
        let pred = argmax(logits.row(0)) as i32;
        seq.predictions.push(pred);
        if self.opts.record_logits {
            seq.prefill_logits = Some(logits.row(0).to_vec());
        }
        seq.next_token = seq.fed_token(pred, 0);
        seq.pos = s0;
        self.counters.inc("prefills");
        Ok(tel)
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// One decode step for a batch of prefilled sequences.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<StepTelemetry> {
        let b = seqs.len();
        anyhow::ensure!(b > 0, "empty batch");
        let bb = self
            .cfg
            .batch_bucket_for(b)
            .context("batch larger than any bucket")?;
        let d = self.cfg.d_model;
        let s = self.cfg.max_seq;
        let mut tel = StepTelemetry::default();

        // Embed current tokens (token bucket >= b).
        let tb = self.cfg.token_bucket_for(b).context("no token bucket")?;
        let mut toks = vec![0i32; tb];
        for (i, sq) in seqs.iter().enumerate() {
            toks[i] = sq.next_token;
        }
        let emb = self.run_embed(tb, &toks)?;
        // x: [bb, d]
        let mut x = Tensor::zeros(vec![bb, d]);
        for i in 0..b {
            x.row_mut(i).copy_from_slice(emb.row(i));
        }

        // Batched KV + position masks.
        let mut pos_mask = Tensor::zeros(vec![bb, s]);
        for (i, sq) in seqs.iter().enumerate() {
            pos_mask.row_mut(i)[..sq.pos].fill(1.0);
        }

        for l in 0..self.cfg.n_layers {
            // Assemble [bb, s, d] caches.
            let mut kc = vec![0.0f32; bb * s * d];
            let mut vc = vec![0.0f32; bb * s * d];
            for (i, sq) in seqs.iter().enumerate() {
                kc[i * s * d..(i + 1) * s * d].copy_from_slice(&sq.kv_k[l].data);
                vc[i * s * d..(i + 1) * s * d].copy_from_slice(&sq.kv_v[l].data);
            }
            let kc = Tensor::new(vec![bb, s, d], kc)?;
            let vc = Tensor::new(vec![bb, s, d], vc)?;
            let out = self.run_attn_decode(l, bb, &x, &kc, &vc, &pos_mask)?;
            let [y, k_new, v_new]: [Tensor; 3] = out
                .try_into()
                .map_err(|_| anyhow::anyhow!("attn_decode output arity"))?;
            for (i, sq) in seqs.iter_mut().enumerate() {
                sq.write_kv(l, k_new.row(i), v_new.row(i));
            }

            let (h, mut routings) = self.run_router(l, &y, b)?;
            let moe = self.run_moe(l, &h, &mut routings, &mut tel)?;
            x = y;
            for t in 0..b {
                let row = x.row_mut(t);
                for (a, mo) in row.iter_mut().zip(moe.row(t)) {
                    *a += mo;
                }
            }
            self.prefetch_next(l, &x);
        }

        // LM head over the batch.
        let mut xb = Tensor::zeros(vec![tb, d]);
        for i in 0..b {
            xb.row_mut(i).copy_from_slice(x.row(i));
        }
        let logits = self.run_lm_head(tb, &xb)?;
        for (i, sq) in seqs.iter_mut().enumerate() {
            let row = logits.row(i);
            if self.opts.record_logits {
                sq.logits_log.push(row.to_vec());
            }
            let pred = argmax(row) as i32;
            sq.predictions.push(pred);
            // Position of the *next* fed token: generated.len()+1 (the
            // prefill prediction occupies position 0).
            let fed = sq.fed_token(pred, sq.generated.len() + 1);
            sq.advance(fed);
        }
        self.counters.inc("decode_steps");
        self.counters.add("decode_tokens", b as u64);
        Ok(tel)
    }

    // ------------------------------------------------------------------
    // Shared per-layer stages
    // ------------------------------------------------------------------

    /// Router stage on `y` ([T, d]); routes the first `n_real` rows.
    fn run_router(&mut self, l: usize, y: &Tensor, n_real: usize) -> Result<(Tensor, Vec<TokenRouting>)> {
        let t = y.dims[0];
        let name = format!("router_T{t}");
        let out = if !self.layer_bufs.is_empty() {
            let lb = &self.layer_bufs[l];
            let by = self.rt.to_device(&y.data, &y.dims)?;
            self.reg
                .run_buffers(&name, &[&by, &lb.ln2, &lb.wg, &lb.rbias])?
        } else {
            let ll = &self.layer_lits[l];
            let ly = lit_tensor(y)?;
            self.reg
                .run_lits(&name, &[&ly, &ll.ln2, &ll.wg, &ll.rbias])?
        };
        let mut it = out.outputs.into_iter();
        let h = it.next().context("router h")?;
        let probs = it.next().context("router probs")?;
        let routings = routings_from_probs(&probs, n_real, self.cfg.top_k);
        if let Some(pc) = self.profile_out.as_mut() {
            for r in &routings {
                pc.record(l, &r.selected, &r.weights)?;
            }
        }
        Ok((h, routings))
    }

    /// The MoE stage: miss policy + expert scheduling + weighted combine.
    /// `h` is the normed input [T, d]; returns the MoE output for the first
    /// `routings.len()` rows.
    fn run_moe(
        &mut self,
        l: usize,
        h: &Tensor,
        routings: &mut Vec<TokenRouting>,
        tel: &mut StepTelemetry,
    ) -> Result<Tensor> {
        let n_real = routings.len();
        let d = self.cfg.d_model;

        // Verification step of the prefetch pipeline (Fig 3).
        let mut actual_unique: Vec<usize> = Vec::new();
        for r in routings.iter() {
            for &e in &r.selected {
                if !actual_unique.contains(&e) {
                    actual_unique.push(e);
                }
            }
        }
        self.prefetcher.verify(l, &actual_unique);

        // Residency mask + policy application.
        let residency = self.transfer.with_state(|st| {
            for &e in &actual_unique {
                st.cache.mark_use(ExpertKey::new(l, e));
            }
            st.cache.residency_mask(l)
        });
        let sub_counters_before = self.counters.get("substitutions");
        let decisions = if let Some(profile) = self.buddy_profile.as_ref() {
            let mut eng = SubstitutionEngine::new(profile);
            eng.gates = GateParams {
                tau: self.scfg.tae_tau,
                margin_gamma: self.scfg.margin_gamma,
                beta: self.scfg.dist_beta,
                temperature: None,
            };
            eng.psi_params = PsiParams {
                eta: self.scfg.eta,
                kappa: self.scfg.kappa,
                diversity_discount: self.scfg.diversity_discount,
            };
            eng.search_h = self.scfg.search_h;
            eng.rho = self.scfg.rho;
            let (dec, _) = eng.apply(
                l,
                routings,
                &residency,
                self.scfg.miss_policy,
                None,
                &mut self.counters,
                &mut self.rng,
            );
            dec
        } else {
            // No buddy profile: degrade Buddy policy to OnDemand.
            let policy = match self.scfg.miss_policy {
                MissPolicy::Buddy => MissPolicy::OnDemand,
                p => p,
            };
            let dummy_profile = BuddyProfile::build(
                &ProfileCollector::new(self.cfg.n_layers, self.cfg.n_experts),
                &vec![1.0; self.cfg.n_layers],
                1,
                1e-9,
                false,
            )?;
            let eng = SubstitutionEngine::new(&dummy_profile);
            let (dec, _) = eng.apply(
                l,
                routings,
                &residency,
                policy,
                None,
                &mut self.counters,
                &mut self.rng,
            );
            dec
        };
        tel.substitutions += self.counters.get("substitutions") - sub_counters_before;

        // Pin every expert we are about to use, then fetch the misses.
        let mut used: Vec<usize> = Vec::new();
        let mut fetches: Vec<usize> = Vec::new();
        for (r, dec) in routings.iter().zip(&decisions) {
            for (slot, d) in dec.iter().enumerate() {
                let e = r.selected[slot];
                match d {
                    SlotDecision::Dropped => {}
                    SlotDecision::Fetch => {
                        if !fetches.contains(&e) {
                            fetches.push(e);
                        }
                        if !used.contains(&e) {
                            used.push(e);
                        }
                    }
                    _ => {
                        if !used.contains(&e) {
                            used.push(e);
                        }
                    }
                }
            }
        }
        self.transfer.with_state(|st| {
            for &e in &used {
                st.cache.pin(ExpertKey::new(l, e));
            }
        });

        // Demand loads (the synchronous miss stall).
        let mut transient: Vec<usize> = Vec::new();
        let mut pending: Vec<ExpertKey> = Vec::new();
        for &e in &fetches {
            let key = ExpertKey::new(l, e);
            match self.transfer.request(key, TransferPriority::Demand) {
                LoadDecision::StartLoad { .. } | LoadDecision::AlreadyLoading => {
                    pending.push(key)
                }
                LoadDecision::AlreadyGpu => {}
                LoadDecision::NoRoom => transient.push(e),
            }
        }
        tel.fetches += fetches.len() as u64;
        if !pending.is_empty() {
            let t0 = Instant::now();
            for key in &pending {
                self.transfer.wait_gpu(*key);
            }
            tel.stall_seconds += t0.elapsed().as_secs_f64();
        }
        self.sync_device_buffers()?;

        // Transient fetches: cache had no unpinned slot; stream the weights
        // through without admission (still pays the PCIe time).
        let mut transient_bufs: BTreeMap<usize, [xla::PjRtBuffer; 3]> = BTreeMap::new();
        for &e in &transient {
            let key = ExpertKey::new(l, e);
            let dur = self
                .transfer
                .with_state(|st| st.pcie.transfer_duration(self.store.expert_bytes));
            if self.opts.time_scale > 0.0 {
                std::thread::sleep(dur.mul_f64(self.opts.time_scale));
            }
            self.transfer
                .with_state(|st| st.pcie.record(self.store.expert_bytes, false));
            let w = self.store.expert(key)?;
            let b1 = self.rt.to_device(&w.0.data, &w.0.dims)?;
            let b3 = self.rt.to_device(&w.1.data, &w.1.dims)?;
            let b2 = self.rt.to_device(&w.2.data, &w.2.dims)?;
            transient_bufs.insert(e, [b1, b3, b2]);
            tel.transient_fetches += 1;
        }

        // Group tokens by expert and execute.
        let mut groups: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for (t, (r, dec)) in routings.iter().zip(&decisions).enumerate() {
            for (slot, sd) in dec.iter().enumerate() {
                if matches!(sd, SlotDecision::Dropped) {
                    continue;
                }
                groups.entry(r.selected[slot]).or_default().push((t, slot));
            }
        }

        let mut out = Tensor::zeros(vec![n_real, d]);
        for (&e, members) in &groups {
            let rows: Vec<usize> = members.iter().map(|&(t, _)| t).collect();
            let grp = h.gather_rows(&rows);
            let tb = self
                .cfg
                .token_bucket_for(rows.len())
                .context("expert group exceeds largest bucket")?;
            let grp = grp.pad_rows(tb);
            let hbuf = self.rt.to_device(&grp.data, &grp.dims)?;
            let key = ExpertKey::new(l, e);
            let y = if let Some(bufs) = transient_bufs.get(&e) {
                self.reg.run_buffers(
                    &format!("expert_T{tb}"),
                    &[&hbuf, &bufs[0], &bufs[1], &bufs[2]],
                )?
            } else {
                let bufs = self.reg.expert_buffers(key)?;
                self.reg.run_buffers(
                    &format!("expert_T{tb}"),
                    &[&hbuf, &bufs[0], &bufs[1], &bufs[2]],
                )?
            }
            .single()?;
            for (i, &(t, slot)) in members.iter().enumerate() {
                let w = routings[t].weights[slot];
                let orow = out.row_mut(t);
                for (o, yv) in orow.iter_mut().zip(y.row(i)) {
                    *o += w * yv;
                }
            }
            self.counters.inc("expert_invocations");
        }

        self.transfer.with_state(|st| {
            for &e in &used {
                st.cache.unpin(ExpertKey::new(l, e));
            }
        });
        Ok(out)
    }

    /// Mirror cache arrivals/evictions into device buffers.
    fn sync_device_buffers(&mut self) -> Result<()> {
        for key in self.transfer.drain_evictions() {
            self.reg.evict_expert(key);
        }
        for (key, w) in self.transfer.drain_arrivals() {
            self.reg.admit_expert(&self.rt, key, &w)?;
        }
        Ok(())
    }

    /// Issue prefetches for layer `l + 1` given the hidden state leaving
    /// layer `l` (the Fig 3 overlap).
    fn prefetch_next(&mut self, l: usize, hidden: &Tensor) {
        let next = l + 1;
        if next >= self.cfg.n_layers {
            return;
        }
        if let Some(pred) = self.predictor.as_mut() {
            let ctx = PredictContext { hidden: Some(hidden), actual: None };
            self.prefetcher.prefetch_layer(next, pred.as_mut(), &ctx);
        }
    }
}
