//! The inference engine: orchestrates the stages per layer, routes tokens,
//! applies the miss policy (buddy substitution / on-demand / random /
//! drop), schedules expert execution against the cache, and drives the
//! prefetcher — the complete Figure 3 + Algorithm 1 pipeline.
//!
//! Stage execution is delegated to a [`StageRunner`] backend (PJRT
//! artifacts or the pure-Rust reference interpreter); all timing flows
//! through the engine's [`SimClock`]. Under a virtual clock the engine
//! *models* compute time (`ServingConfig::sim_attn_s` per layer,
//! `sim_expert_s` per expert invocation) and transfer stalls advance the
//! clock, so throughput/latency numbers are deterministic simulated
//! measurements; under a real-time clock they are genuine elapsed time.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::buddy::{BuddyProfile, GateParams, PsiParams, SlotDecision, SubstitutionEngine, TokenRouting};
use crate::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use crate::memory::{EvictPolicy, ExpertCache, LoadDecision, PcieSim, TransferEngine, TransferHandle, TransferOutcome, TransferPriority, TransferTuning};
use crate::model::route::routings_from_probs;
use crate::model::seq::{KvBatchView, Sequence};
use crate::prefetch::{OracleNoisy, PreGate, PredictContext, Predictor, PrefetchEngine, TopFreq};
use crate::profilecollect::ProfileCollector;
use crate::runtime::{BackendKind, RefStages, StageRunner};
use crate::stats::Counters;
use crate::topology::{HopContext, Placement, Topology};
use crate::trace::{StallKind, Tracer, Track};
use crate::util::arena::Arena;
use crate::util::clock::{ClockMode, SimClock};
use crate::util::math::argmax;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::tensor::{Tensor, TensorView};
use crate::weights::{ExpertKey, ExpertWeights, WeightStore};

/// Engine construction options orthogonal to the serving config.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Time source for the whole stack: `Virtual` (default) simulates the
    /// timeline deterministically with no sleeping; `RealTime` measures
    /// and enforces wall-clock time (PCIe stalls really sleep).
    pub clock: ClockMode,
    /// Record pre-substitution routing into a ProfileCollector.
    pub collect_profile: bool,
    /// Keep per-step logits on each sequence (accuracy evaluation).
    pub record_logits: bool,
    pub evict_policy: EvictPolicy,
    /// PJRT backend only: keep non-expert weights as device buffers and run
    /// stages via the buffer path instead of shipping weight literals
    /// host->device on every call (§Perf; the literal path is retained for
    /// before/after measurement).
    pub weight_buffers: bool,
    /// Stage backend selection (PJRT artifacts vs reference interpreter).
    pub backend: BackendKind,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            clock: ClockMode::Virtual,
            collect_profile: false,
            record_logits: false,
            evict_policy: EvictPolicy::Lru,
            weight_buffers: true,
            backend: BackendKind::Auto,
        }
    }
}

/// Per-step telemetry (aggregated into server metrics).
#[derive(Debug, Clone, Default)]
pub struct StepTelemetry {
    /// Seconds (virtual or real) spent stalled on demand transfers this step.
    pub stall_seconds: f64,
    pub substitutions: u64,
    pub fetches: u64,
    /// Fetches served outside the cache (all slots pinned).
    pub transient_fetches: u64,
    /// Peer-link hops paid for cross-device buddy dispatches this step
    /// (always 0 with `n_devices == 1`).
    pub peer_hops: u64,
    /// Misses absorbed by a surviving replica of a fault-displaced expert
    /// (degradation waterfall arm 1; always 0 without an active fault
    /// plan).
    pub replica_hits: u64,
    /// Demand fetches that needed at least one re-issue (lost in-flight
    /// transfer) or a fresh post-timeout attempt this step (arm 3).
    pub retried_fetches: u64,
    /// Experts dropped from the computation after the waterfall exhausted
    /// every recovery arm (arm 4; only possible under a transfer
    /// deadline).
    pub waterfall_drops: u64,
    /// True when any waterfall arm fired this step — requests that include
    /// such a step are annotated as degraded in the serving telemetry.
    pub degraded: bool,
}

/// Pooled decode-step staging buffers (see [`Engine::decode_step`]):
/// reused across steps so a steady-state step allocates nothing for its
/// token ids, position masks, or lm-head input.
#[derive(Default)]
struct StepScratch {
    toks: Vec<i32>,
    pos_mask: Tensor,
    xb: Tensor,
}

pub struct Engine {
    pub cfg: ModelConfig,
    pub scfg: ServingConfig,
    pub opts: EngineOptions,
    stages: Box<dyn StageRunner>,
    store: Arc<WeightStore>,
    transfer: TransferHandle,
    clock: SimClock,
    /// Expert→device map for the simulated expert-parallel fleet (all
    /// device 0 when `scfg.n_devices == 1`).
    placement: Placement,
    /// Device×device peer hop counts (`crate::topology::Topology`).
    hop_matrix: Vec<Vec<usize>>,
    buddy_profile: Option<BuddyProfile>,
    /// Empty profile built once at construction for the no-buddy path
    /// (previously rebuilt inside every per-layer `run_moe` call).
    fallback_profile: Option<BuddyProfile>,
    predictor: Option<Box<dyn Predictor>>,
    prefetcher: PrefetchEngine,
    pub counters: Counters,
    pub profile_out: Option<ProfileCollector>,
    /// Span/event recorder shared with the transfer fleet (`Tracer::off()`
    /// unless `scfg.trace` selects a sink; every emission site is an
    /// inlined no-op when off).
    tracer: Tracer,
    rng: Rng,
    next_seq_id: u64,
    /// Decode steps since the last online re-placement pass.
    steps_since_replan: usize,
    /// Transfer-fleet fault epoch observed at the last failover scan.
    last_fault_epoch: u64,
    /// Device-down mask as of the last failover scan.
    down_seen: Vec<bool>,
    /// Original home sets of experts rerouted off downed devices,
    /// restored (lazily re-admitted) when their devices recover.
    displaced: BTreeMap<ExpertKey, Vec<usize>>,
    /// Pooled per-step staging (decode hot path).
    step_scratch: StepScratch,
    /// Pooled per-expert-group gather+pad staging for `run_moe`.
    arena: Arena,
    /// Brownout (overload degradation) engaged: misses gate through the
    /// permissive `scfg.admission.brownout_tae_tau` and awaited transfers
    /// run under the tightened brownout deadline. Always `false` with
    /// admission control disabled — the degenerate case never toggles it.
    brownout_active: bool,
    /// The configured transfer deadline, restored on brownout exit.
    base_deadline: Option<Duration>,
}

impl Engine {
    /// Build the engine: construct the stage backend, warm the cache with
    /// the most popular experts per layer, start the transfer engine.
    ///
    /// `warm_rank` ranks experts per layer for cache warm-up + the TopFreq
    /// predictor (pass profiled activation ranks; falls back to router-bias
    /// popularity).
    pub fn new(
        cfg: ModelConfig,
        scfg: ServingConfig,
        store: Arc<WeightStore>,
        buddy_profile: Option<BuddyProfile>,
        warm_rank: Option<Vec<Vec<usize>>>,
        opts: EngineOptions,
    ) -> Result<Self> {
        scfg.validate()?;
        if !scfg.fault_plan.is_empty() && matches!(opts.clock, ClockMode::RealTime) {
            anyhow::bail!("fault injection is virtual-clock only (deterministic discrete events)");
        }
        let clock = SimClock::new(opts.clock);
        let mut stages = Self::build_stages(&cfg, &store, &opts)?;
        log::info!("engine backend: {}, clock: {}", stages.name(), opts.clock.name());

        let capacity = scfg.gpu_experts_per_layer(cfg.n_experts).max(1);
        let n_dev = scfg.n_devices;
        // The layer budget is split evenly across the fleet (remainder to
        // the low device ids); with one device this is the full capacity.
        // Every device needs >= 1 slot (ExpertCache invariant), so when
        // capacity < n_devices the fleet's aggregate runtime budget is
        // inflated to n_devices slots per layer — warn, because that
        // breaks constant-budget comparisons across device counts.
        if capacity < n_dev {
            log::warn!(
                "per-layer cache budget {capacity} < n_devices {n_dev}: \
                 every device gets a minimum 1-slot cache, inflating the \
                 fleet's aggregate budget to {n_dev} experts per layer"
            );
        }
        let per_dev_cap =
            |d: usize| (capacity / n_dev + usize::from(d < capacity % n_dev)).max(1);
        let mut caches: Vec<ExpertCache> = (0..n_dev)
            .map(|d| {
                ExpertCache::new(cfg.n_layers, cfg.n_experts, per_dev_cap(d), opts.evict_policy)
            })
            .collect();

        let warm_rank = warm_rank.unwrap_or_else(|| Self::bias_rank(&cfg, &store));
        let placement = Placement::build(
            scfg.placement,
            cfg.n_layers,
            cfg.n_experts,
            n_dev,
            Some(&warm_rank),
            scfg.replication_factor,
        );
        let topology = Topology::new(n_dev, scfg.topology);
        // Warm each device with its share of the most popular experts: walk
        // the rank list, admitting every expert at each of its home devices
        // while those devices have room. Replica copies spend the same
        // shared per-layer budget as everything else — replication trades
        // unique residents for locality, it does not grow memory. With one
        // device (or replication_factor 1, where every home set is a
        // singleton) this admits exactly the top-`capacity` experts in
        // rank order, as before.
        for (l, ranked) in warm_rank.iter().enumerate() {
            let mut admitted = 0usize;
            for &e in ranked.iter() {
                if admitted >= capacity {
                    break;
                }
                let key = ExpertKey::new(l, e);
                let mut copies = 0usize;
                for &d in placement.homes(key) {
                    if admitted + copies >= capacity {
                        break;
                    }
                    if caches[d].gpu_count(l) >= caches[d].capacity_per_layer() {
                        continue;
                    }
                    caches[d].admit(key).context("cache warm-up")?;
                    copies += 1;
                }
                if copies > 0 {
                    let w = store.expert(key)?;
                    stages.admit_expert(key, &w)?;
                }
                admitted += copies;
            }
        }
        log::info!(
            "cache warmed: {}/{} experts per layer ({}%)",
            capacity,
            cfg.n_experts,
            (scfg.cache_rate * 100.0) as u32
        );
        if n_dev > 1 {
            log::info!(
                "expert-parallel fleet: {} devices ({} topology, {} placement, \
                 replication_factor {})",
                n_dev,
                scfg.topology.name(),
                placement.label(),
                scfg.replication_factor
            );
        }

        let links: Vec<PcieSim> = (0..n_dev)
            .map(|_| {
                PcieSim::new(scfg.pcie_bandwidth, scfg.pcie_base_latency, scfg.transfer_bytes_scale)
            })
            .collect();
        let peer = PcieSim::new(scfg.peer_bandwidth, scfg.peer_base_latency, 1.0);
        let hop_matrix = topology.hop_matrix();
        let tuning = TransferTuning {
            deadline: (scfg.transfer_deadline_s > 0.0)
                .then(|| Duration::from_secs_f64(scfg.transfer_deadline_s)),
            max_retries: scfg.transfer_max_retries,
            backoff_base: Duration::from_secs_f64(scfg.transfer_backoff_base_s),
            seed: scfg.seed,
        };
        let base_deadline = tuning.deadline;
        let transfer = TransferEngine::spawn_multi_with(
            caches.into_iter().zip(links).collect(),
            peer,
            topology,
            placement.clone(),
            store.clone(),
            clock.clone(),
            scfg.fault_plan.timeline(),
            tuning,
        );
        // Log lines stamp virtual time once the serving clock exists.
        crate::util::logging::set_clock(&clock);
        // One recorder shared by the engine and the transfer fleet, so
        // transfer-lifecycle events and engine spans land in one ring.
        let tracer = if scfg.trace.is_on() {
            let t = Tracer::ring(scfg.trace_ring);
            transfer.with_state(|st| st.tracer = t.clone());
            t
        } else {
            Tracer::off()
        };

        let predictor: Option<Box<dyn Predictor>> = match scfg.prefetch {
            PrefetchKind::None => None,
            PrefetchKind::TopFreq => Some(Box::new(TopFreq::from_ranked(warm_rank.clone()))),
            PrefetchKind::PreGate => Some(Box::new(PreGate::new(
                store.clone(),
                cfg.d_model,
                cfg.top_k,
                cfg.rms_eps as f32,
            ))),
            PrefetchKind::OracleNoisy => {
                Some(Box::new(OracleNoisy::new(scfg.oracle_miss_rate, scfg.seed ^ 0xa5)))
            }
        };
        let prefetcher = PrefetchEngine::new(transfer.clone(), cfg.n_layers, scfg.prefetch_width);

        let profile_out = opts
            .collect_profile
            .then(|| ProfileCollector::new(cfg.n_layers, cfg.n_experts));

        // Without a buddy profile every run_moe call needs *some*
        // SubstitutionEngine; build the empty profile once here instead of
        // per layer per step.
        let fallback_profile = if buddy_profile.is_none() {
            Some(BuddyProfile::build(
                &ProfileCollector::new(cfg.n_layers, cfg.n_experts),
                &vec![1.0; cfg.n_layers],
                1,
                1e-9,
                false,
            )?)
        } else {
            None
        };

        Ok(Self {
            rng: Rng::new(scfg.seed),
            cfg,
            scfg,
            opts,
            stages,
            store,
            transfer,
            clock,
            placement,
            hop_matrix,
            buddy_profile,
            fallback_profile,
            predictor,
            prefetcher,
            counters: Counters::new(),
            profile_out,
            tracer,
            next_seq_id: 0,
            steps_since_replan: 0,
            last_fault_epoch: 0,
            down_seen: vec![false; n_dev],
            displaced: BTreeMap::new(),
            step_scratch: StepScratch::default(),
            arena: Arena::new(),
            brownout_active: false,
            base_deadline,
        })
    }

    /// Engage or release brownout degradation (the scheduler's
    /// [`crate::server::BrownoutController`] drives this on SimClock
    /// thresholds). Entering tightens the awaited-transfer deadline to
    /// `scfg.admission.brownout_transfer_deadline_s` (when nonzero) so
    /// straggling fetches take the degradation waterfall, and `run_moe`
    /// gates misses through the permissive brownout τ — shifting handling
    /// from demand-fetch toward ψ buddy substitution. Exiting restores
    /// the configured deadline and τ. Idempotent.
    pub fn set_brownout(&mut self, active: bool) {
        if self.brownout_active == active {
            return;
        }
        self.brownout_active = active;
        let deadline = if active {
            let b = self.scfg.admission.brownout_transfer_deadline_s;
            if b > 0.0 {
                Some(Duration::from_secs_f64(b))
            } else {
                self.base_deadline
            }
        } else {
            self.base_deadline
        };
        self.transfer.set_deadline(deadline);
    }

    /// Whether brownout degradation is currently engaged.
    pub fn brownout_active(&self) -> bool {
        self.brownout_active
    }

    /// The TAE gate τ in force right now: the permissive brownout τ while
    /// browned out, the configured `tae_tau` otherwise.
    fn effective_tau(&self) -> f64 {
        if self.brownout_active {
            self.scfg.admission.brownout_tae_tau
        } else {
            self.scfg.tae_tau
        }
    }

    /// Select and construct the stage backend.
    fn build_stages(
        cfg: &ModelConfig,
        store: &Arc<WeightStore>,
        opts: &EngineOptions,
    ) -> Result<Box<dyn StageRunner>> {
        match opts.backend {
            BackendKind::Reference => {
                Ok(Box::new(RefStages::new(cfg.clone(), store.clone())))
            }
            BackendKind::Pjrt => Self::build_pjrt(cfg, store, opts),
            BackendKind::Auto => {
                if cfg!(feature = "pjrt") && !cfg.artifacts.is_empty() {
                    Self::build_pjrt(cfg, store, opts)
                } else {
                    Ok(Box::new(RefStages::new(cfg.clone(), store.clone())))
                }
            }
        }
    }

    #[cfg(feature = "pjrt")]
    fn build_pjrt(
        cfg: &ModelConfig,
        store: &Arc<WeightStore>,
        opts: &EngineOptions,
    ) -> Result<Box<dyn StageRunner>> {
        Ok(Box::new(crate::runtime::PjrtStages::new(
            cfg,
            store,
            opts.weight_buffers,
        )?))
    }

    #[cfg(not(feature = "pjrt"))]
    fn build_pjrt(
        _cfg: &ModelConfig,
        _store: &Arc<WeightStore>,
        _opts: &EngineOptions,
    ) -> Result<Box<dyn StageRunner>> {
        anyhow::bail!("PJRT backend requested but the 'pjrt' cargo feature is not enabled")
    }

    /// Rank experts per layer by router bias (popularity prior).
    pub fn bias_rank(cfg: &ModelConfig, store: &WeightStore) -> Vec<Vec<usize>> {
        (0..cfg.n_layers)
            .map(|l| {
                let bias = &store
                    .tensor(&format!("L{l}.rbias"))
                    .expect("validated weight store carries a router bias per layer")
                    .data;
                let mut idx: Vec<usize> = (0..cfg.n_experts).collect();
                // total_cmp: NaN bias entries rank deterministically
                // instead of panicking the sort.
                idx.sort_by(|&a, &b| bias[b].total_cmp(&bias[a]).then(a.cmp(&b)));
                idx
            })
            .collect()
    }

    pub fn new_sequence(&mut self, prompt: Vec<i32>, max_new: usize) -> Sequence {
        self.next_seq_id += 1;
        Sequence::new(&self.cfg, self.next_seq_id, prompt, max_new)
    }

    pub fn transfer_handle(&self) -> &TransferHandle {
        &self.transfer
    }

    /// Cheap expert-working-set hint for admission-time batch
    /// composition: embed the prompt and run layer 0's router on it,
    /// returning the final prompt token's top-k expert ids. Pure stage
    /// math on borrowed weights — no clock advance, no cache, counter,
    /// RNG, or prefetch effects — so the priority-composition path (the
    /// only caller, admission control enabled) cannot perturb the
    /// disabled-path goldens. Errors degrade to an empty hint: priority
    /// composition then falls back to pure slack ordering.
    pub fn admission_affinity(&self, prompt: &[i32]) -> Vec<usize> {
        if prompt.is_empty() {
            return Vec::new();
        }
        let s = self.cfg.max_seq;
        let s0 = prompt.len().min(s);
        let mut toks = vec![0i32; s];
        toks[..s0].copy_from_slice(&prompt[..s0]);
        let x = match self.stages.embed(s, &toks) {
            Ok(x) => x,
            Err(_) => return Vec::new(),
        };
        let probs = match self.stages.router(0, &x) {
            Ok((_h, probs)) => probs,
            Err(_) => return Vec::new(),
        };
        let mut routings = routings_from_probs(&probs, s0, self.cfg.top_k);
        match routings.pop() {
            Some(r) => r.selected,
            None => Vec::new(),
        }
    }

    /// The engine's trace sink (`Tracer::off()` unless `scfg.trace` is
    /// enabled). The scheduler emits request lifecycle marks through it;
    /// sweeps export it after a run.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The live expert→device-set placement (reflects online re-placement,
    /// including its fallback flag — sweep reports read it *after* the run
    /// so they can't mislabel a silently-degraded placement).
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// The engine's time source (shared with the transfer engine, batcher,
    /// and metrics).
    pub fn clock(&self) -> SimClock {
        self.clock.clone()
    }

    /// Which stage backend is executing ("pjrt" or "reference").
    pub fn backend_name(&self) -> &'static str {
        self.stages.name()
    }

    pub fn prefetch_counters(&self) -> &Counters {
        &self.prefetcher.counters
    }

    pub fn shutdown(&self) {
        self.transfer.shutdown();
    }

    /// Model one layer's non-expert compute cost on the virtual timeline
    /// (no-op under a real-time clock: real compute takes real time).
    fn advance_layer_compute(&self) {
        self.clock
            .advance(Duration::from_secs_f64(self.scfg.sim_attn_s));
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run the prompt through the model, filling the KV cache and setting
    /// the first generated token.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<StepTelemetry> {
        let s = self.cfg.max_seq;
        let s0 = seq.prompt.len();
        let t_prefill = self.clock.now();
        let mut tel = StepTelemetry::default();

        // Embed the padded prompt.
        let mut toks = vec![0i32; s];
        toks[..s0].copy_from_slice(&seq.prompt);
        let mut x = self.stages.embed(s, &toks)?;

        let mut len_mask = vec![0.0f32; s];
        len_mask[..s0].fill(1.0);
        let mask_t = Tensor::new(vec![s], len_mask)?;

        for l in 0..self.cfg.n_layers {
            let [y, k, v] = self.stages.attn_prefill(l, &x, &mask_t)?;
            self.advance_layer_compute();
            for p in 0..s0 {
                seq.kv_k[l].row_mut(p).copy_from_slice(k.row(p));
                seq.kv_v[l].row_mut(p).copy_from_slice(v.row(p));
            }
            let (h, mut routings) = self.run_router(l, &y, s0)?;
            let moe = self.run_moe(l, &h, &mut routings, &mut tel)?;
            // Residual: x = y + moe on the real rows (padding rows unused).
            x = y;
            for t in 0..s0 {
                let row = x.row_mut(t);
                for (a, b) in row.iter_mut().zip(moe.row(t)) {
                    *a += b;
                }
            }
            self.prefetch_next(l, &x);
        }
        // LM head on the last real position.
        let last = Tensor::new(vec![1, self.cfg.d_model], x.row(s0 - 1).to_vec())?;
        let logits = self.stages.lm_head(1, &last)?;
        let pred = argmax(logits.row(0)) as i32;
        seq.predictions.push(pred);
        if self.opts.record_logits {
            seq.prefill_logits = Some(logits.row(0).to_vec());
        }
        seq.next_token = seq.fed_token(pred, 0);
        seq.pos = s0;
        self.counters.inc("prefills");
        self.tracer.span(
            t_prefill,
            self.clock.now(),
            Track::Engine,
            "prefill",
            &[("seq", seq.id as i64), ("prompt", s0 as i64)],
        );
        Ok(tel)
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// One decode step for a batch of prefilled sequences.
    ///
    /// Zero-copy KV contract (PR 5): each layer's attention reads every
    /// sequence's `[max_seq, d]` cache **in place** through a
    /// [`KvBatchView`] — the seed's per-layer `[bb, s, d]` assembly
    /// (2 × bb × s × d f32 memcpy + two fresh tensors, per layer, per
    /// token) is gone. Step staging (`toks`/`pos_mask`/`xb`) comes from
    /// pooled scratch and the embed output is reshaped in place into the
    /// batch-bucket activation, so a steady-state step performs zero KV
    /// copies and no fresh staging allocations on the reference backend
    /// (asserted in `tests/zero_copy_decode.rs`).
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<StepTelemetry> {
        let b = seqs.len();
        anyhow::ensure!(b > 0, "empty batch");
        let t_step = self.clock.now();
        let mut tel = StepTelemetry::default();
        // Take the scratch out of self so its borrows can't conflict with
        // the `&mut self` stage calls; restored on *every* exit of the
        // stage pipeline, so a failed step doesn't drop the pooled
        // buffers and silently re-allocate them forever after.
        let mut scratch = std::mem::take(&mut self.step_scratch);
        let logits = self.decode_step_stages(seqs, &mut scratch, &mut tel);
        self.step_scratch = scratch;
        let logits = logits?;

        for (i, sq) in seqs.iter_mut().enumerate() {
            let row = logits.row(i);
            if self.opts.record_logits {
                sq.logits_log.push(row.to_vec());
            }
            let pred = argmax(row) as i32;
            sq.predictions.push(pred);
            // Position of the *next* fed token: generated.len()+1 (the
            // prefill prediction occupies position 0).
            let fed = sq.fed_token(pred, sq.generated.len() + 1);
            sq.advance(fed);
        }
        self.counters.inc("decode_steps");
        self.counters.add("decode_tokens", b as u64);
        self.tracer.span(
            t_step,
            self.clock.now(),
            Track::Engine,
            "decode_step",
            &[("batch", b as i64)],
        );
        self.maybe_replan();
        Ok(tel)
    }

    /// Online re-placement cadence: every `replan_interval_steps` decode
    /// steps (when replication is enabled on a multi-device fleet), re-rank
    /// experts by live routing telemetry and promote/demote replicas.
    fn maybe_replan(&mut self) {
        if self.scfg.replication_factor <= 1
            || self.scfg.n_devices <= 1
            || self.scfg.replan_interval_steps == 0
        {
            return;
        }
        self.steps_since_replan += 1;
        if self.steps_since_replan < self.scfg.replan_interval_steps {
            return;
        }
        self.steps_since_replan = 0;
        self.replan_replicas();
    }

    /// One re-placement pass. Per layer: rank experts by their live use
    /// counters (the primary-home cache sees every routing hit), take the
    /// top `replication_factor` as the hot set, then promote newly-hot
    /// experts to `min(replication_factor, n_devices)` homes and demote
    /// replicas that fell out of the hot set. Promotions copy weights
    /// device→device over the contended peer links as real asynchronous
    /// transfers; a promotion that finds no evictable slot is skipped and
    /// counted (`replica_promote_noroom`), never silently retried.
    fn replan_replicas(&mut self) {
        let n_exp = self.cfg.n_experts;
        let n_dev = self.scfg.n_devices;
        let r = self.scfg.replication_factor.min(n_exp);
        let width = self.scfg.replication_factor.min(n_dev);
        for l in 0..self.cfg.n_layers {
            let uses: Vec<u64> = self.transfer.with_state(|st| {
                (0..n_exp)
                    .map(|e| {
                        let k = ExpertKey::new(l, e);
                        st.devices[st.home(k)].cache.use_count(k)
                    })
                    .collect()
            });
            let mut rank: Vec<usize> = (0..n_exp).collect();
            rank.sort_by(|&a, &b| uses[b].cmp(&uses[a]).then(a.cmp(&b)));
            let hot: BTreeSet<usize> = rank[..r].iter().copied().collect();
            for e in 0..n_exp {
                let key = ExpertKey::new(l, e);
                let cur = self.placement.homes(key).to_vec();
                if hot.contains(&e) && cur.len() < width {
                    // Promote: copy the primary's replica to the next
                    // devices round the id space, skipping existing homes.
                    let primary = cur[0];
                    let mut homes = cur.clone();
                    for j in 1..n_dev {
                        if homes.len() >= width {
                            break;
                        }
                        let d = (primary + j) % n_dev;
                        if homes.contains(&d) {
                            continue;
                        }
                        if self.transfer.replica_promote(key, primary, d) {
                            homes.push(d);
                            self.counters.inc("replica_promotions");
                        } else {
                            self.counters.inc("replica_promote_noroom");
                        }
                    }
                    if homes.len() > cur.len() {
                        self.set_homes(key, homes);
                    }
                } else if !hot.contains(&e) && cur.len() > 1 {
                    // Demote: shrink back to the primary home. A copy that
                    // cannot be dropped yet (pinned / host-loading) keeps
                    // its home and is retried next pass.
                    let mut homes = vec![cur[0]];
                    for &d in &cur[1..] {
                        if self.transfer.replica_demote(key, d) {
                            self.counters.inc("replica_demotions");
                        } else {
                            homes.push(d);
                        }
                    }
                    if homes.len() < cur.len() {
                        self.set_homes(key, homes);
                    }
                }
            }
        }
    }

    /// Update an expert's home set in both placement copies (the engine's
    /// and the transfer fleet's — they must agree, since routing decisions
    /// happen on both sides of the lock).
    fn set_homes(&mut self, key: ExpertKey, homes: Vec<usize>) {
        self.placement.set_homes(key, homes.clone());
        self.transfer.with_state(|st| st.placement.set_homes(key, homes));
    }

    // ------------------------------------------------------------------
    // Failure recovery (see the "Failure model" section in ROADMAP.md)
    // ------------------------------------------------------------------

    /// Poll the fleet's fault epoch and run failover when it moved:
    /// reroute experts off newly-downed devices and restore original
    /// homes when devices recover. Called at the top of every `run_moe`,
    /// i.e. strictly between pin windows, so a placement change never
    /// splits a pin/unpin pair across different home sets. A no-op (not
    /// even a lock) when no fault plan is active.
    fn poll_faults(&mut self) {
        if self.scfg.fault_plan.is_empty() {
            return;
        }
        let (epoch, down) = self
            .transfer
            .with_state(|st| (st.fault_epoch(), st.down_mask()));
        if epoch == self.last_fault_epoch {
            return;
        }
        self.last_fault_epoch = epoch;
        self.tracer.instant(
            self.clock.now(),
            Track::Fault,
            "fault_epoch",
            &[("epoch", epoch as i64)],
        );
        let newly_down: Vec<usize> = (0..down.len())
            .filter(|&d| down[d] && !self.down_seen[d])
            .collect();
        let newly_up = (0..down.len()).any(|d| !down[d] && self.down_seen[d]);
        self.down_seen.clone_from(&down);
        for d in newly_down {
            self.failover_device(d, &down);
        }
        if newly_up {
            self.restore_homes(&down);
        }
    }

    /// Reroute every expert homed on the failed device `dev`. Experts
    /// with surviving replicas keep serving from them (one emergency
    /// promotion per expert tries to restore the lost replica width,
    /// charged as a real peer transfer); single-homed experts are
    /// deterministically rehomed to the next live device and acquire
    /// their weights lazily on the first demand fetch. Original home
    /// sets are remembered in `displaced` for restoration on recovery.
    fn failover_device(&mut self, dev: usize, down: &[bool]) {
        let n_dev = self.scfg.n_devices;
        self.counters.inc("device_failovers");
        for l in 0..self.cfg.n_layers {
            for e in 0..self.cfg.n_experts {
                let key = ExpertKey::new(l, e);
                let cur = self.placement.homes(key).to_vec();
                if !cur.contains(&dev) {
                    continue;
                }
                self.displaced.entry(key).or_insert_with(|| cur.clone());
                let survivors: Vec<usize> =
                    cur.iter().copied().filter(|&h| !down[h]).collect();
                if survivors.is_empty() {
                    // The injector refuses to down the last live device,
                    // so a live rehoming target always exists.
                    let Some(next) =
                        (1..n_dev).map(|j| (dev + j) % n_dev).find(|&x| !down[x])
                    else {
                        continue;
                    };
                    self.set_homes(key, vec![next]);
                    self.counters.inc("failover_rehomed");
                } else {
                    let mut homes = survivors;
                    if homes.len() < cur.len() {
                        let src = homes[0];
                        if let Some(tgt) = (1..n_dev)
                            .map(|j| (src + j) % n_dev)
                            .find(|&x| !down[x] && !homes.contains(&x))
                        {
                            if self.transfer.replica_promote(key, src, tgt) {
                                homes.push(tgt);
                                self.counters.inc("emergency_promotions");
                            } else {
                                self.counters.inc("emergency_promote_noroom");
                            }
                        }
                    }
                    self.set_homes(key, homes);
                    self.counters.inc("failover_rerouted");
                }
            }
        }
    }

    /// Restore the original home set of every displaced expert whose
    /// homes are all live again. Re-admission is lazy: the restored
    /// primary refetches weights on its next demand load, and an
    /// emergency replica left outside the restored home set becomes an
    /// ordinary eviction candidate.
    fn restore_homes(&mut self, down: &[bool]) {
        let restorable: Vec<(ExpertKey, Vec<usize>)> = self
            .displaced
            .iter()
            .filter(|(_, orig)| orig.iter().all(|&h| !down[h]))
            .map(|(k, o)| (*k, o.clone()))
            .collect();
        for (key, orig) in restorable {
            self.displaced.remove(&key);
            self.set_homes(key, orig);
            self.counters.inc("failover_restored");
        }
    }

    /// The fallible stage pipeline of one decode step: embed → per-layer
    /// (view-based attention → router → MoE) → lm head; returns the batch
    /// logits. Split out of [`Engine::decode_step`] so the pooled scratch
    /// is restored no matter where an error exits.
    fn decode_step_stages(
        &mut self,
        seqs: &mut [&mut Sequence],
        scratch: &mut StepScratch,
        tel: &mut StepTelemetry,
    ) -> Result<Tensor> {
        let b = seqs.len();
        let bb = self
            .cfg
            .batch_bucket_for(b)
            .context("batch larger than any bucket")?;
        let d = self.cfg.d_model;
        let s = self.cfg.max_seq;

        // Embed current tokens (token bucket >= b).
        let tb = self.cfg.token_bucket_for(b).context("no token bucket")?;
        scratch.toks.clear();
        scratch.toks.resize(tb, 0);
        for (i, sq) in seqs.iter().enumerate() {
            scratch.toks[i] = sq.next_token;
        }
        // x [bb, d]: the embed output reshaped in place — pad (or trim)
        // the leading dim to the batch bucket, then re-zero the padding
        // lanes, which hold token-0 embeddings after a widening resize.
        // Padding rows must stay exactly zero: PreGate reads every row of
        // the hidden state, so nonzero padding would change prefetch
        // decisions and break byte-identity with the seed path.
        let mut x = self.stages.embed(tb, &scratch.toks)?;
        x.data.resize(bb * d, 0.0);
        x.dims[0] = bb;
        for i in b..bb.min(tb) {
            x.row_mut(i).fill(0.0);
        }

        // Position masks (pooled).
        scratch.pos_mask.reset_zeros(&[bb, s]);
        for (i, sq) in seqs.iter().enumerate() {
            scratch.pos_mask.row_mut(i)[..sq.pos].fill(1.0);
        }

        for l in 0..self.cfg.n_layers {
            // Attention borrows each sequence's KV cache in place; the
            // view ends before `write_kv` appends this step's new row.
            let [y, k_new, v_new] = {
                let kv = KvBatchView::new(&*seqs, l);
                self.stages.attn_decode(l, bb, &x, &kv, &scratch.pos_mask)?
            };
            self.advance_layer_compute();
            for (i, sq) in seqs.iter_mut().enumerate() {
                sq.write_kv(l, k_new.row(i), v_new.row(i));
            }

            let (h, mut routings) = self.run_router(l, &y, b)?;
            let moe = self.run_moe(l, &h, &mut routings, tel)?;
            x = y;
            for t in 0..b {
                let row = x.row_mut(t);
                for (a, mo) in row.iter_mut().zip(moe.row(t)) {
                    *a += mo;
                }
            }
            self.prefetch_next(l, &x);
        }

        // LM head over the batch (pooled staging).
        scratch.xb.reset_zeros(&[tb, d]);
        for i in 0..b {
            scratch.xb.row_mut(i).copy_from_slice(x.row(i));
        }
        self.stages.lm_head(tb, &scratch.xb)
    }

    // ------------------------------------------------------------------
    // Shared per-layer stages
    // ------------------------------------------------------------------

    /// Router stage on `y` ([T, d]); routes the first `n_real` rows.
    fn run_router(&mut self, l: usize, y: &Tensor, n_real: usize) -> Result<(Tensor, Vec<TokenRouting>)> {
        let (h, probs) = self.stages.router(l, y)?;
        let routings = routings_from_probs(&probs, n_real, self.cfg.top_k);
        if let Some(pc) = self.profile_out.as_mut() {
            for r in &routings {
                pc.record(l, &r.selected, &r.weights)?;
            }
        }
        Ok((h, routings))
    }

    /// The MoE stage: miss policy + expert scheduling + weighted combine.
    /// `h` is the normed input [T, d]; returns the MoE output for the first
    /// `routings.len()` rows.
    fn run_moe(
        &mut self,
        l: usize,
        h: &Tensor,
        routings: &mut Vec<TokenRouting>,
        tel: &mut StepTelemetry,
    ) -> Result<Tensor> {
        let n_real = routings.len();
        let d = self.cfg.d_model;
        // Fault failover runs strictly between pin windows (none are held
        // here), so placement changes can't split a pin/unpin pair.
        self.poll_faults();

        // Verification step of the prefetch pipeline (Fig 3). First-seen
        // order is load-bearing (mark_use ticks, prefetch verification), so
        // dedup with a set membership check but keep the Vec ordering.
        let mut actual_unique: Vec<usize> = Vec::new();
        let mut actual_seen: BTreeSet<usize> = BTreeSet::new();
        for r in routings.iter() {
            for &e in &r.selected {
                if actual_seen.insert(e) {
                    actual_unique.push(e);
                }
            }
        }
        self.prefetcher.verify(l, &actual_unique);
        // Routed expert-slot denominator for availability metrics
        // (1 - dropped_slots / routed_slots in the fault sweep).
        self.counters.add(
            "routed_slots",
            routings.iter().map(|r| r.selected.len() as u64).sum::<u64>(),
        );

        // Residency mask + policy application. Residency is fleet-wide:
        // an expert counts as resident when it sits on its home device.
        let residency = self.transfer.with_state(|st| {
            for &e in &actual_unique {
                st.mark_use(ExpertKey::new(l, e));
            }
            st.residency_mask(l)
        });
        // Waterfall arm 1: a displaced expert still resident on a
        // surviving (or emergency-promoted) replica is a replica hit —
        // the fault cost its home but not its service.
        if !self.displaced.is_empty() {
            for &e in &actual_unique {
                if residency[e] && self.displaced.contains_key(&ExpertKey::new(l, e)) {
                    self.counters.inc("waterfall_replica_hits");
                    tel.replica_hits += 1;
                    self.tracer.instant(
                        self.clock.now(),
                        Track::Engine,
                        "replica_hit",
                        &[("layer", l as i64), ("expert", e as i64)],
                    );
                }
            }
        }
        let multi_device = self.scfg.n_devices > 1;
        let sub_counters_before = self.counters.get("substitutions");
        let (mut decisions, sub_events) = if let Some(profile) = self.buddy_profile.as_ref() {
            let mut eng = SubstitutionEngine::new(profile);
            // Brownout shifts the gate toward substitution (effective_tau
            // == scfg.tae_tau whenever brownout is off, so the default
            // path is untouched).
            eng.gates = GateParams {
                tau: self.effective_tau(),
                margin_gamma: self.scfg.margin_gamma,
                beta: self.scfg.dist_beta,
                temperature: None,
            };
            eng.psi_params = PsiParams {
                eta: self.scfg.eta,
                kappa: self.scfg.kappa,
                diversity_discount: self.scfg.diversity_discount,
            };
            eng.search_h = self.scfg.search_h;
            eng.rho = self.scfg.rho;
            if multi_device {
                // Real placement-derived hop counts: ψ's κ term goes live,
                // scoring each candidate against its *nearest* replica.
                eng.topo = Some(HopContext {
                    homes: self.placement.layer_homes(l),
                    hop_matrix: &self.hop_matrix,
                });
            }
            eng.apply(
                l,
                routings,
                &residency,
                self.scfg.miss_policy,
                None,
                &mut self.counters,
                &mut self.rng,
            )
        } else {
            // No buddy profile: degrade Buddy policy to OnDemand and use
            // the empty profile built once at engine construction.
            let policy = match self.scfg.miss_policy {
                MissPolicy::Buddy => MissPolicy::OnDemand,
                p => p,
            };
            let dummy_profile = self
                .fallback_profile
                .as_ref()
                .expect("fallback profile built when no buddy profile is given");
            let eng = SubstitutionEngine::new(dummy_profile);
            eng.apply(
                l,
                routings,
                &residency,
                policy,
                None,
                &mut self.counters,
                &mut self.rng,
            )
        };
        let call_subs = self.counters.get("substitutions") - sub_counters_before;
        tel.substitutions += call_subs;
        if self.tracer.enabled() {
            for ev in &sub_events {
                self.tracer.instant(
                    self.clock.now(),
                    Track::Engine,
                    "psi_sub",
                    &[("layer", l as i64), ("from", ev.from as i64), ("to", ev.to as i64)],
                );
            }
        }

        // Waterfall arm 2: buddy substitutions standing in for experts a
        // fault displaced (Ψ already steered these to resident buddies).
        let mut victim_subs = 0u64;
        if !self.displaced.is_empty() && !sub_events.is_empty() {
            victim_subs = sub_events
                .iter()
                .filter(|ev| self.displaced.contains_key(&ExpertKey::new(l, ev.from)))
                .count() as u64;
            if victim_subs > 0 {
                self.counters.add("waterfall_buddy_subs", victim_subs);
                self.tracer.instant(
                    self.clock.now(),
                    Track::Engine,
                    "waterfall_buddy_sub",
                    &[("layer", l as i64), ("count", victim_subs as i64)],
                );
            }
        }

        // Cross-device substitutions pay the peer interconnect: dispatching
        // a token to a buddy homed on another device adds unplanned
        // all-to-all hops (one activation row each way per hop crossed),
        // routed between the *nearest* replica pair and queued on the
        // serialized peer links. Same-device buddies (including same-device
        // replicas) are free — exactly what κ steers toward.
        if multi_device && !sub_events.is_empty() {
            let ctx = HopContext {
                homes: self.placement.layer_homes(l),
                hop_matrix: &self.hop_matrix,
            };
            let mut routes: Vec<(usize, usize)> = Vec::new();
            let mut hop_total = 0usize;
            let mut crossed = 0u64;
            for ev in &sub_events {
                let (from, to, hop) = ctx.route(ev.from, ev.to);
                if hop > 0 {
                    hop_total += hop;
                    crossed += 1;
                    routes.push((from, to));
                }
            }
            if hop_total > 0 {
                let bytes = 2 * self.cfg.d_model * std::mem::size_of::<f32>();
                self.transfer.peer_dispatch_routes(bytes, &routes);
                self.counters.add("cross_device_subs", crossed);
                self.counters.add("peer_hops", hop_total as u64);
                tel.peer_hops += hop_total as u64;
            }
        }

        // Pin every expert we are about to use, then fetch the misses.
        // First-seen order again drives transfer-request order, so dedup
        // via sets without reordering the Vecs.
        let mut used: Vec<usize> = Vec::new();
        let mut used_set: BTreeSet<usize> = BTreeSet::new();
        let mut fetches: Vec<usize> = Vec::new();
        let mut fetch_set: BTreeSet<usize> = BTreeSet::new();
        for (r, dec) in routings.iter().zip(&decisions) {
            for (slot, d) in dec.iter().enumerate() {
                let e = r.selected[slot];
                match d {
                    SlotDecision::Dropped => {}
                    SlotDecision::Fetch => {
                        if fetch_set.insert(e) {
                            fetches.push(e);
                        }
                        if used_set.insert(e) {
                            used.push(e);
                        }
                    }
                    _ => {
                        if used_set.insert(e) {
                            used.push(e);
                        }
                    }
                }
            }
        }
        self.tracer.instant(
            self.clock.now(),
            Track::Engine,
            "route",
            &[
                ("layer", l as i64),
                ("unique", actual_unique.len() as i64),
                ("fetches", fetches.len() as i64),
                ("subs", call_subs as i64),
            ],
        );
        let t_pin = self.clock.now();
        self.transfer.with_state(|st| {
            for &e in &used {
                st.pin(ExpertKey::new(l, e));
            }
        });

        // Demand loads (the synchronous miss stall).
        let mut transient: Vec<usize> = Vec::new();
        let mut pending: Vec<ExpertKey> = Vec::new();
        for &e in &fetches {
            let key = ExpertKey::new(l, e);
            match self.transfer.request(key, TransferPriority::Demand) {
                LoadDecision::StartLoad { .. } | LoadDecision::AlreadyLoading => {
                    pending.push(key)
                }
                LoadDecision::AlreadyGpu => {}
                LoadDecision::NoRoom => transient.push(e),
            }
        }
        tel.fetches += fetches.len() as u64;
        let mut dropped: Vec<usize> = Vec::new();
        let mut transient_rescues = 0u64;
        if !pending.is_empty() {
            let t0 = self.clock.now();
            for key in &pending {
                match self.transfer.wait_gpu(*key) {
                    TransferOutcome::Ok => {}
                    TransferOutcome::Retried(n) => {
                        tel.retried_fetches += 1;
                        self.counters.inc("waterfall_retried_fetches");
                        self.counters.add("transfer_retries", n as u64);
                        self.tracer.instant(
                            self.clock.now(),
                            Track::Engine,
                            "waterfall_retry",
                            &[
                                ("layer", l as i64),
                                ("expert", key.expert as i64),
                                ("retries", n as i64),
                            ],
                        );
                    }
                    TransferOutcome::TimedOut => {
                        // Waterfall arm 3 fallback: one fresh attempt (the
                        // home may have failed mid-wait and recovery or
                        // rerouting can land the next try), then either a
                        // lossless transient stream-through (no deadline
                        // configured — completeness beats latency) or a
                        // drop (arm 4: deadline pressure says give up).
                        let recovered =
                            match self.transfer.request(*key, TransferPriority::Demand) {
                                LoadDecision::StartLoad { .. }
                                | LoadDecision::AlreadyLoading => {
                                    match self.transfer.wait_gpu(*key) {
                                        TransferOutcome::Ok | TransferOutcome::Retried(_) => {
                                            tel.retried_fetches += 1;
                                            self.counters.inc("waterfall_retried_fetches");
                                            self.tracer.instant(
                                                self.clock.now(),
                                                Track::Engine,
                                                "waterfall_retry",
                                                &[
                                                    ("layer", l as i64),
                                                    ("expert", key.expert as i64),
                                                    ("retries", 0),
                                                ],
                                            );
                                            true
                                        }
                                        TransferOutcome::TimedOut => false,
                                    }
                                }
                                LoadDecision::AlreadyGpu => true,
                                LoadDecision::NoRoom => false,
                            };
                        if !recovered {
                            if self.transfer.tuning().deadline.is_none() {
                                transient.push(key.expert);
                                transient_rescues += 1;
                                self.counters.inc("waterfall_transient_rescues");
                                self.tracer.instant(
                                    self.clock.now(),
                                    Track::Engine,
                                    "transient_rescue",
                                    &[("layer", l as i64), ("expert", key.expert as i64)],
                                );
                            } else {
                                dropped.push(key.expert);
                                tel.waterfall_drops += 1;
                                self.counters.inc("waterfall_drops");
                                self.tracer.instant(
                                    self.clock.now(),
                                    Track::Engine,
                                    "waterfall_drop",
                                    &[("layer", l as i64), ("expert", key.expert as i64)],
                                );
                            }
                        }
                    }
                }
            }
            self.tracer.stall(
                StallKind::TransferWait,
                t0,
                self.clock.now(),
                Track::Engine,
                &[("layer", l as i64), ("pending", pending.len() as i64)],
            );
            tel.stall_seconds += self.clock.since(t0);
        }
        self.sync_device_buffers()?;

        // Waterfall arm 4: scrub dropped experts out of the execution
        // plan. Their tokens run on their remaining slots (weights are
        // left as-is, matching the Drop-baseline combine semantics).
        let mut dropped_slots = 0u64;
        if !dropped.is_empty() {
            for (r, dec) in routings.iter().zip(decisions.iter_mut()) {
                for (slot, sd) in dec.iter_mut().enumerate() {
                    if !matches!(sd, SlotDecision::Dropped)
                        && dropped.contains(&r.selected[slot])
                    {
                        *sd = SlotDecision::Dropped;
                        dropped_slots += 1;
                    }
                }
            }
            self.counters.add("dropped_slots", dropped_slots);
        }

        // Transient fetches: cache had no unpinned slot; stream the weights
        // through without admission (still pays the PCIe time).
        let mut transient_weights: BTreeMap<usize, ExpertWeights> = BTreeMap::new();
        for &e in &transient {
            let key = ExpertKey::new(l, e);
            self.transfer.transient_fetch_for(key, self.store.expert_bytes);
            transient_weights.insert(e, self.store.expert(key)?);
            tel.transient_fetches += 1;
        }

        // Group tokens by expert and execute.
        let mut groups: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for (t, (r, dec)) in routings.iter().zip(&decisions).enumerate() {
            for (slot, sd) in dec.iter().enumerate() {
                if matches!(sd, SlotDecision::Dropped) {
                    continue;
                }
                groups.entry(r.selected[slot]).or_default().push((t, slot));
            }
        }

        // Expert FFNs are independent work units: fan them out over scoped
        // threads (when the per-group work warrants it), then combine
        // sequentially in ascending-expert order so the weighted summation
        // order — and therefore the golden outputs — never changes.
        let group_list: Vec<(usize, Vec<(usize, usize)>)> = groups.into_iter().collect();
        let cfg = &self.cfg;
        let arena = &self.arena;
        let stages: &dyn StageRunner = self.stages.as_ref();
        let run_group = |gi: usize| -> Result<Tensor> {
            let (e, members) = &group_list[gi];
            let tb = cfg
                .token_bucket_for(members.len())
                .context("expert group exceeds largest bucket")?;
            // Gather + bucket-pad in one pass through pooled scratch: the
            // seed's gather_rows().pad_rows() pair allocated two tensors
            // and copied the group twice, per group, per layer. The
            // scratch is zero-filled, so the padding rows match pad_rows.
            let mut grp = arena.take(tb * d);
            for (ri, &(t, _)) in members.iter().enumerate() {
                grp[ri * d..(ri + 1) * d].copy_from_slice(h.row(t));
            }
            let dims = [tb, d];
            let hview = TensorView::new(&dims, &grp)?;
            let key = ExpertKey::new(l, *e);
            if let Some(w) = transient_weights.get(e) {
                stages.expert_transient(tb, w, &hview)
            } else {
                stages.expert_resident(tb, key, &hview)
            }
        };
        // Runtime dispatch, not a cargo feature: the PJRT backend's device
        // handles are thread-confined (`supports_parallel` = false, see
        // runtime/pjrt.rs), while the reference backend keeps its
        // multi-core fan-out under every feature set.
        let ys: Vec<Result<Tensor>> = if stages.supports_parallel() {
            par::par_map(group_list.len(), cfg.d_model * cfg.d_ff * 3, &run_group)
        } else {
            (0..group_list.len()).map(&run_group).collect()
        };

        let mut out = Tensor::zeros(vec![n_real, d]);
        for ((_, members), y) in group_list.iter().zip(ys) {
            let y = y?;
            for (i, &(t, slot)) in members.iter().enumerate() {
                let w = routings[t].weights[slot];
                let orow = out.row_mut(t);
                for (o, yv) in orow.iter_mut().zip(y.row(i)) {
                    *o += w * yv;
                }
            }
            self.counters.inc("expert_invocations");
        }
        // Model the MoE compute cost (one FFN pass per invoked expert).
        self.clock.advance(Duration::from_secs_f64(
            self.scfg.sim_expert_s * group_list.len() as f64,
        ));

        self.transfer.with_state(|st| {
            for &e in &used {
                st.unpin(ExpertKey::new(l, e));
            }
        });
        self.tracer.span(
            t_pin,
            self.clock.now(),
            Track::Engine,
            "pin_window",
            &[("layer", l as i64), ("pinned", used.len() as i64)],
        );

        // Degradation accounting: split substitutions/drops by whether
        // this instant falls inside a scheduled fault window, and flag
        // the step as degraded when any waterfall arm fired. Skipped
        // entirely (no clock read, no counters) without a fault plan.
        if !self.scfg.fault_plan.is_empty() {
            let in_w = self.scfg.fault_plan.in_window(self.clock.now());
            if call_subs > 0 {
                self.counters.add(
                    if in_w { "subs_in_fault_window" } else { "subs_outside_fault_window" },
                    call_subs,
                );
            }
            if dropped_slots > 0 {
                self.counters.add(
                    if in_w { "drops_in_fault_window" } else { "drops_outside_fault_window" },
                    dropped_slots,
                );
            }
            if tel.replica_hits > 0
                || tel.retried_fetches > 0
                || tel.waterfall_drops > 0
                || transient_rescues > 0
                || victim_subs > 0
            {
                tel.degraded = true;
            }
        }
        Ok(out)
    }

    /// Mirror cache arrivals/evictions into device buffers. With
    /// replication an eviction on one device can leave another replica
    /// resident; the stage buffer must survive then (the simulated devices
    /// share one stage-buffer namespace).
    fn sync_device_buffers(&mut self) -> Result<()> {
        let evictions = self.transfer.drain_evictions()?;
        if !evictions.is_empty() {
            let keep: Vec<bool> = self
                .transfer
                .with_state(|st| evictions.iter().map(|&k| st.is_gpu(k)).collect());
            for (key, keep) in evictions.into_iter().zip(keep) {
                if !keep {
                    self.stages.evict_expert(key);
                }
            }
        }
        let arrivals = self.transfer.drain_arrivals()?;
        for (key, w) in arrivals {
            self.stages.admit_expert(key, &w)?;
        }
        Ok(())
    }

    /// Issue prefetches for layer `l + 1` given the hidden state leaving
    /// layer `l` (the Fig 3 overlap).
    fn prefetch_next(&mut self, l: usize, hidden: &Tensor) {
        let next = l + 1;
        if next >= self.cfg.n_layers {
            return;
        }
        if let Some(pred) = self.predictor.as_mut() {
            let ctx = PredictContext { hidden: Some(hidden), actual: None };
            self.prefetcher.prefetch_layer(next, pred.as_mut(), &ctx);
        }
    }
}
