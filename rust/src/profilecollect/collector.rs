//! Co-activation statistics accumulator.
//!
//! For every token x and layer l with selected set S_l(x):
//!   A_l(i)    += 1            for i in S
//!   M_l(i,j)  += 1            for unordered pairs {i,j} ⊆ S  (binary)
//!   W_l(i,j)  += min(p_i,p_j) (probability-weighted, paper §3.3 (i))
//!
//! Laplace smoothing is applied at read time (paper §3.3 (ii)), and an
//! optional warm-up discount down-weights the first steps (§3.3 (iii)).

use anyhow::{bail, Result};

use crate::util::json::{arr_f32, num, obj, Json};

/// Dense symmetric co-activation matrices for one layer.
#[derive(Debug, Clone)]
pub struct CoActivation {
    pub n_experts: usize,
    /// A_l(i): tokens that routed to i.
    pub activations: Vec<f64>,
    /// M_l(i,j): binary co-activation counts (symmetric, zero diagonal).
    pub binary: Vec<f64>,
    /// Probability-weighted co-activations.
    pub weighted: Vec<f64>,
}

impl CoActivation {
    fn new(n_experts: usize) -> Self {
        Self {
            n_experts,
            activations: vec![0.0; n_experts],
            binary: vec![0.0; n_experts * n_experts],
            weighted: vec![0.0; n_experts * n_experts],
        }
    }

    #[inline]
    pub fn m(&self, i: usize, j: usize) -> f64 {
        self.binary[i * self.n_experts + j]
    }

    #[inline]
    pub fn w(&self, i: usize, j: usize) -> f64 {
        self.weighted[i * self.n_experts + j]
    }

    /// Conditional co-activation q_{j|i} (paper Eq. 4) with Laplace
    /// smoothing epsilon, over the `weighted` matrix when `use_weighted`.
    pub fn q_given(&self, i: usize, eps: f64, use_weighted: bool) -> Vec<f64> {
        let src = if use_weighted { &self.weighted } else { &self.binary };
        let row = &src[i * self.n_experts..(i + 1) * self.n_experts];
        let mut q: Vec<f64> = row.iter().map(|&x| x + eps).collect();
        q[i] = 0.0; // q_{i|i} = 0
        let sum: f64 = q.iter().sum();
        if sum > 0.0 {
            for x in q.iter_mut() {
                *x /= sum;
            }
        }
        q
    }
}

/// Streaming collector over routing events.
#[derive(Debug)]
pub struct ProfileCollector {
    layers: Vec<CoActivation>,
    /// Down-weight applied to the first `warmup_tokens` tokens per layer.
    warmup_tokens: usize,
    warmup_weight: f64,
    tokens_seen: Vec<usize>,
}

impl ProfileCollector {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        Self {
            layers: (0..n_layers).map(|_| CoActivation::new(n_experts)).collect(),
            warmup_tokens: 0,
            warmup_weight: 1.0,
            tokens_seen: vec![0; n_layers],
        }
    }

    /// Enable warm-up discounting (paper §3.3 (iii)).
    pub fn with_warmup(mut self, tokens: usize, weight: f64) -> Self {
        self.warmup_tokens = tokens;
        self.warmup_weight = weight;
        self
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Record one token's routing at one layer: selected experts and their
    /// renormalized top-k probabilities.
    pub fn record(&mut self, layer: usize, selected: &[usize], probs: &[f32]) -> Result<()> {
        if selected.len() != probs.len() {
            bail!("selected/probs length mismatch");
        }
        let la = &mut self.layers[layer];
        for &e in selected {
            if e >= la.n_experts {
                bail!("expert {e} out of range");
            }
        }
        let w = if self.tokens_seen[layer] < self.warmup_tokens {
            self.warmup_weight
        } else {
            1.0
        };
        self.tokens_seen[layer] += 1;
        let n = la.n_experts;
        for (a, &i) in selected.iter().enumerate() {
            la.activations[i] += w;
            for (b, &j) in selected.iter().enumerate().skip(a + 1) {
                let pw = probs[a].min(probs[b]) as f64 * w;
                la.binary[i * n + j] += w;
                la.binary[j * n + i] += w;
                la.weighted[i * n + j] += pw;
                la.weighted[j * n + i] += pw;
            }
        }
        Ok(())
    }

    pub fn layer(&self, l: usize) -> &CoActivation {
        &self.layers[l]
    }

    pub fn tokens_seen(&self, l: usize) -> usize {
        self.tokens_seen[l]
    }

    /// Serialize for `buddy::BuddyProfile::build` offline hand-off and the
    /// Fig 6/7/9 data dumps.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.layers
                .iter()
                .map(|la| {
                    obj(vec![
                        ("n_experts", num(la.n_experts as f64)),
                        (
                            "activations",
                            arr_f32(&la.activations.iter().map(|&x| x as f32).collect::<Vec<_>>()),
                        ),
                        (
                            "binary",
                            arr_f32(&la.binary.iter().map(|&x| x as f32).collect::<Vec<_>>()),
                        ),
                        (
                            "weighted",
                            arr_f32(&la.weighted.iter().map(|&x| x as f32).collect::<Vec<_>>()),
                        ),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let arr = j.as_arr()?;
        let mut layers = Vec::with_capacity(arr.len());
        for la in arr {
            let n = la.get("n_experts")?.as_usize()?;
            let to64 = |v: Vec<f32>| v.into_iter().map(|x| x as f64).collect::<Vec<f64>>();
            layers.push(CoActivation {
                n_experts: n,
                activations: to64(la.get("activations")?.as_f32_vec()?),
                binary: to64(la.get("binary")?.as_f32_vec()?),
                weighted: to64(la.get("weighted")?.as_f32_vec()?),
            });
        }
        let n_layers = layers.len();
        Ok(Self {
            layers,
            warmup_tokens: 0,
            warmup_weight: 1.0,
            tokens_seen: vec![0; n_layers],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_symmetric_counts() {
        let mut p = ProfileCollector::new(1, 4);
        p.record(0, &[0, 2], &[0.7, 0.3]).unwrap();
        p.record(0, &[0, 2], &[0.6, 0.4]).unwrap();
        p.record(0, &[1, 3], &[0.5, 0.5]).unwrap();
        let la = p.layer(0);
        assert_eq!(la.activations, vec![2.0, 1.0, 2.0, 1.0]);
        assert_eq!(la.m(0, 2), 2.0);
        assert_eq!(la.m(2, 0), 2.0);
        assert_eq!(la.m(0, 1), 0.0);
        assert!((la.w(0, 2) - (0.3 + 0.4)).abs() < 1e-6);
    }

    #[test]
    fn q_given_normalizes_and_zeroes_diagonal() {
        let mut p = ProfileCollector::new(1, 3);
        p.record(0, &[0, 1], &[0.5, 0.5]).unwrap();
        p.record(0, &[0, 1], &[0.5, 0.5]).unwrap();
        p.record(0, &[0, 2], &[0.5, 0.5]).unwrap();
        let q = p.layer(0).q_given(0, 0.0, false);
        assert_eq!(q[0], 0.0);
        assert!((q[1] - 2.0 / 3.0).abs() < 1e-9);
        assert!((q[2] - 1.0 / 3.0).abs() < 1e-9);
        assert!((q.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn laplace_smoothing_gives_mass_to_unseen() {
        let mut p = ProfileCollector::new(1, 3);
        p.record(0, &[0, 1], &[0.5, 0.5]).unwrap();
        let q = p.layer(0).q_given(0, 0.5, false);
        assert!(q[2] > 0.0);
        assert!(q[1] > q[2]);
    }

    #[test]
    fn warmup_downweights() {
        let mut p = ProfileCollector::new(1, 2).with_warmup(1, 0.1);
        p.record(0, &[0, 1], &[0.5, 0.5]).unwrap(); // warm-up token
        p.record(0, &[0, 1], &[0.5, 0.5]).unwrap();
        let la = p.layer(0);
        assert!((la.activations[0] - 1.1).abs() < 1e-9);
        assert!((la.m(0, 1) - 1.1).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_input() {
        let mut p = ProfileCollector::new(1, 2);
        assert!(p.record(0, &[0, 5], &[0.5, 0.5]).is_err());
        assert!(p.record(0, &[0], &[0.5, 0.5]).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut p = ProfileCollector::new(2, 3);
        p.record(0, &[0, 1], &[0.6, 0.4]).unwrap();
        p.record(1, &[1, 2], &[0.9, 0.1]).unwrap();
        let j = p.to_json();
        let back = ProfileCollector::from_json(&j).unwrap();
        assert_eq!(back.layer(0).m(0, 1), p.layer(0).m(0, 1));
        assert!((back.layer(1).w(1, 2) - p.layer(1).w(1, 2)).abs() < 1e-6);
    }
}
