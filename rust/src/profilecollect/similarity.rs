//! Weight-space expert similarity (paper Fig 4): pairwise cosine similarity
//! of flattened expert parameters within one layer.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::util::math::cosine;
use crate::weights::{ExpertKey, WeightStore};

/// Dense symmetric [E, E] cosine-similarity matrix for `layer`.
pub fn expert_similarity_matrix(
    cfg: &ModelConfig,
    store: &WeightStore,
    layer: usize,
) -> Result<Vec<Vec<f32>>> {
    let e = cfg.n_experts;
    let flats: Vec<Vec<f32>> = (0..e)
        .map(|i| store.expert_flat(ExpertKey::new(layer, i)))
        .collect::<Result<_>>()?;
    let mut m = vec![vec![0.0f32; e]; e];
    for i in 0..e {
        m[i][i] = 1.0;
        for j in (i + 1)..e {
            let c = cosine(&flats[i], &flats[j]);
            m[i][j] = c;
            m[j][i] = c;
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_unit_diagonal() {
        let cfg = ModelConfig::test_tiny();
        let store = WeightStore::synthetic(&cfg, 3);
        let m = expert_similarity_matrix(&cfg, &store, 0).unwrap();
        for i in 0..cfg.n_experts {
            assert!((m[i][i] - 1.0).abs() < 1e-6);
            for j in 0..cfg.n_experts {
                assert!((m[i][j] - m[j][i]).abs() < 1e-6);
                assert!(m[i][j].abs() <= 1.0 + 1e-6);
            }
        }
    }

    #[test]
    fn random_experts_near_orthogonal() {
        // Synthetic store has no family structure: off-diagonal similarity
        // should be near zero (contrast with the engineered bundle).
        let cfg = ModelConfig::test_tiny();
        let store = WeightStore::synthetic(&cfg, 4);
        let m = expert_similarity_matrix(&cfg, &store, 1).unwrap();
        let mut acc = 0.0f64;
        let mut n = 0;
        for i in 0..cfg.n_experts {
            for j in (i + 1)..cfg.n_experts {
                acc += m[i][j].abs() as f64;
                n += 1;
            }
        }
        assert!(acc / (n as f64) < 0.2);
    }
}
