//! Offline profiling (paper §3.2): per-layer activation counts, pairwise
//! co-activation matrices (binary + probability-weighted), router trace
//! record/replay, and weight-space similarity analysis (Fig 4).

mod collector;
mod similarity;
mod traces;

pub use collector::{CoActivation, ProfileCollector};
pub use similarity::expert_similarity_matrix;
pub use traces::{RoutingEvent, RoutingTrace};
