//! Routing trace record/replay.
//!
//! Serving runs can record every routing decision; benches replay traces
//! through the substitution machinery deterministically (Table 1 and the
//! micro benches don't need the full model in the loop).

use anyhow::Result;

use crate::util::json::{arr_f32, arr_usize, num, obj, Json};

/// One token's routing at one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingEvent {
    pub layer: usize,
    /// Selected (top-k) experts, descending probability.
    pub selected: Vec<usize>,
    /// Renormalized top-k probabilities, aligned with `selected`.
    pub probs: Vec<f32>,
}

#[derive(Debug, Clone, Default)]
pub struct RoutingTrace {
    pub events: Vec<RoutingEvent>,
}

impl RoutingTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, layer: usize, selected: Vec<usize>, probs: Vec<f32>) {
        self.events.push(RoutingEvent { layer, selected, probs });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events for one layer.
    pub fn layer_events(&self, layer: usize) -> impl Iterator<Item = &RoutingEvent> {
        self.events.iter().filter(move |e| e.layer == layer)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    obj(vec![
                        ("layer", num(e.layer as f64)),
                        ("selected", arr_usize(&e.selected)),
                        ("probs", arr_f32(&e.probs)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut events = Vec::new();
        for e in j.as_arr()? {
            events.push(RoutingEvent {
                layer: e.get("layer")?.as_usize()?,
                selected: e.get("selected")?.as_usize_vec()?,
                probs: e.get("probs")?.as_f32_vec()?,
            });
        }
        Ok(Self { events })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_filter() {
        let mut t = RoutingTrace::new();
        t.push(0, vec![1, 2], vec![0.7, 0.3]);
        t.push(1, vec![0], vec![1.0]);
        t.push(0, vec![3, 1], vec![0.6, 0.4]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.layer_events(0).count(), 2);
        assert_eq!(t.layer_events(1).count(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = RoutingTrace::new();
        t.push(2, vec![5, 7, 1], vec![0.5, 0.3, 0.2]);
        let back = RoutingTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.events, t.events);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("bmw_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        let mut t = RoutingTrace::new();
        t.push(0, vec![1], vec![1.0]);
        t.save(&p).unwrap();
        let back = RoutingTrace::load(&p).unwrap();
        assert_eq!(back.events, t.events);
    }
}
