//! Report emitters: markdown tables for the bench output and
//! EXPERIMENTS.md.

use std::path::Path;

use anyhow::Result;

use crate::eval::harness::EvalOutcome;

/// Render outcomes as a markdown table matching the paper's columns.
pub fn markdown_table(title: &str, rows: &[EvalOutcome]) -> String {
    let mut out = format!("### {title}\n\n");
    out.push_str("| Method | ACC-E | ACC-C | Avg | tok/s | KL-E | KL-C | subs | fetches | pf-hit |\n");
    out.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} | {:.2} | {:.4} | {:.4} | {} | {} | {:.2} |\n",
            r.label,
            r.acc_easy,
            r.acc_hard,
            r.avg,
            r.tok_s,
            r.kl_easy,
            r.kl_hard,
            r.substitutions,
            r.fetches,
            r.prefetch_hit_rate,
        ));
    }
    out
}

pub fn write_report(path: &Path, content: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::PcieStats;

    #[test]
    fn renders_rows() {
        let rows = vec![EvalOutcome {
            label: "Original".into(),
            acc_easy: 1.0,
            acc_hard: 1.0,
            avg: 1.0,
            kl_easy: 0.0,
            kl_hard: 0.0,
            tok_s: 34.2,
            substitutions: 0,
            fetches: 10,
            pcie: PcieStats::default(),
            prefetch_hit_rate: 0.9,
            wall_s: 1.0,
        }];
        let md = markdown_table("Table 2 (c=0.75)", &rows);
        assert!(md.contains("Original"));
        assert!(md.contains("34.2"));
        assert!(md.lines().count() >= 4);
    }
}
