//! Table runner: profile → build buddy lists → serve each method preset on
//! the same workload → report accuracy (vs oracle) and throughput.
//!
//! This is the machinery behind Tables 2, 3, 4 and Figure 8.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::buddy::BuddyProfile;
use crate::config::{ModelConfig, ServingConfig};
use crate::eval::accuracy::{forced_agreement, mean_logit_kl};
use crate::eval::workload::{Domain, WorkloadGen};
use crate::memory::PcieStats;
use crate::model::{Engine, EngineOptions};
use crate::profilecollect::ProfileCollector;
use crate::server::{InferenceRequest, InferenceResponse, Server};
use crate::util::clock::ClockMode;
use crate::weights::WeightStore;

/// Workload shape shared by every method in one table.
#[derive(Debug, Clone)]
pub struct TableSettings {
    pub cache_rate: f64,
    pub n_easy: usize,
    pub n_hard: usize,
    pub max_new: usize,
    pub seed: u64,
    /// Time source for the served methods. `Virtual` (default) runs the
    /// whole sweep on the simulated timeline — milliseconds of wall time,
    /// byte-identical reports per seed; `RealTime` measures genuine
    /// elapsed time (PCIe stalls really sleep).
    pub clock: ClockMode,
}

impl Default for TableSettings {
    fn default() -> Self {
        Self {
            cache_rate: 0.75,
            n_easy: 8,
            n_hard: 8,
            max_new: 16,
            seed: 42,
            clock: ClockMode::Virtual,
        }
    }
}

/// One table row: a named serving configuration.
#[derive(Debug, Clone)]
pub struct MethodSpec {
    pub label: String,
    /// `ServingConfig::preset` name.
    pub preset: String,
}

impl MethodSpec {
    pub fn new(label: &str, preset: &str) -> Self {
        Self { label: label.into(), preset: preset.into() }
    }
}

/// Everything measured for one method. `wall_s`/`tok_s` are measured on
/// the run's clock: virtual seconds under `ClockMode::Virtual` (and then
/// exactly reproducible per seed), real seconds under `RealTime`.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalOutcome {
    pub label: String,
    pub acc_easy: f64,
    pub acc_hard: f64,
    pub avg: f64,
    pub kl_easy: f64,
    pub kl_hard: f64,
    pub tok_s: f64,
    pub substitutions: u64,
    pub fetches: u64,
    pub pcie: PcieStats,
    pub prefetch_hit_rate: f64,
    pub wall_s: f64,
}

/// Deterministic request mix: easy ids in [0, n_easy), hard ids >= 1000.
pub fn build_requests(cfg: &ModelConfig, st: &TableSettings) -> Vec<InferenceRequest> {
    let mut gen = WorkloadGen::new(cfg, st.seed);
    gen.max_new = st.max_new;
    let mut reqs = gen.requests(Domain::Easy, st.n_easy, 0);
    reqs.extend(gen.requests(Domain::Hard, st.n_hard, 1000));
    // Interleave easy/hard so batches mix domains (as a real queue would).
    let mut inter = Vec::with_capacity(reqs.len());
    for i in 0..st.n_easy.max(st.n_hard) {
        if i < st.n_easy {
            inter.push(reqs[i].clone());
        }
        if i < st.n_hard {
            inter.push(reqs[st.n_easy + i].clone());
        }
    }
    inter
}

/// The artifact model at `dir` when built; otherwise the synthetic family
/// model (`ModelConfig::synthetic_small` + `WeightStore::synthetic_families`
/// seeded with `seed`) — the single artifacts-or-synthetic fallback shared
/// by benches, examples, and integration tests.
pub fn load_model_or_synthetic(
    dir: &std::path::Path,
    seed: u64,
) -> Result<(ModelConfig, Arc<WeightStore>)> {
    if dir.join("model_config.json").exists() {
        let cfg = ModelConfig::load(dir)?;
        let store = Arc::new(WeightStore::load(&cfg)?);
        Ok((cfg, store))
    } else {
        log::info!("artifacts not built — using synthetic family weights (seed {seed})");
        let cfg = ModelConfig::synthetic_small();
        let store = Arc::new(WeightStore::synthetic_families(&cfg, seed));
        Ok((cfg, store))
    }
}

/// Run the profiling corpus through a full-residency engine and collect
/// co-activation statistics (the offline phase; held-out seed).
pub fn profile_model(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    n_prompts: usize,
    seed: u64,
) -> Result<ProfileCollector> {
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: crate::config::MissPolicy::OnDemand,
        prefetch: crate::config::PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        collect_profile: true,
        ..Default::default()
    };
    let engine = Engine::new(cfg.clone(), scfg, store, None, None, opts)?;
    let mut server = Server::new(engine);
    let mut gen = WorkloadGen::new(cfg, seed);
    let reqs = gen.requests(Domain::Mixed, n_prompts, 0);
    server.run_offline(reqs)?;
    let pc = server
        .engine
        .profile_out
        .take()
        .context("profiling was not enabled")?;
    server.engine.shutdown();
    Ok(pc)
}

/// Expert rank per layer by profiled activation count (cache warm-up +
/// TopFreq predictor input).
pub fn warm_rank_from_profile(pc: &ProfileCollector) -> Vec<Vec<usize>> {
    (0..pc.n_layers())
        .map(|l| {
            let acts = &pc.layer(l).activations;
            let mut idx: Vec<usize> = (0..acts.len()).collect();
            // total_cmp: a NaN activation (e.g. a poisoned profile) ranks
            // deterministically instead of panicking the sort.
            idx.sort_by(|&a, &b| acts[b].total_cmp(&acts[a]).then(a.cmp(&b)));
            idx
        })
        .collect()
}

/// Oracle generations: the lossless reference for accuracy scoring.
pub fn oracle_run(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    requests: Vec<InferenceRequest>,
) -> Result<Vec<InferenceResponse>> {
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: crate::config::MissPolicy::OnDemand,
        prefetch: crate::config::PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        record_logits: true,
        ..Default::default()
    };
    let engine = Engine::new(cfg.clone(), scfg, store, None, None, opts)?;
    let mut server = Server::new(engine);
    let out = server.run_offline(requests)?;
    server.engine.shutdown();
    Ok(out)
}

fn by_domain(responses: &[InferenceResponse]) -> (Vec<&InferenceResponse>, Vec<&InferenceResponse>) {
    let mut easy: Vec<&InferenceResponse> = responses.iter().filter(|r| r.id < 1000).collect();
    let mut hard: Vec<&InferenceResponse> = responses.iter().filter(|r| r.id >= 1000).collect();
    easy.sort_by_key(|r| r.id);
    hard.sort_by_key(|r| r.id);
    (easy, hard)
}

/// Build a serving engine from a fully-resolved `ServingConfig`: buddy
/// lists are rebuilt from the profile with the config's α / K_max (they
/// differ across method rows), warm-rank seeds the cache. Shared by the
/// table runner, the bandwidth sweep, and the traffic load sweep.
pub fn engine_with_config(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    scfg: ServingConfig,
    opts: EngineOptions,
) -> Result<Engine> {
    let alphas = vec![scfg.cft_alpha; cfg.n_layers];
    let profile = BuddyProfile::build(collector, &alphas, scfg.k_max, 1e-3, true)?;
    Engine::new(
        cfg.clone(),
        scfg,
        store,
        Some(profile),
        Some(warm_rank.to_vec()),
        opts,
    )
}

/// Serve one method configuration and score it against the oracle.
#[allow(clippy::too_many_arguments)]
pub fn run_method(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    collector: &ProfileCollector,
    warm_rank: &[Vec<usize>],
    spec: &MethodSpec,
    base: &ServingConfig,
    settings: &TableSettings,
    oracle: &[InferenceResponse],
) -> Result<EvalOutcome> {
    let mut scfg = base.clone().preset(&spec.preset)?;
    scfg.cache_rate = settings.cache_rate;
    scfg.seed = settings.seed;

    let opts = EngineOptions {
        clock: settings.clock,
        record_logits: true,
        ..Default::default()
    };
    let engine = engine_with_config(cfg, store, collector, warm_rank, scfg, opts)?;
    let mut server = Server::new(engine);
    // Teacher-force every request to the oracle's token stream so each
    // position is scored independently (see accuracy.rs). The compute path
    // is identical to free-running decode, so throughput is unaffected.
    let mut requests = build_requests(cfg, settings);
    for req in requests.iter_mut() {
        let o = oracle
            .iter()
            .find(|r| r.id == req.id)
            .context("oracle response missing for request")?;
        req.force_tokens = Some(o.predictions.clone());
    }
    let clock = server.engine.clock();
    let t0 = clock.now();
    let responses = server.run_offline(requests)?;
    let wall_s = clock.since(t0);

    let (o_easy, o_hard) = by_domain(oracle);
    let (s_easy, s_hard) = by_domain(&responses);
    let logs = |rs: &[&InferenceResponse]| rs.iter().map(|r| r.logits.clone()).collect::<Vec<_>>();

    let acc_easy = forced_agreement(&o_easy, &s_easy);
    let acc_hard = forced_agreement(&o_hard, &s_hard);
    let kl_easy = mean_logit_kl(&logs(&o_easy), &logs(&s_easy));
    let kl_hard = mean_logit_kl(&logs(&o_hard), &logs(&s_hard));

    let pcie = server.engine.transfer_handle().with_state(|st| st.pcie_stats());
    let outcome = EvalOutcome {
        label: spec.label.clone(),
        acc_easy,
        acc_hard,
        avg: 0.5 * (acc_easy + acc_hard),
        kl_easy,
        kl_hard,
        tok_s: if wall_s > 0.0 {
            server.metrics.tokens_out as f64 / wall_s
        } else {
            0.0
        },
        substitutions: server.engine.counters.get("substitutions"),
        fetches: server.engine.counters.get("fetches"),
        pcie,
        prefetch_hit_rate: server
            .engine
            .prefetch_counters()
            .ratio("prefetch_useful", "prefetch_issued"),
        wall_s,
    };
    server.engine.shutdown();
    Ok(outcome)
}

/// Full table driver: profile -> oracle -> every method row. Returns the
/// outcome rows plus a rendered markdown table.
pub fn run_table(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    settings: &TableSettings,
    methods: &[MethodSpec],
) -> Result<(Vec<EvalOutcome>, String)> {
    log::info!("profiling (held-out corpus)...");
    let pc = profile_model(cfg, store.clone(), 64, 7777)?;
    let warm = warm_rank_from_profile(&pc);
    log::info!("oracle run...");
    let oracle = oracle_run(cfg, store.clone(), build_requests(cfg, settings))?;
    let base = ServingConfig::default();
    let mut rows = Vec::new();
    for m in methods {
        log::info!("method {} ...", m.label);
        let row = run_method(cfg, store.clone(), &pc, &warm, m, &base, settings, &oracle)?;
        log::info!(
            "  acc {:.3}/{:.3} tok/s {:.2} subs {} fetches {}",
            row.acc_easy,
            row.acc_hard,
            row.tok_s,
            row.substitutions,
            row.fetches
        );
        rows.push(row);
    }
    let md = crate::eval::report::markdown_table(
        &format!("cache rate c = {}", settings.cache_rate),
        &rows,
    );
    Ok((rows, md))
}

/// The method grid a paper table sweeps (Tables 2–4 share this shape).
pub fn table_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::new("Original (on-demand)", "original"),
        MethodSpec::new("Random", "random"),
        MethodSpec::new("BuddyMoE t=0.75 |B|=4", "buddy-tight"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16", "buddy-wide"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=3", "buddy-rho3"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=4", "buddy-rho4"),
    ]
}
