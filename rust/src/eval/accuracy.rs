//! Oracle-agreement accuracy: the ARC-score analogue (DESIGN.md §3).
//!
//! Accuracy of a served configuration = fraction of decode positions whose
//! argmax token matches the *lossless oracle* (full GPU residency, no
//! substitution) on the same prompts. The paper's ARC scores measure the
//! same quantity — how much the serving approximation perturbs the model
//! relative to the lossless baseline — on a natural-language benchmark we
//! cannot run offline.

use crate::server::InferenceResponse;
use crate::util::math::{kl_divergence, softmax};

/// Near-tie tolerance on oracle logits: a served prediction counts as a
/// match if the oracle scored it within this logit gap of its own argmax.
/// PJRT-CPU reductions are not bitwise deterministic run-to-run, so exact
/// equality would punish ±ulp flips that carry no information.
pub const TIE_EPS: f32 = 1e-3;

/// Teacher-forced per-position agreement (the ARC-score analogue).
///
/// Both runs must be over the same prompts with the served run forced to
/// the oracle's token stream, so position i is scored under the identical
/// context — one near-tie flip cannot poison the continuation.
pub fn forced_agreement(oracle: &[&InferenceResponse], served: &[&InferenceResponse]) -> f64 {
    assert_eq!(oracle.len(), served.len(), "response count mismatch");
    let mut matches = 0usize;
    let mut total = 0usize;
    for (o, s) in oracle.iter().zip(served) {
        assert_eq!(o.id, s.id, "response alignment broken");
        let n = o.predictions.len().min(s.predictions.len());
        for i in 0..n {
            total += 1;
            if o.predictions[i] == s.predictions[i] {
                matches += 1;
            } else if let Some(logits) = o.logits.get(i) {
                // Tolerate near-ties as judged by the oracle itself.
                let top = logits[o.predictions[i] as usize];
                let alt = logits[s.predictions[i] as usize];
                if top - alt < TIE_EPS {
                    matches += 1;
                }
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        matches as f64 / total as f64
    }
}

/// Token-level agreement between oracle and served generations.
pub fn agreement(oracle: &[Vec<i32>], served: &[Vec<i32>]) -> f64 {
    assert_eq!(oracle.len(), served.len(), "response count mismatch");
    let mut match_count = 0usize;
    let mut total = 0usize;
    for (o, s) in oracle.iter().zip(served) {
        assert_eq!(o.len(), s.len(), "generation length mismatch");
        total += o.len();
        match_count += o.iter().zip(s).filter(|(a, b)| a == b).count();
    }
    if total == 0 {
        return 1.0;
    }
    match_count as f64 / total as f64
}

/// Mean per-step KL(oracle || served) over softmaxed logits.
pub fn mean_logit_kl(oracle: &[Vec<Vec<f32>>], served: &[Vec<Vec<f32>>]) -> f64 {
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (ol, sl) in oracle.iter().zip(served) {
        for (o, s) in ol.iter().zip(sl) {
            let mut p = o.clone();
            let mut q = s.clone();
            softmax(&mut p);
            softmax(&mut q);
            total += kl_divergence(&p, &q);
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// Accuracy numbers for one (method, workload) cell.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    pub agreement: f64,
    pub mean_kl: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement() {
        let o = vec![vec![1, 2, 3], vec![4, 5]];
        assert_eq!(agreement(&o, &o), 1.0);
    }

    #[test]
    fn partial_agreement() {
        let o = vec![vec![1, 2, 3, 4]];
        let s = vec![vec![1, 9, 3, 9]];
        assert!((agreement(&o, &s) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_perfect() {
        assert_eq!(agreement(&[], &[]), 1.0);
    }

    #[test]
    fn kl_zero_for_identical_logits() {
        let l = vec![vec![vec![1.0f32, 2.0, 3.0]]];
        assert!(mean_logit_kl(&l, &l).abs() < 1e-9);
    }

    #[test]
    fn kl_positive_for_different() {
        let o = vec![vec![vec![5.0f32, 0.0, 0.0]]];
        let s = vec![vec![vec![0.0f32, 5.0, 0.0]]];
        assert!(mean_logit_kl(&o, &s) > 1.0);
    }
}
