//! Synthetic workloads standing in for the paper's ARC-Easy / ARC-Challenge
//! prompt sets (DESIGN.md §3):
//!
//! * `Easy`  — tokens from the lower vocab half: generic routing, mostly
//!   popular experts, cache-friendly.
//! * `Hard`  — tokens from the upper vocab half: weightgen aligned these
//!   embeddings with *unpopular* expert families, so routing hits the
//!   offloaded tail — more misses, more substitution pressure.

use crate::config::ModelConfig;
use crate::server::InferenceRequest;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Easy,
    Hard,
    Mixed,
}

impl Domain {
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Easy => "syn-e",
            Domain::Hard => "syn-c",
            Domain::Mixed => "mixed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub vocab_size: usize,
    pub prompt_len_lo: usize,
    pub prompt_len_hi: usize,
    pub max_new: usize,
    rng: Rng,
}

impl WorkloadGen {
    pub fn new(cfg: &ModelConfig, seed: u64) -> Self {
        Self {
            vocab_size: cfg.vocab_size,
            prompt_len_lo: 8,
            prompt_len_hi: 16,
            max_new: 16,
            rng: Rng::new(seed),
        }
    }

    /// One prompt from a domain (token 0 reserved as padding).
    pub fn prompt(&mut self, domain: Domain) -> Vec<i32> {
        let len = self.rng.range(self.prompt_len_lo, self.prompt_len_hi + 1);
        let half = self.vocab_size / 2;
        (0..len)
            .map(|_| {
                let d = match domain {
                    Domain::Mixed => {
                        if self.rng.bool(0.5) {
                            Domain::Easy
                        } else {
                            Domain::Hard
                        }
                    }
                    d => d,
                };
                match d {
                    Domain::Easy => self.rng.range(1, half) as i32,
                    Domain::Hard => self.rng.range(half, self.vocab_size) as i32,
                    Domain::Mixed => unreachable!(),
                }
            })
            .collect()
    }

    /// One request: a `domain` prompt with this generator's `max_new`.
    /// This is the request-body source the traffic subsystem's
    /// [`crate::traffic::PromptSource`] draws from.
    pub fn request(&mut self, domain: Domain, id: u64) -> InferenceRequest {
        InferenceRequest::new(id, self.prompt(domain), self.max_new)
    }

    /// A request batch: `n` prompts from `domain`, ids starting at `id0`.
    pub fn requests(&mut self, domain: Domain, n: usize, id0: u64) -> Vec<InferenceRequest> {
        (0..n).map(|i| self.request(domain, id0 + i as u64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn domains_split_vocab() {
        let cfg = ModelConfig::test_tiny();
        let mut g = WorkloadGen::new(&cfg, 1);
        for _ in 0..20 {
            for &t in &g.prompt(Domain::Easy) {
                assert!((1..(cfg.vocab_size / 2) as i32).contains(&t));
            }
            for &t in &g.prompt(Domain::Hard) {
                assert!(((cfg.vocab_size / 2) as i32..cfg.vocab_size as i32).contains(&t));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = ModelConfig::test_tiny();
        let mut a = WorkloadGen::new(&cfg, 5);
        let mut b = WorkloadGen::new(&cfg, 5);
        assert_eq!(a.prompt(Domain::Mixed), b.prompt(Domain::Mixed));
    }

    #[test]
    fn request_ids_sequential() {
        let cfg = ModelConfig::test_tiny();
        let mut g = WorkloadGen::new(&cfg, 2);
        let reqs = g.requests(Domain::Easy, 3, 10);
        assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![10, 11, 12]);
        assert!(reqs.iter().all(|r| r.max_new == g.max_new));
    }

    #[test]
    fn prompt_lengths_in_range() {
        let cfg = ModelConfig::test_tiny();
        let mut g = WorkloadGen::new(&cfg, 3);
        g.prompt_len_lo = 4;
        g.prompt_len_hi = 6;
        for _ in 0..10 {
            let p = g.prompt(Domain::Easy);
            assert!((4..=6).contains(&p.len()));
        }
    }
}
