//! Experiment harness: synthetic workloads, oracle-agreement accuracy, and
//! the table/figure runners that regenerate the paper's evaluation.

mod accuracy;
mod harness;
mod report;
mod workload;

pub use accuracy::{agreement, forced_agreement, mean_logit_kl, AccuracyReport, TIE_EPS};
pub use harness::{
    build_requests, engine_with_config, load_model_or_synthetic, oracle_run, profile_model,
    run_method, run_table, table_methods, warm_rank_from_profile, EvalOutcome, MethodSpec,
    TableSettings,
};
pub use report::{markdown_table, write_report};
pub use workload::{Domain, WorkloadGen};
