//! `forall(cfg, gen, check)` — run `check` over `cfg.cases` generated
//! inputs; panic with the reproducing (seed, case) on the first failure.
//!
//! No shrinking: generators here produce small cases by construction, and
//! the (seed, case index) pair pinpoints the exact counterexample.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 100, seed: 0xb0dd7 }
    }
}

/// Run a property. `gen` builds a case from the RNG; `check` returns
/// `Err(reason)` on violation.
pub fn forall<T, G, C>(cfg: PropConfig, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(reason) = check(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}):\n  reason: {reason}\n  input: {input:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            PropConfig { cases: 50, seed: 1 },
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_case_info() {
        forall(
            PropConfig { cases: 50, seed: 2 },
            |rng| rng.below(10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 5"))
                }
            },
        );
    }

    #[test]
    fn deterministic_cases() {
        let mut seen_a = Vec::new();
        forall(
            PropConfig { cases: 5, seed: 3 },
            |rng| rng.next_u64(),
            |&x| {
                seen_a.push(x);
                Ok(())
            },
        );
        let mut seen_b = Vec::new();
        forall(
            PropConfig { cases: 5, seed: 3 },
            |rng| rng.next_u64(),
            |&x| {
                seen_b.push(x);
                Ok(())
            },
        );
        assert_eq!(seen_a, seen_b);
    }
}
