//! In-tree property-testing mini-framework (proptest is unavailable
//! offline). Deterministic case generation from a seed, failure reporting
//! with the case index + seed so any counterexample reproduces exactly.

pub mod prop;

pub use prop::{forall, PropConfig};
