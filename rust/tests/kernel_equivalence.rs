//! The tentpole determinism contract: the blocked/parallel reference
//! kernels are bit-for-bit equal to the naive forms across random shapes
//! and thread counts; full stages agree between kernel modes; the golden
//! virtual-clock sweep is byte-identical at PALLAS_THREADS=1 and =4; and
//! expert admission/lookup is zero-copy (`Arc::ptr_eq`).

use std::sync::{Arc, Mutex};

use buddymoe::config::ModelConfig;
use buddymoe::eval::{run_table, MethodSpec, TableSettings};
use buddymoe::runtime::kernels::{self, naive};
use buddymoe::runtime::{KernelMode, KvSlices, RefStages, StageRunner};
use buddymoe::testing::{forall, PropConfig};
use buddymoe::util::clock::ClockMode;
use buddymoe::util::par;
use buddymoe::util::rng::Rng;
use buddymoe::util::tensor::{Tensor, TensorView};
use buddymoe::weights::{ExpertKey, WeightStore};

/// `par::set_threads` is a process-global override and the test harness
/// runs tests concurrently; serialize every test that drives it so each
/// one really executes at the thread counts it claims to exercise.
static PAR_LOCK: Mutex<()> = Mutex::new(());

fn par_lock() -> std::sync::MutexGuard<'static, ()> {
    PAR_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Random values with exact zeros mixed in (the matmul zero-skip path).
fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| if rng.bool(0.1) { 0.0 } else { (rng.f32() - 0.5) * 4.0 })
        .collect()
}

fn first_diff(a: &[f32], b: &[f32]) -> Option<usize> {
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}

#[test]
fn prop_blocked_matmul_bitwise_matches_naive() {
    let _serialize = par_lock();
    forall(
        PropConfig { cases: 120, seed: 61 },
        |rng| {
            // Shapes crossing both the TILE_I (4-row) and TILE_J (128-col)
            // boundaries, at 1..4 threads.
            let m = rng.range(1, 18);
            let k = rng.range(1, 70);
            let n = rng.range(1, 300);
            let a = randv(rng, m * k);
            let b = randv(rng, k * n);
            let threads = rng.range(1, 5);
            (m, k, n, a, b, threads)
        },
        |(m, k, n, a, b, threads)| {
            par::set_threads(*threads);
            let want = naive::matmul(a, *m, *k, b, *n);
            let got = kernels::matmul(a, *m, *k, b, *n);
            par::set_threads(0);
            match first_diff(&got, &want) {
                None => Ok(()),
                Some(i) => Err(format!(
                    "[{m}x{k}]@[{k}x{n}] t={threads}: first bit diff at {i}: {} vs {}",
                    got[i], want[i]
                )),
            }
        },
    );
}

#[test]
fn prop_blocked_matmul_bt_bitwise_matches_naive() {
    let _serialize = par_lock();
    forall(
        PropConfig { cases: 100, seed: 62 },
        |rng| {
            let m = rng.range(1, 10);
            let k = rng.range(1, 70);
            let n = rng.range(1, 400);
            let a = randv(rng, m * k);
            let bt = randv(rng, n * k);
            let threads = rng.range(1, 5);
            (m, k, n, a, bt, threads)
        },
        |(m, k, n, a, bt, threads)| {
            par::set_threads(*threads);
            let want = naive::matmul_bt(a, *m, *k, bt, *n);
            let got = kernels::matmul_bt(a, *m, *k, bt, *n);
            par::set_threads(0);
            match first_diff(&got, &want) {
                None => Ok(()),
                Some(i) => Err(format!(
                    "bt [{m}x{k}]@[{n}x{k}]^T t={threads}: first bit diff at {i}: {} vs {}",
                    got[i], want[i]
                )),
            }
        },
    );
}

#[test]
fn prop_blocked_rms_norm_bitwise_matches_naive() {
    let _serialize = par_lock();
    forall(
        PropConfig { cases: 100, seed: 63 },
        |rng| {
            let rows = rng.range(1, 40);
            let d = rng.range(1, 80);
            let x = randv(rng, rows * d);
            let gain: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0).collect();
            let threads = rng.range(1, 5);
            (rows, d, x, gain, threads)
        },
        |(rows, d, x, gain, threads)| {
            par::set_threads(*threads);
            let want = naive::rms_norm_rows(x, *rows, *d, gain, 1e-5);
            let got = kernels::rms_norm_rows(x, *rows, *d, gain, 1e-5);
            par::set_threads(0);
            match first_diff(&got, &want) {
                None => Ok(()),
                Some(i) => Err(format!(
                    "rms [{rows}x{d}] t={threads}: first bit diff at {i}: {} vs {}",
                    got[i], want[i]
                )),
            }
        },
    );
}

/// Every stage of the reference backend agrees bit-for-bit between the
/// naive and blocked kernel modes, at several thread counts. Sized above
/// the fan-out work threshold so the parallel code paths really run.
#[test]
fn stages_bitwise_equal_across_modes_and_threads() {
    let _serialize = par_lock();
    let mut cfg = ModelConfig::synthetic_small();
    cfg.d_model = 128;
    cfg.n_heads = 4;
    cfg.head_dim = 32;
    cfg.d_ff = 256;
    cfg.vocab_size = 512;
    cfg.max_seq = 64;
    cfg.token_buckets = vec![1, 2, 4, 8, 16, 32, 64];
    cfg.batch_buckets = vec![1, 2, 4, 8];
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 31));
    let naive_st = RefStages::with_mode(cfg.clone(), store.clone(), KernelMode::Naive);
    let blocked = RefStages::with_mode(cfg.clone(), store.clone(), KernelMode::Blocked);
    assert_eq!(naive_st.kernel_mode(), KernelMode::Naive);
    assert_eq!(blocked.kernel_mode(), KernelMode::Blocked);
    let d = cfg.d_model;
    let mut rng = Rng::new(5);
    let mut rv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f32() - 0.5).collect() };

    for &threads in &[1usize, 2, 4] {
        par::set_threads(threads);

        // Prefill attention (causal + length mask).
        let s = cfg.max_seq;
        let x = Tensor::new(vec![s, d], rv(s * d)).unwrap();
        let mut mask = vec![1.0f32; s];
        for m in mask.iter_mut().skip(s - 5) {
            *m = 0.0;
        }
        let mask = Tensor::new(vec![s], mask).unwrap();
        let [ya, ka, va] = naive_st.attn_prefill(0, &x, &mask).unwrap();
        let [yb, kb, vb] = blocked.attn_prefill(0, &x, &mask).unwrap();
        assert_eq!(ya.data, yb.data, "prefill y, threads={threads}");
        assert_eq!(ka.data, kb.data, "prefill k, threads={threads}");
        assert_eq!(va.data, vb.data, "prefill v, threads={threads}");

        // Decode attention (cached window + current token), reading the
        // per-sequence caches through the borrowed view.
        let bb = 4;
        let xd = Tensor::new(vec![bb, d], rv(bb * d)).unwrap();
        let kcs: Vec<Tensor> =
            (0..bb).map(|_| Tensor::new(vec![s, d], rv(s * d)).unwrap()).collect();
        let vcs: Vec<Tensor> =
            (0..bb).map(|_| Tensor::new(vec![s, d], rv(s * d)).unwrap()).collect();
        let kr: Vec<&Tensor> = kcs.iter().collect();
        let vr: Vec<&Tensor> = vcs.iter().collect();
        let kv = KvSlices { k: &kr, v: &vr };
        let pm = Tensor::new(
            vec![bb, s],
            (0..bb * s).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect(),
        )
        .unwrap();
        let [ya, ka, va] = naive_st.attn_decode(1, bb, &xd, &kv, &pm).unwrap();
        let [yb, kb, vb] = blocked.attn_decode(1, bb, &xd, &kv, &pm).unwrap();
        assert_eq!(ya.data, yb.data, "decode y, threads={threads}");
        assert_eq!(ka.data, kb.data, "decode k_new, threads={threads}");
        assert_eq!(va.data, vb.data, "decode v_new, threads={threads}");

        // Router.
        let t = 6;
        let y = Tensor::new(vec![t, d], rv(t * d)).unwrap();
        let (ha, pa) = naive_st.router(2, &y).unwrap();
        let (hb, pb) = blocked.router(2, &y).unwrap();
        assert_eq!(ha.data, hb.data, "router h, threads={threads}");
        assert_eq!(pa.data, pb.data, "router probs, threads={threads}");

        // Expert FFN (borrowed-view input).
        let w = store.expert(ExpertKey::new(0, 1)).unwrap();
        let h = Tensor::new(vec![t, d], rv(t * d)).unwrap();
        let hv = TensorView::from_tensor(&h);
        let ea = naive_st.expert_transient(t, &w, &hv).unwrap();
        let eb = blocked.expert_transient(t, &w, &hv).unwrap();
        assert_eq!(ea.data, eb.data, "expert ffn, threads={threads}");

        // LM head.
        let xl = Tensor::new(vec![t, d], rv(t * d)).unwrap();
        let la = naive_st.lm_head(t, &xl).unwrap();
        let lb = blocked.lm_head(t, &xl).unwrap();
        assert_eq!(la.data, lb.data, "lm head, threads={threads}");
    }
    par::set_threads(0);
}

/// The golden determinism contract extended to threading: a table sweep at
/// 1 thread and at 4 threads must produce identical outcome rows and
/// byte-identical markdown.
#[test]
fn golden_sweep_identical_across_thread_counts() {
    let _serialize = par_lock();
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 99));
    let settings = TableSettings {
        cache_rate: 0.75,
        n_easy: 2,
        n_hard: 2,
        max_new: 4,
        seed: 42,
        clock: ClockMode::Virtual,
    };
    let methods = vec![
        MethodSpec::new("Original (on-demand)", "original"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=3", "buddy-rho3"),
    ];
    par::set_threads(1);
    let (rows_1, md_1) = run_table(&cfg, store.clone(), &settings, &methods).expect("1-thread");
    par::set_threads(4);
    let (rows_4, md_4) = run_table(&cfg, store, &settings, &methods).expect("4-thread");
    par::set_threads(0);
    assert_eq!(rows_1, rows_4, "PALLAS_THREADS must never change an outcome");
    assert_eq!(md_1, md_4, "reports must be byte-identical across thread counts");
}

/// Zero-copy contract: admission shares the store's Arc allocation, and
/// running a resident expert adds no refcount traffic (it borrows).
#[test]
fn expert_residency_is_zero_copy() {
    let cfg = ModelConfig::test_tiny();
    let store = Arc::new(WeightStore::synthetic(&cfg, 7));
    let mut stages = RefStages::with_mode(cfg.clone(), store.clone(), KernelMode::Blocked);
    let key = ExpertKey::new(0, 3);
    let w = store.expert(key).unwrap();
    stages.admit_expert(key, &w).unwrap();

    let resident = stages.resident_weights(key).expect("admitted");
    assert!(
        Arc::ptr_eq(resident, &w),
        "admit_expert must be a pointer bump, not a 3x(d x d_ff) copy"
    );
    assert!(
        Arc::ptr_eq(resident, &store.expert(key).unwrap()),
        "the resident entry must alias the store's own allocation"
    );

    // store + local `w` + resident map = 3 strong refs; running the
    // expert must not add or copy anything.
    assert_eq!(Arc::strong_count(&w), 3);
    let h = Tensor::zeros(vec![2, cfg.d_model]);
    let _ = stages.expert_resident(2, key, &TensorView::from_tensor(&h)).unwrap();
    assert_eq!(
        Arc::strong_count(&w),
        3,
        "expert_resident must borrow the resident weights, not clone them"
    );
}
