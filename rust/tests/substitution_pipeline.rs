//! Integration tests of the substitution machinery over the full
//! profile -> CFT -> gates -> Algorithm 1 pipeline (no PJRT involved).

use buddymoe::buddy::{BuddyProfile, SlotDecision, SubstitutionEngine, TokenRouting};
use buddymoe::config::MissPolicy;
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::stats::Counters;
use buddymoe::util::rng::Rng;

const E: usize = 16;
const FAM: usize = 4;

/// Family-structured profile over 16 experts (families of 4).
fn family_profile(seed: u64) -> ProfileCollector {
    let mut pc = ProfileCollector::new(2, E);
    let mut rng = Rng::new(seed);
    for _ in 0..5000 {
        let layer = rng.below(2);
        let fam = rng.below(E / FAM);
        let a = fam * FAM + rng.below(FAM);
        let mut b = fam * FAM + rng.below(FAM);
        if rng.bool(0.1) {
            b = rng.below(E); // occasional cross-family noise
        }
        if a != b {
            pc.record(layer, &[a, b], &[0.55, 0.45]).unwrap();
        }
    }
    pc
}

#[test]
fn cft_lists_are_family_dominated() {
    let pc = family_profile(1);
    let profile = BuddyProfile::build(&pc, &[0.8, 0.8], 8, 1e-3, true).unwrap();
    let mut same_family_top1 = 0;
    for pivot in 0..E {
        let list = profile.list(0, pivot);
        assert!(!list.is_empty());
        if list.ranked[0].0 / FAM == pivot / FAM {
            same_family_top1 += 1;
        }
    }
    assert!(
        same_family_top1 >= E * 3 / 4,
        "top-1 buddy should be same-family for most pivots, got {same_family_top1}/{E}"
    );
}

#[test]
fn alpha_monotone_in_list_size() {
    let pc = family_profile(2);
    let small = BuddyProfile::build(&pc, &[0.3, 0.3], 16, 1e-3, true).unwrap();
    let large = BuddyProfile::build(&pc, &[0.95, 0.95], 16, 1e-3, true).unwrap();
    for pivot in 0..E {
        assert!(
            small.list(0, pivot).len() <= large.list(0, pivot).len(),
            "CFT prefix must grow with alpha"
        );
    }
}

#[test]
fn substitution_prefers_family_under_full_pipeline() {
    let pc = family_profile(3);
    let profile = BuddyProfile::build(&pc, &[0.9, 0.9], 8, 1e-3, true).unwrap();
    let mut eng = SubstitutionEngine::new(&profile);
    eng.gates.tau = 0.3;
    eng.gates.beta = 0.9;
    // Families 0,1 resident; families 2,3 offloaded.
    let residency: Vec<bool> = (0..E).map(|e| e / FAM < 2).collect();
    let mut counters = Counters::new();
    let mut rng = Rng::new(4);
    // Tokens that want offloaded experts 8..16 but also one resident.
    let mut toks: Vec<TokenRouting> = (0..6)
        .map(|i| TokenRouting {
            selected: vec![8 + (i % 8), 0, 1],
            weights: vec![0.4, 0.3, 0.3],
        })
        .collect();
    let (decisions, events) = eng.apply(
        0,
        &mut toks,
        &residency,
        MissPolicy::Buddy,
        None,
        &mut counters,
        &mut rng,
    );
    // Every substituted slot now points at a resident expert.
    for (tok, dec) in toks.iter().zip(&decisions) {
        for (slot, d) in dec.iter().enumerate() {
            if matches!(d, SlotDecision::Substitute { .. }) {
                assert!(residency[tok.selected[slot]]);
            }
        }
    }
    // All events stay within the buddy search rank.
    for ev in &events {
        assert!(ev.rank <= eng.search_h);
        assert!(residency[ev.to]);
        assert!(!residency[ev.from]);
    }
}

#[test]
fn policies_ordering_on_same_workload() {
    // Random substitutes everything it can, buddy is gated, on-demand never
    // substitutes: check the ordering of substitution counts.
    let pc = family_profile(5);
    let profile = BuddyProfile::build(&pc, &[0.9, 0.9], 8, 1e-3, true).unwrap();
    let residency: Vec<bool> = (0..E).map(|e| e % 2 == 0).collect();

    let count_subs = |policy: MissPolicy, tau: f64| {
        let mut eng = SubstitutionEngine::new(&profile);
        eng.gates.tau = tau;
        eng.gates.beta = 1.0;
        eng.rho = None;
        let mut counters = Counters::new();
        let mut rng = Rng::new(6);
        let mut toks: Vec<TokenRouting> = (0..8)
            .map(|i| TokenRouting {
                selected: vec![(2 * i + 1) % E, (2 * i) % E],
                // TAE([0.7, 0.3]) ~= 0.881: above tau=0.3, below tau=0.95.
                weights: vec![0.7, 0.3],
            })
            .collect();
        eng.apply(0, &mut toks, &residency, policy, None, &mut counters, &mut rng);
        counters.get("substitutions")
    };

    let on_demand = count_subs(MissPolicy::OnDemand, 0.3);
    let buddy = count_subs(MissPolicy::Buddy, 0.3);
    let buddy_strict = count_subs(MissPolicy::Buddy, 0.95);
    let random = count_subs(MissPolicy::Random, 0.3);
    assert_eq!(on_demand, 0);
    assert_eq!(buddy_strict, 0, "tau=0.95 forbids these tokens (TAE <= tau)");
    assert!(buddy > 0);
    assert!(random >= buddy, "random substitutes unconditionally");
}

#[test]
fn per_layer_alpha_schedule() {
    // Early layers broad (large alpha), late layers tight — the paper's
    // layer-wise heterogeneity calibration.
    let pc = family_profile(7);
    let profile = BuddyProfile::build(&pc, &[0.95, 0.4], 16, 1e-3, true).unwrap();
    let mean = |l: usize| {
        let s = profile.list_sizes(l);
        s.iter().sum::<usize>() as f64 / s.len() as f64
    };
    assert!(
        mean(0) > mean(1),
        "alpha 0.95 layer should have longer lists than alpha 0.4 layer"
    );
}

#[test]
fn serialization_roundtrip_preserves_behaviour() {
    let pc = family_profile(8);
    let profile = BuddyProfile::build(&pc, &[0.8, 0.8], 8, 1e-3, true).unwrap();
    let dir = std::env::temp_dir().join("buddymoe_profile_rt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("p.json");
    profile.save(&path).unwrap();
    let back = BuddyProfile::load(&path).unwrap();
    for l in 0..2 {
        for p in 0..E {
            assert_eq!(profile.list(l, p), back.list(l, p));
        }
    }
}
