//! Property-based tests over coordinator invariants (in-tree prop
//! framework; see rust/src/testing/prop.rs).

use buddymoe::buddy::{BuddyProfile, SlotDecision, SubstitutionEngine, TokenRouting};
use buddymoe::config::MissPolicy;
use buddymoe::memory::{EvictPolicy, ExpertCache, LoadDecision, SlotState};
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::stats::Counters;
use buddymoe::testing::{forall, PropConfig};
use buddymoe::util::math::{softmax, tae, top_k};
use buddymoe::util::rng::Rng;
use buddymoe::weights::ExpertKey;

// ---------------------------------------------------------------------
// math invariants
// ---------------------------------------------------------------------

#[test]
fn prop_softmax_is_distribution() {
    forall(
        PropConfig { cases: 200, seed: 11 },
        |rng| {
            let n = rng.range(1, 65);
            (0..n).map(|_| (rng.f32() - 0.5) * 40.0).collect::<Vec<f32>>()
        },
        |xs| {
            let mut p = xs.clone();
            softmax(&mut p);
            let sum: f32 = p.iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("sum {sum}"));
            }
            if p.iter().any(|&x| !(0.0..=1.0 + 1e-6).contains(&x)) {
                return Err("probability out of range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_top_k_selects_maximal_mass() {
    forall(
        PropConfig { cases: 200, seed: 12 },
        |rng| {
            let n = rng.range(2, 64);
            let k = rng.range(1, n);
            let mut p: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            softmax(&mut p);
            (p, k)
        },
        |(p, k)| {
            let (idx, w) = top_k(p, *k);
            if idx.len() != *k {
                return Err("wrong k".into());
            }
            // Every non-selected prob <= every selected prob.
            let min_sel = idx.iter().map(|&i| p[i]).fold(f32::INFINITY, f32::min);
            for (i, &pi) in p.iter().enumerate() {
                if !idx.contains(&i) && pi > min_sel + 1e-7 {
                    return Err(format!("expert {i} ({pi}) beats selected ({min_sel})"));
                }
            }
            let sum: f32 = w.iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("weights sum {sum}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tae_bounded_and_normalized() {
    forall(
        PropConfig { cases: 300, seed: 13 },
        |rng| {
            let k = rng.range(2, 9);
            let mut w: Vec<f32> = (0..k).map(|_| rng.f32() + 1e-6).collect();
            let s: f32 = w.iter().sum();
            for x in w.iter_mut() {
                *x /= s;
            }
            w
        },
        |w| {
            let t = tae(w);
            if !(0.0..=1.0 + 1e-5).contains(&t) {
                return Err(format!("TAE {t} out of [0,1]"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// cache invariants
// ---------------------------------------------------------------------

#[test]
fn prop_cache_never_exceeds_capacity() {
    forall(
        PropConfig { cases: 60, seed: 21 },
        |rng| {
            let cap = rng.range(1, 5);
            let ops: Vec<(usize, usize)> = (0..200)
                .map(|_| (rng.below(3), rng.below(8)))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let mut cache = ExpertCache::new(2, 8, *cap, EvictPolicy::Lru);
            for &(op, e) in ops {
                let k = ExpertKey::new(e % 2, e);
                match op {
                    0 => {
                        if let LoadDecision::StartLoad { .. } = cache.request_load(k) {
                            cache.complete_load(k);
                        }
                    }
                    1 => cache.mark_use(k),
                    _ => {
                        let _ = cache.request_load(k);
                    }
                }
                for layer in 0..2 {
                    if cache.gpu_count(layer) > *cap {
                        return Err(format!(
                            "layer {layer} holds {} > cap {cap}",
                            cache.gpu_count(layer)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_residency_mask_consistent() {
    forall(
        PropConfig { cases: 60, seed: 22 },
        |rng| (0..40).map(|_| rng.below(6)).collect::<Vec<usize>>(),
        |admits| {
            let mut cache = ExpertCache::new(1, 6, 3, EvictPolicy::Lfu);
            for &e in admits {
                let k = ExpertKey::new(0, e);
                if let LoadDecision::StartLoad { .. } = cache.request_load(k) {
                    cache.complete_load(k);
                }
            }
            let mask = cache.residency_mask(0);
            for (e, &m) in mask.iter().enumerate() {
                if m != cache.is_gpu(ExpertKey::new(0, e)) {
                    return Err("mask mismatch".into());
                }
            }
            if mask.iter().filter(|&&m| m).count() != cache.gpu_count(0) {
                return Err("count mismatch".into());
            }
            Ok(())
        },
    );
}

/// Shadow model for the cache state machine: what SlotState should be,
/// given only the legal transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ModelState {
    Cpu,
    Loading,
    Gpu,
}

#[test]
fn prop_cache_capacity_counts_loading_slots() {
    // The layer budget covers GPU-resident *and* in-flight experts: a
    // `Loading` slot owns real GPU memory the moment the transfer starts.
    forall(
        PropConfig { cases: 80, seed: 23 },
        |rng| {
            let cap = rng.range(1, 5);
            // op: 0 = request_load, 1 = complete a random loading expert,
            // 2 = abort a random loading expert, 3 = mark_use.
            let ops: Vec<(usize, usize)> = (0..300)
                .map(|_| (rng.below(4), rng.below(8)))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let mut cache = ExpertCache::new(2, 8, *cap, EvictPolicy::Lru);
            for &(op, e) in ops {
                let k = ExpertKey::new(e % 2, e);
                match op {
                    0 => {
                        let _ = cache.request_load(k);
                    }
                    1 => {
                        if cache.state(k) == SlotState::Loading {
                            cache.complete_load(k);
                        }
                    }
                    2 => cache.abort_load(k),
                    _ => cache.mark_use(k),
                }
                for layer in 0..2 {
                    let gpu = cache.gpu_count(layer);
                    let loading = (0..8)
                        .filter(|&ei| {
                            cache.state(ExpertKey::new(layer, ei)) == SlotState::Loading
                        })
                        .count();
                    if gpu + loading > *cap {
                        return Err(format!(
                            "layer {layer}: {gpu} gpu + {loading} loading > cap {cap}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pinned_experts_never_evicted() {
    forall(
        PropConfig { cases: 80, seed: 24 },
        |rng| {
            let n = 8;
            let cap = rng.range(2, 5);
            let pinned: Vec<usize> = (0..n).filter(|_| rng.bool(0.3)).collect();
            let loads: Vec<usize> = (0..60).map(|_| rng.below(n)).collect();
            (cap, pinned, loads)
        },
        |(cap, pinned, loads)| {
            let mut cache = ExpertCache::new(1, 8, *cap, EvictPolicy::Lru);
            // Admit + pin a subset (never more than the capacity).
            for (i, &e) in pinned.iter().take(*cap).enumerate() {
                let k = ExpertKey::new(0, e);
                cache.admit(k).map_err(|err| format!("admit {i}: {err}"))?;
                cache.pin(k);
            }
            let protected: Vec<usize> = pinned.iter().take(*cap).copied().collect();
            for &e in loads {
                let k = ExpertKey::new(0, e);
                if let LoadDecision::StartLoad { evicted } = cache.request_load(k) {
                    if let Some(v) = evicted {
                        if protected.contains(&v.expert) {
                            return Err(format!("evicted pinned expert {}", v.expert));
                        }
                    }
                    cache.complete_load(k);
                }
                // Pinned experts must still be resident.
                for &p in &protected {
                    if !cache.is_gpu(ExpertKey::new(0, p)) {
                        return Err(format!("pinned expert {p} left the GPU"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_victim_selection_respects_pins() {
    // Satellite contract: across every eviction policy, the victim chosen
    // for a full layer is always an unpinned GPU-resident slot, and NoRoom
    // is reported exactly when every resident slot is pinned.
    forall(
        PropConfig { cases: 150, seed: 26 },
        |rng| {
            let policy = match rng.below(3) {
                0 => EvictPolicy::Lru,
                1 => EvictPolicy::Lfu,
                _ => EvictPolicy::FreqLayer,
            };
            let cap = rng.range(1, 5);
            let layer = rng.below(2);
            let uses: Vec<usize> = (0..30).map(|_| rng.below(8)).collect();
            let pin_mask: Vec<bool> = (0..8).map(|_| rng.bool(0.4)).collect();
            (policy, cap, layer, uses, pin_mask)
        },
        |(policy, cap, layer, uses, pin_mask)| {
            let mut cache = ExpertCache::new(2, 8, *cap, *policy);
            // Fill the layer to capacity with experts 0..cap.
            for e in 0..*cap {
                cache
                    .admit(ExpertKey::new(*layer, e))
                    .map_err(|err| format!("admit {e}: {err}"))?;
            }
            // Random recency/frequency history for the policy to rank.
            for &u in uses {
                if u < *cap {
                    cache.mark_use(ExpertKey::new(*layer, u));
                }
            }
            let pinned: Vec<usize> = (0..*cap).filter(|&e| pin_mask[e]).collect();
            for &e in &pinned {
                cache.pin(ExpertKey::new(*layer, e));
            }
            // Expert 7 is never resident (cap <= 4): the full layer must
            // either evict a legal victim or report NoRoom.
            match cache.request_load(ExpertKey::new(*layer, 7)) {
                LoadDecision::StartLoad { evicted } => {
                    let v = evicted.ok_or("full layer must evict to start a load")?;
                    if v.layer != *layer {
                        return Err(format!("victim from layer {}", v.layer));
                    }
                    if v.expert >= *cap {
                        return Err(format!("victim {} was not GPU-resident", v.expert));
                    }
                    if pinned.contains(&v.expert) {
                        return Err(format!("evicted pinned expert {}", v.expert));
                    }
                    if pinned.len() == *cap {
                        return Err("expected NoRoom: every resident slot is pinned".into());
                    }
                }
                LoadDecision::NoRoom => {
                    if pinned.len() != *cap {
                        return Err("NoRoom despite an unpinned resident victim".into());
                    }
                }
                other => return Err(format!("unexpected decision {other:?}")),
            }
            Ok(())
        },
    );
}

#[test]
fn prop_load_state_machine_legality() {
    // Random op sequences against a shadow model: request_load /
    // complete_load / abort_load transitions must match the documented
    // state machine exactly, and decisions must agree with the model.
    forall(
        PropConfig { cases: 100, seed: 25 },
        |rng| {
            let cap = rng.range(1, 4);
            let ops: Vec<(usize, usize)> = (0..200)
                .map(|_| (rng.below(3), rng.below(6)))
                .collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let mut cache = ExpertCache::new(1, 6, *cap, EvictPolicy::Lru);
            let mut model = [ModelState::Cpu; 6];
            for &(op, e) in ops {
                let k = ExpertKey::new(0, e);
                match op {
                    0 => {
                        let dec = cache.request_load(k);
                        match (model[e], dec) {
                            (ModelState::Gpu, LoadDecision::AlreadyGpu) => {}
                            (ModelState::Loading, LoadDecision::AlreadyLoading) => {}
                            (ModelState::Cpu, LoadDecision::StartLoad { evicted }) => {
                                if let Some(v) = evicted {
                                    if model[v.expert] != ModelState::Gpu {
                                        return Err(format!(
                                            "evicted expert {} was not Gpu",
                                            v.expert
                                        ));
                                    }
                                    model[v.expert] = ModelState::Cpu;
                                }
                                model[e] = ModelState::Loading;
                            }
                            (ModelState::Cpu, LoadDecision::NoRoom) => {
                                // Legal only when no Gpu slot is evictable;
                                // with no pins that means the layer is full
                                // of Loading slots.
                                let gpu = model.iter().filter(|&&s| s == ModelState::Gpu).count();
                                if gpu != 0 {
                                    return Err("NoRoom despite evictable Gpu slot".into());
                                }
                            }
                            (m, d) => {
                                return Err(format!("model {m:?} but decision {d:?}"))
                            }
                        }
                    }
                    1 => {
                        // complete_load is only legal while Loading.
                        if model[e] == ModelState::Loading {
                            cache.complete_load(k);
                            model[e] = ModelState::Gpu;
                        }
                    }
                    _ => {
                        // abort_load: Loading -> Cpu, no-op otherwise.
                        cache.abort_load(k);
                        if model[e] == ModelState::Loading {
                            model[e] = ModelState::Cpu;
                        }
                    }
                }
                // Cache state must track the model everywhere.
                for (ei, &m) in model.iter().enumerate() {
                    let got = cache.state(ExpertKey::new(0, ei));
                    let want = match m {
                        ModelState::Cpu => SlotState::Cpu,
                        ModelState::Loading => SlotState::Loading,
                        ModelState::Gpu => SlotState::Gpu,
                    };
                    if got != want {
                        return Err(format!("expert {ei}: cache {got:?} != model {want:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Algorithm 1 invariants (the paper's correctness contract)
// ---------------------------------------------------------------------

struct SubCase {
    residency: Vec<bool>,
    tokens: Vec<TokenRouting>,
    rho: Option<usize>,
    h: usize,
    tau: f64,
    beta: f64,
}

impl std::fmt::Debug for SubCase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SubCase(res={:?}, toks={}, rho={:?}, h={}, tau={}, beta={})",
            self.residency,
            self.tokens.len(),
            self.rho,
            self.h,
            self.tau,
            self.beta
        )
    }
}

fn shared_profile() -> BuddyProfile {
    let mut pc = ProfileCollector::new(1, 12);
    let mut rng = Rng::new(99);
    for _ in 0..4000 {
        let a = rng.below(12);
        let b = rng.below(12);
        if a != b {
            pc.record(0, &[a, b], &[0.6, 0.4]).unwrap();
        }
    }
    BuddyProfile::build(&pc, &[1.0], 12, 1e-3, true).unwrap()
}

#[test]
fn prop_algorithm1_invariants() {
    let profile = shared_profile();
    forall(
        PropConfig { cases: 150, seed: 31 },
        |rng| {
            let residency: Vec<bool> = (0..12).map(|_| rng.bool(0.5)).collect();
            let k = rng.range(2, 5);
            let tokens: Vec<TokenRouting> = (0..rng.range(1, 6))
                .map(|_| {
                    let mut sel = Vec::new();
                    while sel.len() < k {
                        let e = rng.below(12);
                        if !sel.contains(&e) {
                            sel.push(e);
                        }
                    }
                    let mut w: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
                    let s: f32 = w.iter().sum();
                    w.iter_mut().for_each(|x| *x /= s);
                    w.sort_by(|a, b| b.total_cmp(a));
                    TokenRouting { selected: sel, weights: w }
                })
                .collect();
            SubCase {
                residency,
                tokens,
                rho: if rng.bool(0.5) { Some(rng.range(1, 4)) } else { None },
                h: rng.range(1, 13),
                tau: rng.f64(),
                beta: rng.f64(),
            }
        },
        |case| {
            let mut eng = SubstitutionEngine::new(&profile);
            eng.gates.tau = case.tau;
            eng.gates.beta = case.beta;
            eng.search_h = case.h;
            eng.rho = case.rho;
            let mut tokens = case.tokens.clone();
            let mut counters = Counters::new();
            let mut rng = Rng::new(1);
            let (decisions, events) = eng.apply(
                0,
                &mut tokens,
                &case.residency,
                MissPolicy::Buddy,
                None,
                &mut counters,
                &mut rng,
            );
            for (ti, (tok, dec)) in tokens.iter().zip(&decisions).enumerate() {
                // 1. No duplicate experts per token.
                let mut s = tok.selected.clone();
                s.sort_unstable();
                s.dedup();
                if s.len() != tok.selected.len() {
                    return Err(format!("token {ti} has duplicate experts"));
                }
                let mut subs = 0;
                for (slot, d) in dec.iter().enumerate() {
                    match d {
                        SlotDecision::Substitute { to, rank } => {
                            subs += 1;
                            // 2. Substitutes are GPU-resident.
                            if !case.residency[*to] {
                                return Err(format!("token {ti} slot {slot}: non-resident buddy"));
                            }
                            // 3. Within search rank H.
                            if *rank > case.h {
                                return Err(format!("rank {rank} > H {}", case.h));
                            }
                            // 4. Original expert really was missing.
                            if case.residency[case.tokens[ti].selected[slot]] {
                                return Err("substituted a resident expert".into());
                            }
                        }
                        SlotDecision::Keep => {
                            if !case.residency[tok.selected[slot]] {
                                return Err("kept a non-resident expert".into());
                            }
                        }
                        SlotDecision::Fetch => {
                            // Fetched slots keep the ORIGINAL expert.
                            if tok.selected[slot] != case.tokens[ti].selected[slot] {
                                return Err("fetch mutated selection".into());
                            }
                        }
                        SlotDecision::Dropped => return Err("buddy policy never drops".into()),
                    }
                }
                // 5. Replacement budget respected.
                if let Some(rho) = case.rho {
                    if subs > rho {
                        return Err(format!("token {ti}: {subs} subs > rho {rho}"));
                    }
                }
            }
            // 6. Counter consistency.
            if counters.get("slots_miss")
                != counters.get("substitutions") + counters.get("fetches") + counters.get("drops")
            {
                return Err("miss accounting broken".into());
            }
            // 7. Events match decisions.
            let dec_subs: usize = decisions
                .iter()
                .flatten()
                .filter(|d| matches!(d, SlotDecision::Substitute { .. }))
                .count();
            if events.len() != dec_subs {
                return Err("event count mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_drop_policy_weights_renormalize() {
    let profile = shared_profile();
    forall(
        PropConfig { cases: 100, seed: 41 },
        |rng| {
            let residency: Vec<bool> = (0..12).map(|_| rng.bool(0.4)).collect();
            let mut sel = Vec::new();
            while sel.len() < 4 {
                let e = rng.below(12);
                if !sel.contains(&e) {
                    sel.push(e);
                }
            }
            (residency, sel)
        },
        |(residency, sel)| {
            let eng = SubstitutionEngine::new(&profile);
            let mut tokens = vec![TokenRouting {
                selected: sel.clone(),
                weights: vec![0.4, 0.3, 0.2, 0.1],
            }];
            let mut counters = Counters::new();
            let mut rng = Rng::new(2);
            let (decisions, _) = eng.apply(
                0,
                &mut tokens,
                residency,
                MissPolicy::Drop,
                None,
                &mut counters,
                &mut rng,
            );
            let kept_any = decisions[0]
                .iter()
                .any(|d| !matches!(d, SlotDecision::Dropped));
            let sum: f32 = tokens[0].weights.iter().sum();
            if kept_any && (sum - 1.0).abs() > 1e-4 {
                return Err(format!("weights sum {sum} after drop"));
            }
            for (d, &w) in decisions[0].iter().zip(&tokens[0].weights) {
                if matches!(d, SlotDecision::Dropped) && w != 0.0 {
                    return Err("dropped slot kept weight".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// tensor views (PR 5)
// ---------------------------------------------------------------------

/// `TensorView` row access agrees with the owned `Tensor::row` across
/// shapes, both for tensor-backed views and raw-slice (arena-scratch
/// style) views with stack-held dims.
#[test]
fn prop_tensor_view_rows_match_owned() {
    use buddymoe::util::tensor::{Tensor, TensorView};
    forall(
        PropConfig { cases: 150, seed: 71 },
        |rng| {
            let rows = rng.range(1, 24);
            let w = rng.range(1, 48);
            let data: Vec<f32> = (0..rows * w).map(|_| (rng.f32() - 0.5) * 8.0).collect();
            (rows, w, data)
        },
        |(rows, w, data)| {
            let t = Tensor::new(vec![*rows, *w], data.clone()).map_err(|e| e.to_string())?;
            let v = TensorView::from_tensor(&t);
            if v.rank() != t.rank() || v.len() != t.len() {
                return Err("view shape disagrees with tensor".into());
            }
            let dims = [*rows, *w];
            let raw = TensorView::new(&dims, data).map_err(|e| e.to_string())?;
            for i in 0..*rows {
                if v.row(i) != t.row(i) {
                    return Err(format!("tensor-backed view row {i} differs"));
                }
                if raw.row(i) != t.row(i) {
                    return Err(format!("raw-slice view row {i} differs"));
                }
            }
            Ok(())
        },
    );
}
